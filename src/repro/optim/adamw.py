"""Hand-rolled AdamW (optax is not installed in this environment).

Decoupled weight decay, bias-corrected moments, optional global-norm
clipping. State is a pytree matching params, so the launcher's sharding
rules (including the ZeRO-style opt-state rules) apply transparently.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Union[float, Callable[[jax.Array], jax.Array]] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def lr_at(self, step) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state, params):
        """Returns (new_params, new_state, metrics)."""
        if self.grad_clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, self.grad_clip_norm)
        else:
            _, gnorm = clip_by_global_norm(grads, jnp.inf)
        step = state["step"] + 1
        lr = self.lr_at(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * jnp.square(g)
            mh = m_new / bc1
            vh = v_new / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            p32 = p.astype(jnp.float32)
            if self.weight_decay > 0 and p.ndim >= 2:   # no decay on norms
                delta = delta + self.weight_decay * p32
            return (p32 - lr * delta).astype(p.dtype), m_new, v_new

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_state = {
            "m": tdef.unflatten([o[1] for o in out]),
            "v": tdef.unflatten([o[2] for o in out]),
            "step": step,
        }
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
