from repro.optim.adamw import AdamW, clip_by_global_norm
from repro.optim.schedule import cosine_warmup
from repro.optim.grad_compression import (
    compressed_pod_mean, quantize_int8, dequantize_int8)
