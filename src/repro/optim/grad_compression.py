"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

The paper's broadcast&gather pattern maps to the DDP gradient collective
(DESIGN.md §2); across pods that collective crosses the slowest link
("cross-facility" analogue), so we offer 1-byte compressed exchange with
error feedback: each pod quantizes (grad + carried error) to int8 with a
per-tensor scale, all-gathers (values, scales), reconstructs the true mean,
and carries the quantization residual into the next step. Error feedback
preserves convergence (tests/test_optim.py checks a quadratic descends to
optimum through the compressor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_pod_mean(grad: jax.Array, error: jax.Array,
                        axis_name: str = "pod"):
    """Inside shard_map over ``axis_name``: returns (mean_grad, new_error).

    Exchanges int8 values + one fp32 scale per pod instead of bf16/fp32
    grads (≈2-4x less cross-pod traffic)."""
    comp_in = grad.astype(jnp.float32) + error
    q, s = quantize_int8(comp_in)
    qs = jax.lax.all_gather(q, axis_name)            # (n_pod, ...)
    ss = jax.lax.all_gather(s, axis_name)            # (n_pod,)
    n = qs.shape[0]
    mean = jnp.tensordot(ss, qs.astype(jnp.float32), axes=(0, 0)) / n
    new_error = comp_in - dequantize_int8(q, s)
    return mean.astype(grad.dtype), new_error
