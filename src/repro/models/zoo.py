"""Model zoo: one uniform interface over all assigned architecture families.

``build_model(cfg)`` returns a :class:`Model` bundle with:
  init_params(key)          real parameter init (smoke-test scale)
  abstract_params()         ShapeDtypeStruct pytree (dry-run, no allocation)
  param_specs()             logical-axis names per parameter
  forward(params, batch, ctx) -> logits
  loss(params, batch, ctx) -> scalar CE
  init_cache(batch, max_len) / abstract_cache(...)
  cache_specs()             logical-axis names for the decode cache
  decode_step(params, cache, tokens, pos, ctx) -> (logits, cache)
  make_batch(key|specs)     concrete or abstract input batches per family
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import hybrid, transformer, xlstm
from repro.models.layers import cross_entropy
from repro.models.sharding import ModelContext

TRANSFORMER_FAMILIES = ("dense", "moe", "audio", "vlm")


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    init_params: Callable
    param_specs: Callable
    forward: Callable
    init_cache: Callable
    cache_specs: Callable
    decode_step: Callable

    # ---- derived helpers -------------------------------------------------
    def loss(self, params, batch, ctx: Optional[ModelContext] = None):
        logits = self.forward(params, batch, ctx)
        labels = batch["labels"]
        if self.cfg.family == "vlm":
            # loss only on text positions (image prefix is conditioning)
            logits = logits[:, self.cfg.num_patches:]
        return cross_entropy(logits, labels, batch.get("loss_mask"))

    def abstract_params(self):
        return jax.eval_shape(lambda: self.init_params(jax.random.key(0)))

    def abstract_cache(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    # ---- input construction ----------------------------------------------
    def batch_shapes(self, batch: int, seq: int) -> dict:
        """ShapeDtypeStructs for one training/prefill batch."""
        cfg = self.cfg
        f32 = jnp.bfloat16
        i32 = jnp.int32
        if cfg.family == "audio":
            return {
                "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), f32),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            }
        if cfg.family == "vlm":
            P = cfg.num_patches
            return {
                "tokens": jax.ShapeDtypeStruct((batch, seq - P), i32),
                "patch_embeds": jax.ShapeDtypeStruct((batch, P, cfg.d_model),
                                                     f32),
                "labels": jax.ShapeDtypeStruct((batch, seq - P), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        }

    def batch_logical_axes(self) -> dict:
        cfg = self.cfg
        if cfg.family == "audio":
            return {"embeds": ("batch", "seq", "d_model"),
                    "labels": ("batch", "seq")}
        if cfg.family == "vlm":
            return {"tokens": ("batch", "seq"),
                    "patch_embeds": ("batch", "seq", "d_model"),
                    "labels": ("batch", "seq")}
        return {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}

    def make_batch(self, key, batch: int, seq: int) -> dict:
        """Concrete random batch (smoke tests / examples)."""
        cfg = self.cfg
        shapes = self.batch_shapes(batch, seq)
        out = {}
        for name, sds in shapes.items():
            key, sub = jax.random.split(key)
            if jnp.issubdtype(sds.dtype, jnp.integer):
                out[name] = jax.random.randint(
                    sub, sds.shape, 0, cfg.vocab_size, sds.dtype)
            else:
                out[name] = 0.02 * jax.random.normal(
                    sub, sds.shape).astype(sds.dtype)
        return out


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in TRANSFORMER_FAMILIES:
        return Model(
            cfg=cfg,
            init_params=lambda key: transformer.init_lm_params(key, cfg),
            param_specs=lambda: transformer.lm_param_specs(cfg),
            forward=functools.partial(_tf_forward, cfg),
            init_cache=lambda b, t: transformer.init_lm_cache(cfg, b, t),
            cache_specs=lambda: transformer.cache_specs(),
            decode_step=functools.partial(_tf_decode, cfg),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init_params=lambda key: hybrid.init_hybrid_params(key, cfg),
            param_specs=lambda: hybrid.hybrid_param_specs(cfg),
            forward=functools.partial(_hy_forward, cfg),
            init_cache=lambda b, t: hybrid.init_hybrid_cache(cfg, b, t),
            cache_specs=lambda: hybrid.hybrid_cache_specs(),
            decode_step=functools.partial(_hy_decode, cfg),
        )
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init_params=lambda key: xlstm.init_xlstm_lm_params(key, cfg),
            param_specs=lambda: xlstm.xlstm_param_specs(cfg),
            forward=functools.partial(_xl_forward, cfg),
            init_cache=lambda b, t: xlstm.init_xlstm_lm_cache(cfg, b, t),
            cache_specs=lambda: xlstm.xlstm_cache_specs(cfg),
            decode_step=functools.partial(_xl_decode, cfg),
        )
    raise ValueError(f"unknown family {cfg.family!r}")


def _tf_forward(cfg, params, batch, ctx=None, last_only=False):
    return transformer.lm_forward(params, batch, cfg, ctx, last_only)


def _tf_decode(cfg, params, cache, tokens, pos, ctx=None):
    return transformer.lm_decode_step(params, cache, tokens, pos, cfg, ctx)


def _hy_forward(cfg, params, batch, ctx=None, last_only=False):
    return hybrid.hybrid_forward(params, batch, cfg, ctx, last_only)


def _hy_decode(cfg, params, cache, tokens, pos, ctx=None):
    return hybrid.hybrid_decode_step(params, cache, tokens, pos, cfg, ctx)


def _xl_forward(cfg, params, batch, ctx=None, last_only=False):
    return xlstm.xlstm_forward(params, batch, cfg, ctx, last_only)


def _xl_decode(cfg, params, cache, tokens, pos, ctx=None):
    return xlstm.xlstm_decode_step(params, cache, tokens, pos, cfg, ctx)
