"""xLSTM blocks: chunkwise-parallel mLSTM + recurrent sLSTM (Beck et al.).

TPU adaptation (DESIGN.md §2): the mLSTM matrix memory is mathematically a
gated linear attention — we compute it in the same chunked dual form as the
Mamba2 SSD scan (batched chunk x chunk GEMMs on the MXU + a short scan over
chunk states), rather than porting the CUDA recurrent kernel. The matrix
memory (numerator) and the normalizer vector (denominator) are separate
states so the value dimension can TP-shard over the mesh.

Simplifications vs the paper (documented, tested for self-consistency):
* gates are computed in fp32 with clamped input-gate logits instead of the
  full max-stabilizer bookkeeping (exact for the magnitudes our configs
  produce; tests/test_models.py checks chunked == naive recurrence);
* sLSTM uses diagonal (per-channel) recurrent weights (block-diagonal
  simplification of the paper's per-head recurrent matrices).

xlstm-1.3b structure: 48 residual blocks, d_model 2048, 4 heads; every
``slstm_every``-th block is an sLSTM block, the rest mLSTM (7:1 ratio).
``d_ff=0``: there is no separate FFN — blocks carry their own 2x up/down
projections.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm
from repro.models.sharding import ModelContext

MLSTM_CHUNK = 256
IGATE_CLAMP = 8.0


# --------------------------------------------------------------------------
# parameter init (shared shape for both block kinds => stackable for scan)
# --------------------------------------------------------------------------


def init_xlstm_params(key, d_model: int, n_heads: int, expand: int = 2) -> dict:
    d_in = expand * d_model
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.zeros((d_model,), jnp.float32),
        "up_proj": dense_init(ks[0], (d_model, 2 * d_in)),   # [x | z-gate]
        "qkv": dense_init(ks[1], (d_in, 3 * d_in)),
        "gates": dense_init(ks[2], (d_in, 2 * n_heads), scale=0.01),
        "gate_bias": jnp.concatenate([
            jnp.zeros((n_heads,), jnp.float32),              # input gates
            jnp.linspace(3.0, 6.0, n_heads, dtype=jnp.float32),  # forget
        ]),
        # sLSTM extras (diagonal recurrence + output gate); zero-cost for
        # mLSTM blocks but kept in the stacked pytree for scan uniformity
        "r_diag": dense_init(ks[3], (4, d_in), scale=0.01),
        "o_proj": dense_init(ks[4], (d_in, d_in), scale=0.01),
        "out_norm": jnp.zeros((d_in,), jnp.float32),
        "down_proj": dense_init(ks[5], (d_in, d_model)),
    }


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def mlstm_chunked(q, k, v, i_gate, f_gate, chunk: int = MLSTM_CHUNK,
                  init_state=None):
    """Chunkwise mLSTM.

    q,k,v: (B,S,nh,hd); i_gate,f_gate: (B,S,nh) raw logits.
    Returns (h (B,S,nh,hd), state) with state = (C (B,nh,hd_k,hd_v),
    n (B,nh,hd_k)) — numerator matrix memory and denominator vector kept
    SEPARATE (not a ones-column on V) so the value dimension can be
    TP-sharded over the mesh without touching the normalizer.

    Dual form per chunk: weight(i<-j) = exp(cumlf_i - cumlf_j + i_j),
    h_i = sum_j w_ij (q_i . k_j) v_j / max(|den_i|, 1).
    """
    B, S, nh, hd = q.shape
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))        # (B,S,nh) <=0
    ig = jnp.clip(i_gate.astype(jnp.float32), -IGATE_CLAMP, IGATE_CLAMP)

    qc = qf.reshape(B, nc, chunk, nh, hd)
    kc = kf.reshape(B, nc, chunk, nh, hd)
    vc = vf.reshape(B, nc, chunk, nh, hd)
    lfc = lf.reshape(B, nc, chunk, nh)
    igc = ig.reshape(B, nc, chunk, nh)

    cum = jnp.cumsum(lfc, axis=2)
    total = cum[:, :, -1]                                      # (B,nc,nh)

    # intra-chunk (mask the exponent BEFORE exp: masked entries would
    # overflow and poison gradients through where)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :] + igc[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    W = jnp.exp(jnp.where(mask, diff, -1e30))
    scores = jnp.einsum("bcinh,bcjnh->bcijn", qc, kc)          # (B,nc,Q,Q,nh)
    WS = W * scores
    h_intra = jnp.einsum("bcijn,bcjnd->bcind", WS, vc)
    den_intra = WS.sum(axis=3)                                 # (B,nc,Q,nh)

    # chunk states: C_c = sum_j w_j k_j v_j^T ; n_c = sum_j w_j k_j
    wstate = jnp.exp(total[:, :, None, :] - cum + igc)         # (B,nc,Q,nh)
    states = jnp.einsum("bcjn,bcjnh,bcjnd->bcnhd", wstate, kc, vc)
    nstates = jnp.einsum("bcjn,bcjnh->bcnh", wstate, kc)

    if init_state is None:
        s0 = (jnp.zeros((B, nh, hd, hd), jnp.float32),
              jnp.zeros((B, nh, hd), jnp.float32))
    else:
        s0 = init_state

    def step(carry, inp):
        sC, sn = carry
        stC, stn, tot = inp
        d = jnp.exp(tot)
        return (sC * d[:, :, None, None] + stC,
                sn * d[:, :, None] + stn), (sC, sn)

    (finC, finN), (prevC, prevN) = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   nstates.transpose(1, 0, 2, 3),
                   total.transpose(1, 0, 2)))
    prevC = prevC.transpose(1, 0, 2, 3, 4)     # (B,nc,nh,hd_k,hd_v)
    prevN = prevN.transpose(1, 0, 2, 3)        # (B,nc,nh,hd_k)

    ecum = jnp.exp(cum)
    h_inter = jnp.einsum("bcinh,bcnhd,bcin->bcind", qc, prevC, ecum)
    den_inter = jnp.einsum("bcinh,bcnh,bcin->bcin", qc, prevN, ecum)
    num = (h_intra + h_inter).reshape(B, S, nh, hd)
    den = (den_intra + den_inter).reshape(B, S, nh)
    out = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return out.astype(q.dtype), (finC, finN)


def mlstm_decode_step(q, k, v, i_gate, f_gate, state):
    """Single step. q,k,v: (B,nh,hd); gates (B,nh);
    state = (C (B,nh,hd,hd), n (B,nh,hd))."""
    hd = q.shape[-1]
    C, n = state
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    ig = jnp.clip(i_gate.astype(jnp.float32), -IGATE_CLAMP, IGATE_CLAMP)
    d = jnp.exp(lf)
    w = jnp.exp(ig)
    C_new = C * d[:, :, None, None] + w[:, :, None, None] * jnp.einsum(
        "bnh,bnd->bnhd", kf, vf)
    n_new = n * d[:, :, None] + w[:, :, None] * kf
    num = jnp.einsum("bnh,bnhd->bnd", qf, C_new)
    den = jnp.einsum("bnh,bnh->bn", qf, n_new)
    out = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return out.astype(q.dtype), (C_new, n_new)


# --------------------------------------------------------------------------
# sequence-parallel mLSTM (ring mode): affine state exchange
# --------------------------------------------------------------------------
#
# Under sequence sharding (S over `model`), every projection/norm is
# position-wise (zero comm); only the inter-chunk state recurrence crosses
# ranks. That recurrence is an AFFINE map per rank r:
#     s_out = s_in * D_r + F_r
# (D_r = prod of the rank's chunk decays, F_r = its final local state with
# zero init), and affine maps compose associatively — so instead of a
# sequential 16-hop ring, each rank all-gathers every (D_r, F_r) pair once
# and computes its incoming state in closed form:
#     s_in(r) = sum_{r'<r} F_{r'} * prod_{r'<r''<r} D_{r''}
# Cost: one all_gather of (n_model, B, nh, hd, hd)-ish per layer plus a
# cheap first pass that computes only the chunk-state reductions.


def _mlstm_rank_summary(k, v, i_gate, f_gate, chunk: int):
    """Per-rank (log-decay total, final C, final n) with zero init —
    the affine map (D_r, F_r) of this rank's sequence slice."""
    B, S, nh, hd = k.shape
    nc = max(S // chunk, 1)
    kc = k.astype(jnp.float32).reshape(B, nc, -1, nh, hd)
    vc = v.astype(jnp.float32).reshape(B, nc, -1, nh, hd)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32)).reshape(B, nc, -1, nh)
    ig = jnp.clip(i_gate.astype(jnp.float32), -IGATE_CLAMP,
                  IGATE_CLAMP).reshape(B, nc, -1, nh)
    cum = jnp.cumsum(lf, axis=2)
    total = cum[:, :, -1]                                  # (B,nc,nh)
    w = jnp.exp(total[:, :, None, :] - cum + ig)
    states = jnp.einsum("bcjn,bcjnh,bcjnd->bcnhd", w, kc, vc)
    nstates = jnp.einsum("bcjn,bcjnh->bcnh", w, kc)

    def step(carry, inp):
        sC, sn = carry
        stC, stn, tot = inp
        d = jnp.exp(tot)
        return (sC * d[:, :, None, None] + stC,
                sn * d[:, :, None] + stn), None

    (fC, fN), _ = jax.lax.scan(
        step, (jnp.zeros_like(states[:, 0]), jnp.zeros_like(nstates[:, 0])),
        (states.transpose(1, 0, 2, 3, 4), nstates.transpose(1, 0, 2, 3),
         total.transpose(1, 0, 2)))
    logD = total.sum(axis=1)                               # (B,nh)
    return logD, fC, fN


def mlstm_seq_parallel(q, k, v, i_gate, f_gate, *, mesh, batch_axes,
                       chunk: int = MLSTM_CHUNK):
    """mLSTM with the sequence dim sharded over `model` via shard_map.
    q,k,v: (B, S, nh, hd) GLOBAL shapes, S sharded over `model`."""
    from jax.sharding import PartitionSpec as P
    n_model = mesh.shape["model"]
    io_spec = P(batch_axes, "model", None, None)
    g_spec = P(batch_axes, "model", None)

    def body(q_l, k_l, v_l, ig_l, fg_l):
        rank = jax.lax.axis_index("model")
        logD, fC, fN = _mlstm_rank_summary(k_l, v_l, ig_l, fg_l, chunk)
        # gather every rank's affine map: (n, B, nh, ...)
        logDs = jax.lax.all_gather(logD, "model")
        fCs = jax.lax.all_gather(fC, "model")
        fNs = jax.lax.all_gather(fN, "model")
        # incoming state: sum_{r<rank} F_r * exp(decay between r and rank)
        idx = jnp.arange(n_model)
        csum = jnp.cumsum(logDs, axis=0)                  # inclusive prefix
        upto = jnp.where(rank > 0, csum[jnp.maximum(rank - 1, 0)],
                         jnp.zeros_like(csum[0]))
        w_log = upto[None] - csum                         # (n, B, nh)
        mask = (idx < rank)[:, None, None]
        wgt = jnp.where(mask, jnp.exp(jnp.where(mask, w_log, 0.0)), 0.0)
        inC = jnp.einsum("rbn,rbnhd->bnhd", wgt, fCs)
        inN = jnp.einsum("rbn,rbnh->bnh", wgt, fNs)
        out, _ = mlstm_chunked(q_l, k_l, v_l, ig_l, fg_l, chunk=chunk,
                               init_state=(inC, inN))
        return out

    from repro.compat import shard_map
    return shard_map(
        body, mesh=mesh,
        in_specs=(io_spec, io_spec, io_spec, g_spec, g_spec),
        out_specs=io_spec, check_vma=False)(q, k, v, i_gate, f_gate)


# --------------------------------------------------------------------------
# sLSTM (sequential scalar memory)
# --------------------------------------------------------------------------


def slstm_scan(zifo, r_diag, n_heads: int, init_state=None):
    """zifo: (B, S, 4, d_in) pre-activations for z,i,f,o; r_diag: (4, d_in)
    diagonal recurrent weights. Returns (h (B,S,d_in), state)."""
    B, S, _, d_in = zifo.shape
    if init_state is None:
        init_state = (jnp.zeros((B, d_in), jnp.float32),
                      jnp.ones((B, d_in), jnp.float32),
                      jnp.zeros((B, d_in), jnp.float32))

    def step(carry, x_t):
        c, n, h_prev = carry
        pre = x_t.astype(jnp.float32) + r_diag * h_prev[:, None, :]
        z = jnp.tanh(pre[:, 0])
        i = jnp.exp(jnp.clip(pre[:, 1], -IGATE_CLAMP, IGATE_CLAMP))
        f = jax.nn.sigmoid(pre[:, 2])
        o = jax.nn.sigmoid(pre[:, 3])
        c_new = f * c + i * z
        n_new = f * n + i
        h = o * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h), h

    state, hs = jax.lax.scan(step, init_state,
                             zifo.transpose(1, 0, 2, 3))
    return hs.transpose(1, 0, 2), state


def slstm_decode_step(zifo, r_diag, state):
    """zifo: (B, 4, d_in); one step of the scan above."""
    c, n, h_prev = state
    pre = zifo.astype(jnp.float32) + r_diag * h_prev[:, None, :]
    z = jnp.tanh(pre[:, 0])
    i = jnp.exp(jnp.clip(pre[:, 1], -IGATE_CLAMP, IGATE_CLAMP))
    f = jax.nn.sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    c_new = f * c + i * z
    n_new = f * n + i
    h = o * c_new / jnp.maximum(n_new, 1.0)
    return h, (c_new, n_new, h)


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------


def xlstm_block(x, params, *, n_heads: int, is_slstm: bool,
                ctx: Optional[ModelContext] = None,
                decode_state=None):
    """One residual xLSTM block (pre-norm, 2x up/down projection).
    x: (B, S, D). Returns (y, new_decode_state)."""
    B, S, D = x.shape
    d_in = params["up_proj"].shape[1] // 2
    h = rmsnorm(x, params["norm"])
    vtp = (ctx is not None and ctx.rules is not None
           and ctx.rules.get("xlstm_hd") and not is_slstm)
    if vtp:
        # merged column-parallel projections (vtp mode): qkv and the gates
        # consume the x-branch of up_proj LINEARLY, so fold
        # (up_x @ qkv) / (up_x @ gates) into single D->out weights — every
        # projection is column-sharded on the head dim with ZERO comms; the
        # block's only collective is the down_proj row-parallel all-reduce.
        # (Merge cost: D x d_in x 3d_in per layer, batch-free => negligible.)
        d_in_ = params["up_proj"].shape[1] // 2
        up_x = params["up_proj"][:, :d_in_]
        up_z = params["up_proj"][:, d_in_:]
        w_qkv = (up_x @ params["qkv"]).astype(h.dtype)      # (D, 3*d_in)
        w_gates = (up_x @ params["gates"]).astype(h.dtype)  # (D, 2*nh)
        z = h @ up_z.astype(h.dtype)
        xin = None
    else:
        up = h @ params["up_proj"].astype(h.dtype)
        xin, z = jnp.split(up, 2, axis=-1)

    if is_slstm:
        # map qkv projection output onto z,i,f,o pre-activations:
        # reuse qkv (3*d_in) + o_proj (d_in) for the 4 gates
        zi = xin @ params["qkv"].astype(xin.dtype)           # (B,S,3*d_in)
        og = xin @ params["o_proj"].astype(xin.dtype)        # (B,S,d_in)
        zifo = jnp.concatenate([zi, og], axis=-1).reshape(B, S, 4, d_in)
        if (ctx is not None and ctx.rules is not None
                and ctx.rules.get("_parallelism") == "ring"
                and decode_state is None):
            # sLSTM's h_{t-1} recurrence is not affine-composable: gather
            # the (cheap, scalar-memory) scan onto every rank
            zifo = ctx.shard(zifo, "batch", "attn_seq", None, None)
        if decode_state is None:
            hseq, new_state = slstm_scan(zifo, params["r_diag"], n_heads)
            if (ctx is not None and ctx.rules is not None
                    and ctx.rules.get("_parallelism") == "ring"):
                hseq = ctx.shard(hseq, "batch", "seq", None)
        else:
            h1, new_state = slstm_decode_step(
                zifo[:, 0], params["r_diag"], decode_state)
            hseq = h1[:, None]
        inner = hseq.astype(x.dtype)
    else:
        nh = n_heads
        hd = d_in // nh
        if vtp:
            qkv = h @ w_qkv
        else:
            qkv = xin @ params["qkv"].astype(xin.dtype)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(B, S, nh, hd)
        k = k.reshape(B, S, nh, hd)
        v = v.reshape(B, S, nh, hd)
        if ctx is not None and ctx.rules and ctx.rules.get("xlstm_hd"):
            # head-dim TP (hillclimb lever, parallelism="vtp"): when heads
            # are too few to shard, shard hd over `model` for q/k/v — the
            # projection GEMMs stay fully distributed, the qk-score
            # contraction all-reduces once, and the matrix memory's value
            # dim stays sharded end-to-end. (No constraint otherwise: let
            # GSPMD propagate the d_ff sharding of the projections.)
            q = ctx.shard(q, "batch", "seq", "ssm_heads", "xlstm_hd")
            k = ctx.shard(k, "batch", "seq", "ssm_heads", "xlstm_hd")
            v = ctx.shard(v, "batch", "seq", "ssm_heads", "xlstm_hd")
        gates = (h @ w_gates if vtp
                 else xin @ params["gates"].astype(xin.dtype))  # (B,S,2*nh)
        gates = gates.astype(jnp.float32) + params["gate_bias"][None, None, :]
        ig, fg = jnp.split(gates, 2, axis=-1)
        ring = (ctx is not None and ctx.rules is not None
                and ctx.rules.get("_parallelism") == "ring"
                and decode_state is None and ctx.mesh is not None)
        if ring:
            n_model = ctx.mesh.shape["model"]
            hseq = mlstm_seq_parallel(
                q, k, v, ig, fg, mesh=ctx.mesh,
                batch_axes=ctx.rules.get("batch"),
                chunk=min(MLSTM_CHUNK, max(S // n_model, 1)))
            new_state = None
        elif decode_state is None:
            hseq, new_state = mlstm_chunked(q, k, v, ig, fg,
                                            chunk=min(MLSTM_CHUNK, S))
        else:
            h1, new_state = mlstm_decode_step(
                q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0], decode_state)
            hseq = h1[:, None]
        inner = hseq.reshape(B, S, d_in).astype(x.dtype)

    inner = rmsnorm(inner, params["out_norm"]) * jax.nn.silu(z)
    out = inner @ params["down_proj"].astype(inner.dtype)
    return x + out, new_state


def init_xlstm_state(batch: int, d_model: int, n_heads: int,
                     is_slstm: bool, expand: int = 2):
    d_in = expand * d_model
    if is_slstm:
        return (jnp.zeros((batch, d_in), jnp.float32),
                jnp.ones((batch, d_in), jnp.float32),
                jnp.zeros((batch, d_in), jnp.float32))
    hd = d_in // n_heads
    return (jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
            jnp.zeros((batch, n_heads, hd), jnp.float32))


# --------------------------------------------------------------------------
# model level (xlstm-1.3b): heterogeneous blocks => python-unrolled loop
# --------------------------------------------------------------------------


def slstm_flags(cfg) -> list[bool]:
    if cfg.slstm_every <= 0:
        return [False] * cfg.n_layers
    return [(i + 1) % cfg.slstm_every == 0 for i in range(cfg.n_layers)]


def init_xlstm_lm_params(key, cfg) -> dict:
    from repro.models.layers import dense_init
    kb, ke, kh = jax.random.split(key, 3)
    per = [init_xlstm_params(k, cfg.d_model, cfg.n_heads)
           for k in jax.random.split(kb, cfg.n_layers)]
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    return {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model)),
        "blocks": stack,
        "final_norm": jnp.zeros((cfg.d_model,)),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab_size)),
    }


def xlstm_param_specs(cfg) -> dict:
    return {
        "embed": ("vocab", "d_model"),
        "blocks": {
            "norm": ("layers", "d_model"),
            "up_proj": ("layers", "d_model", None),
            "qkv": ("layers", "d_model", "d_ff"),
            "gates": ("layers", "d_model", None),
            "gate_bias": ("layers", None),
            "r_diag": ("layers", None, "d_model"),
            "o_proj": ("layers", "d_model", "d_ff"),
            "out_norm": ("layers", "d_model"),
            "down_proj": ("layers", "d_model", None),
        },
        "final_norm": ("d_model",),
        "lm_head": ("d_model", "vocab"),
    }


def xlstm_forward(params, batch, cfg, ctx: Optional[ModelContext] = None,
                  last_only: bool = False):
    from repro.models.layers import embed as embed_fn, unembed
    ctx = ctx or ModelContext()
    x = embed_fn(batch["tokens"], params["embed"].astype(jnp.bfloat16), ctx)
    flags = slstm_flags(cfg)

    def make_block(i, flag):
        def blk(x):
            p_i = jax.tree.map(lambda a: a[i], params["blocks"])
            y, _ = xlstm_block(x, p_i, n_heads=cfg.n_heads, is_slstm=flag,
                               ctx=ctx)
            return y
        return jax.checkpoint(blk) if cfg.remat else blk

    for i, flag in enumerate(flags):
        x = make_block(i, flag)(x)
    if last_only:
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"])
    logits = unembed(x, params["lm_head"], 0.0, ctx)
    if ctx.distributed:
        logits = ctx.shard(logits, "batch", "seq", "vocab")
    return logits


def init_xlstm_lm_cache(cfg, batch: int, max_len: int = 0) -> list:
    return [init_xlstm_state(batch, cfg.d_model, cfg.n_heads, f)
            for f in slstm_flags(cfg)]


def xlstm_cache_specs(cfg) -> list:
    out = []
    for f in slstm_flags(cfg):
        if f:
            out.append((("batch", None),) * 3)
        else:
            out.append((("batch", "ssm_heads", None, "xlstm_hd"),
                        ("batch", "ssm_heads", None)))
    return out


def xlstm_decode_step(params, cache, tokens, pos, cfg,
                      ctx: Optional[ModelContext] = None):
    from repro.models.layers import embed as embed_fn, unembed
    ctx = ctx or ModelContext()
    x = embed_fn(tokens[:, None], params["embed"].astype(jnp.bfloat16), None)
    new_cache = []
    for i, flag in enumerate(slstm_flags(cfg)):
        p_i = jax.tree.map(lambda a: a[i], params["blocks"])
        x, st = xlstm_block(x, p_i, n_heads=cfg.n_heads, is_slstm=flag,
                            ctx=ctx, decode_state=cache[i])
        new_cache.append(st)
    x = rmsnorm(x[:, 0], params["final_norm"])
    logits = unembed(x, params["lm_head"], 0.0, ctx)
    return logits, new_cache
