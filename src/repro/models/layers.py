"""Shared neural layers: RMSNorm, RoPE, GQA attention (three
implementations), SwiGLU MLP, embeddings, losses.

Attention implementations:

* ``reference`` — materializes the full (S, T) score matrix. Oracle for
  tests and the small-sequence default.
* ``blocked``   — online-softmax over KV blocks via ``lax.scan``; O(S*block)
  memory, used for long sequences in the lowered (dry-run) path where the
  Pallas kernel cannot lower (CPU host backend has no Mosaic).
* ``pallas``    — the TPU kernel in :mod:`repro.kernels` (fwd), enabled on
  real TPU; validated against ``reference`` in interpret mode by tests.

GQA layout decisions (TPU/GSPMD-friendly, see DESIGN.md):
* train/prefill: K/V are *expanded* to the full head count so Q/K/V/O all
  shard cleanly over the ``model`` axis by heads (no awkward grouped-dim
  reshardings);
* decode: grouped einsum against the KV cache with the *sequence* dimension
  sharded over ``model`` — a distributed flash-decode (GSPMD turns the
  masked softmax into partial max/sum + cross-shard combines).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import ModelContext

NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms / activations
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, T, KV, hd) -> (B, T, H, hd) by repeating each KV head."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    reps = n_heads // kv
    return jnp.repeat(k, reps, axis=2)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: int) -> jax.Array:
    """(S, T) additive bias: 0 where visible, NEG_INF where masked."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention_reference(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                        logit_cap=0.0, scale=None,
                        ctx: Optional[ModelContext] = None) -> jax.Array:
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) -> (B,S,H,hd). Full score matrix."""
    H, hd = q.shape[2], q.shape[3]
    scale = (hd ** -0.5) if scale is None else scale
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if logit_cap > 0:
        s = softcap(s, logit_cap)
    s = s + _mask_bias(q_pos, k_pos, causal, window)[None, None]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)
    return out


def attention_blocked(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                      logit_cap=0.0, scale=None, block=1024,
                      ctx: Optional[ModelContext] = None) -> jax.Array:
    """Online-softmax over KV blocks (flash-attention recurrence in XLA).

    Memory O(S*block) instead of O(S*T); exact same math as reference.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    scale = (hd ** -0.5) if scale is None else scale
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    if T % block != 0:
        pad = block - T % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-(10 ** 9))
        T += pad
    nblk = T // block
    qf = q.astype(jnp.float32) * scale
    # scan carry: running max m (B,H,S), sum l (B,H,S), acc (B,H,S,hd)
    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, hd), jnp.float32)
    kb = k.reshape(B, nblk, block, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, H, hd).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(nblk, block)

    def step(carry, blk):
        m, l, acc = carry
        k_j, v_j, kp = blk
        s = jnp.einsum("bshd,bthd->bhst", qf, k_j.astype(jnp.float32))
        if logit_cap > 0:
            s = softcap(s, logit_cap)
        s = s + _mask_bias(q_pos, kp, causal, window)[None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p, v_j.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    unroll = nblk if (ctx is not None and ctx.unroll_scans) else 1
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb),
                                  unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
              logit_cap=0.0, scale=None,
              ctx: Optional[ModelContext] = None) -> jax.Array:
    """Dispatch by ctx.attention_impl (auto: blocked beyond threshold)."""
    impl = ctx.attention_impl if ctx is not None else "auto"
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(
            q, k, v, q_pos, k_pos, causal=causal, window=window,
            logit_cap=logit_cap, scale=scale,
            interpret=ctx.interpret if ctx else True)
    if impl == "auto":
        thresh = ctx.blocked_threshold if ctx is not None else 2048
        impl = "blocked" if q.shape[1] > thresh else "reference"
    fn = attention_blocked if impl == "blocked" else attention_reference
    return fn(q, k, v, q_pos, k_pos, causal=causal, window=window,
              logit_cap=logit_cap, scale=scale, ctx=ctx)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, logit_cap=0.0,
                     scale=None, ctx: Optional[ModelContext] = None
                     ) -> jax.Array:
    """One-token attention against a (possibly sequence-sharded) KV cache.

    q: (B, H, hd); k_cache/v_cache: (B, T, KV, hd); pos: (B,) int32 index of
    the current token (already written into the cache). Grouped einsum — no
    KV expansion — so the cache's T dimension can be sharded over ``model``
    (distributed flash-decode; GSPMD inserts the partial-softmax combines).
    """
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    T = k_cache.shape[1]
    scale = (hd ** -0.5) if scale is None else scale
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache.astype(jnp.float32))
    if logit_cap > 0:
        s = softcap(s, logit_cap)
    t_idx = jnp.arange(T)
    ok = t_idx[None, :] <= pos[:, None]                       # (B, T)
    if window > 0:
        ok &= (pos[:, None] - t_idx[None, :]) < window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# MLP / embeddings / loss
# --------------------------------------------------------------------------


def swiglu(x: jax.Array, wi: jax.Array, wo: jax.Array,
           ctx: Optional[ModelContext] = None) -> jax.Array:
    """wi: (D, 2F) fused gate+up; wo: (F, D)."""
    h = x @ wi.astype(x.dtype)
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    if ctx is not None and x.ndim == 3:
        h = ctx.shard(h, "batch", "attn_seq", "d_ff")
    return h @ wo.astype(x.dtype)


def embed(tokens: jax.Array, table: jax.Array,
          ctx: Optional[ModelContext] = None) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    if ctx is not None and out.ndim == 3:
        out = ctx.shard(out, "batch", "seq", "d_model")
    return out


def unembed(x: jax.Array, w: jax.Array, final_cap: float = 0.0,
            ctx: Optional[ModelContext] = None) -> jax.Array:
    """x: (..., D) @ w: (D, V) -> logits, optional final softcap (gemma2)."""
    logits = x @ w.astype(x.dtype)
    if final_cap > 0:
        logits = softcap(logits.astype(jnp.float32), final_cap)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE. logits (B,S,V) [vocab-shardable], labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        total = jnp.maximum(mask.sum(), 1)
        return (nll * mask).sum() / total
    return nll.mean()


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------


def dense_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)
