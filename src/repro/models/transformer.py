"""Decoder-only transformer LM covering the dense, MoE, audio-backbone and
VLM-backbone families.

Structure choices made for compile-scale (40 dry-run cells x 512 devices):
* parameters are stacked along a leading layer axis and the layer loop is a
  ``lax.scan`` (keeps HLO size O(1) in depth);
* ``jax.checkpoint`` (remat) wraps the block body;
* per-layer static variation (gemma2's local/global alternation) rides the
  scan as a boolean ``xs`` array — both mask variants are position
  arithmetic, never materialized S x S;
* gradient-accumulation microbatching lives in the training step
  (:mod:`repro.launch.steps`), not here.

``batch`` accepted forms (modality frontends are stubs per the brief):
  {"tokens": (B,S) int32}                                  # LM
  {"embeds": (B,S,D) bf16, "labels": (B,S)}                # audio (musicgen)
  {"tokens": (B,S_text), "patch_embeds": (B,P,D)}          # vlm (pixtral)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.moe import moe_block
from repro.models.sharding import ModelContext


# --------------------------------------------------------------------------
# init + specs
# --------------------------------------------------------------------------


def init_lm_params(key, cfg: ArchConfig) -> dict:
    D, V, ff = cfg.d_model, cfg.vocab_size, cfg.d_ff
    H, KV, hd, Lr = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.n_layers
    ks = iter(jax.random.split(key, 24))
    def dn(shape, scale=0.02):
        return L.dense_init(next(ks), shape, scale)
    blocks = {
        "attn_norm": jnp.zeros((Lr, D)),
        "wq": dn((Lr, D, H * hd)),
        "wk": dn((Lr, D, KV * hd)),
        "wv": dn((Lr, D, KV * hd)),
        "wo": dn((Lr, H * hd, D)),
        "mlp_norm": jnp.zeros((Lr, D)),
    }
    if cfg.is_moe:
        E, ns = cfg.n_experts, cfg.n_shared_experts
        blocks["router"] = dn((Lr, D, E))
        blocks["wi_e"] = dn((Lr, E, D, 2 * ff))
        blocks["wo_e"] = dn((Lr, E, ff, D))
        if ns > 0:
            blocks["wi_s"] = dn((Lr, D, 2 * ff * ns))
            blocks["wo_s"] = dn((Lr, ff * ns, D))
    else:
        blocks["wi"] = dn((Lr, D, 2 * ff))
        blocks["wo_mlp"] = dn((Lr, ff, D))
    if cfg.post_norms:
        blocks["post_attn_norm"] = jnp.zeros((Lr, D))
        blocks["post_mlp_norm"] = jnp.zeros((Lr, D))
    params = {
        "embed": dn((V, D)),
        "blocks": blocks,
        "final_norm": jnp.zeros((D,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dn((D, V))
    return params


def lm_param_specs(cfg: ArchConfig) -> dict:
    """Logical-axis names per parameter (same pytree structure as params)."""
    blocks = {
        "attn_norm": ("layers", "d_model"),
        "wq": ("layers", "d_model", "heads"),
        "wk": ("layers", "d_model", "kv_heads"),
        "wv": ("layers", "d_model", "kv_heads"),
        "wo": ("layers", "heads", "d_model"),
        "mlp_norm": ("layers", "d_model"),
    }
    if cfg.is_moe:
        blocks["router"] = ("layers", "d_model", None)
        blocks["wi_e"] = ("layers", "experts", "d_model", None)
        blocks["wo_e"] = ("layers", "experts", None, "d_model")
        if cfg.n_shared_experts > 0:
            blocks["wi_s"] = ("layers", "d_model", "d_ff")
            blocks["wo_s"] = ("layers", "d_ff", "d_model")
    else:
        blocks["wi"] = ("layers", "d_model", "d_ff")
        blocks["wo_mlp"] = ("layers", "d_ff", "d_model")
    if cfg.post_norms:
        blocks["post_attn_norm"] = ("layers", "d_model")
        blocks["post_mlp_norm"] = ("layers", "d_model")
    specs = {
        "embed": ("vocab", "d_model"),
        "blocks": blocks,
        "final_norm": ("d_model",),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ("d_model", "vocab")
    return specs


def _pair(blocks: dict, n_layers: int) -> dict:
    """Stack (L, ...) params into (L/2, 2, ...) for the local/global
    pair-scan (gemma2). Each sub-layer keeps a *static* window, so each
    attention variant is computed exactly once (no compute-both-select)."""
    return jax.tree.map(
        lambda a: a.reshape(n_layers // 2, 2, *a.shape[1:]), blocks)


# --------------------------------------------------------------------------
# block
# --------------------------------------------------------------------------


def _attn_proj(x, p, cfg: ArchConfig, ctx: ModelContext, positions):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, KV, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, KV, hd)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if ctx is not None:
        q = ctx.shard(q, "batch", "attn_seq", "heads", "head_dim")
    return q, k, v


def _moe_params(p: dict, cfg: ArchConfig) -> dict:
    mp = {"router": p["router"], "wi": p["wi_e"], "wo": p["wo_e"]}
    if cfg.n_shared_experts > 0:
        mp["wi_s"] = p["wi_s"]
        mp["wo_s"] = p["wo_s"]
    return mp


def transformer_block(x, p, window: int, cfg: ArchConfig, ctx: ModelContext,
                      positions):
    """Pre-norm block with a *static* attention window (0 = global)."""
    B, S, D = x.shape
    h = L.rmsnorm(x, p["attn_norm"])
    q, k, v = _attn_proj(h, p, cfg, ctx, positions)
    attn_out = L.attention(q, k, v, positions, positions, causal=True,
                           window=window,
                           logit_cap=cfg.attn_logit_softcap, ctx=ctx)
    attn_out = attn_out.reshape(B, S, cfg.n_heads * cfg.hd)
    attn_out = attn_out @ p["wo"].astype(x.dtype)
    if cfg.post_norms:
        attn_out = L.rmsnorm(attn_out, p["post_attn_norm"])
    x = x + attn_out
    h = L.rmsnorm(x, p["mlp_norm"])
    if ctx is not None:
        h = ctx.shard(h, "batch", "seq", "d_model")
    if cfg.is_moe:
        mlp_out = moe_block(
            h, _moe_params(p, cfg),
            k=cfg.experts_per_token, n_experts=cfg.n_experts,
            n_shared=cfg.n_shared_experts,
            capacity_factor=cfg.capacity_factor, ctx=ctx)
    else:
        mlp_out = L.swiglu(h, p["wi"], p["wo_mlp"], ctx)
    if cfg.post_norms:
        mlp_out = L.rmsnorm(mlp_out, p["post_mlp_norm"])
    return x + mlp_out


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------


def _input_embeds(params, batch, cfg: ArchConfig, ctx: ModelContext):
    if "embeds" in batch:                     # audio stub frontend
        x = batch["embeds"]
    elif "patch_embeds" in batch:             # vlm stub frontend
        tok = L.embed(batch["tokens"], params["embed"].astype(jnp.bfloat16),
                      ctx)
        x = jnp.concatenate([batch["patch_embeds"].astype(tok.dtype), tok],
                            axis=1)
    else:
        x = L.embed(batch["tokens"], params["embed"].astype(jnp.bfloat16),
                    ctx)
    return x


def lm_forward(params, batch, cfg: ArchConfig,
               ctx: Optional[ModelContext] = None,
               last_only: bool = False) -> jax.Array:
    """Returns logits (B, S, V), or (B, 1, V) when ``last_only`` (prefill:
    skips the full-sequence vocab head — V/H x less head compute and no
    (B, S, V) logits materialization)."""
    ctx = ctx or ModelContext()
    x = _input_embeds(params, batch, cfg, ctx)
    B, S, D = x.shape
    positions = jnp.arange(S)
    paired = cfg.attn_pattern == "local_global"

    if paired:
        def body(x, p2):
            p_loc = jax.tree.map(lambda a: a[0], p2)
            p_glb = jax.tree.map(lambda a: a[1], p2)
            x = transformer_block(x, p_loc, cfg.window, cfg, ctx, positions)
            x = transformer_block(x, p_glb, 0, cfg, ctx, positions)
            return x, None
        stacked = _pair(params["blocks"], cfg.n_layers)
        n_steps = cfg.n_layers // 2
    else:
        def body(x, p):
            return transformer_block(x, p, 0, cfg, ctx, positions), None
        stacked = params["blocks"]
        n_steps = cfg.n_layers

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, stacked)
    else:
        for i in range(n_steps):
            p_i = jax.tree.map(lambda a: a[i], stacked)
            x, _ = body(x, p_i)
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = L.unembed(x, head, cfg.final_logit_softcap, ctx)
    if ctx is not None and logits.ndim == 3:
        logits = ctx.shard(logits, "batch", "seq", "vocab")
    return logits


# --------------------------------------------------------------------------
# KV cache + decode
# --------------------------------------------------------------------------


def init_lm_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs() -> dict:
    ax = (None, "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax}


def lm_decode_step(params, cache, tokens, pos, cfg: ArchConfig,
                   ctx: Optional[ModelContext] = None):
    """One decode step. tokens: (B,) int32; pos: (B,) int32 current index.
    Returns (logits (B, V), new_cache)."""
    ctx = ctx or ModelContext()
    B = tokens.shape[0]
    x = L.embed(tokens[:, None], params["embed"].astype(jnp.bfloat16), None)
    paired = cfg.attn_pattern == "local_global"

    def sub_block(x, p, k_l, v_l, window: int):
        h = L.rmsnorm(x, p["attn_norm"])
        q, k, v = _attn_proj(h, p, cfg, ctx, pos[:, None])
        # write current token's K/V into the (seq-sharded) cache
        k_l = _cache_write(k_l, k[:, 0], pos)
        v_l = _cache_write(v_l, v[:, 0], pos)
        if ctx is not None:
            k_l = ctx.shard(k_l, "batch", "kv_seq", "kv_heads", "head_dim")
            v_l = ctx.shard(v_l, "batch", "kv_seq", "kv_heads", "head_dim")
        a = L.decode_attention(q[:, 0], k_l, v_l, pos, window=window,
                               logit_cap=cfg.attn_logit_softcap, ctx=ctx)
        a = a.reshape(B, cfg.n_heads * cfg.hd) @ p["wo"].astype(x.dtype)
        if cfg.post_norms:
            a = L.rmsnorm(a, p["post_attn_norm"])
        x = x + a[:, None]
        h = L.rmsnorm(x, p["mlp_norm"])
        if cfg.is_moe:
            m = moe_block(
                h, _moe_params(p, cfg),
                k=cfg.experts_per_token, n_experts=cfg.n_experts,
                n_shared=cfg.n_shared_experts,
                capacity_factor=cfg.capacity_factor, ctx=ctx)
        else:
            m = L.swiglu(h, p["wi"], p["wo_mlp"], ctx)
        if cfg.post_norms:
            m = L.rmsnorm(m, p["post_mlp_norm"])
        return x + m, k_l, v_l

    if paired:
        def body(x, xs):
            p2, k2, v2 = xs
            outs_k, outs_v = [], []
            for j, window in enumerate((cfg.window, 0)):
                p_j = jax.tree.map(lambda a: a[j], p2)
                x, k_j, v_j = sub_block(x, p_j, k2[j], v2[j], window)
                outs_k.append(k_j)
                outs_v.append(v_j)
            return x, (jnp.stack(outs_k), jnp.stack(outs_v))
        stacked = (_pair(params["blocks"], cfg.n_layers),
                   cache["k"].reshape(cfg.n_layers // 2, 2,
                                      *cache["k"].shape[1:]),
                   cache["v"].reshape(cfg.n_layers // 2, 2,
                                      *cache["v"].shape[1:]))
        n_steps = cfg.n_layers // 2
    else:
        def body(x, xs):
            p, k_l, v_l = xs
            x, k_l, v_l = sub_block(x, p, k_l, v_l, 0)
            return x, (k_l, v_l)
        stacked = (params["blocks"], cache["k"], cache["v"])
        n_steps = cfg.n_layers

    if cfg.scan_layers:
        x, (k_new, v_new) = jax.lax.scan(body, x, stacked)
    else:
        ks, vs = [], []
        for i in range(n_steps):
            xs_i = jax.tree.map(lambda a: a[i], stacked)
            x, (k_i, v_i) = body(x, xs_i)
            ks.append(k_i); vs.append(v_i)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    k_new = k_new.reshape(cache["k"].shape)
    v_new = v_new.reshape(cache["v"].shape)
    x = L.rmsnorm(x[:, 0], params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = L.unembed(x, head, cfg.final_logit_softcap, ctx)
    return logits, {"k": k_new, "v": v_new}


def _cache_write(cache_l, kv_t, pos):
    """cache_l: (B, T, KV, hd); kv_t: (B, KV, hd); pos: (B,). Batched
    scatter write — aliases in place under donation (the where/one-hot
    alternative materializes a full cache copy per layer) and stays local
    under a seq-sharded cache."""
    B = cache_l.shape[0]
    return cache_l.at[jnp.arange(B), pos].set(
        kv_t.astype(cache_l.dtype), mode="drop")


def lm_prefill(params, batch, cfg: ArchConfig,
               ctx: Optional[ModelContext] = None):
    """Prefill: full forward returning last-position logits. (The dry-run
    prefill cell measures this lowering; cache build-out is exercised by the
    serving runtime tests at small scale.)"""
    logits = lm_forward(params, batch, cfg, ctx)
    return logits[:, -1]
