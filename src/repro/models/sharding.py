"""Logical-axis sharding for the model zoo (GSPMD via sharding constraints).

Model code annotates tensors with *logical* axis names; a rule table maps
them to mesh axes. This is the MaxText/TPU-idiomatic megatron layout:

* batch        -> ("pod", "data")   pure DP across pods + data axis
* heads/d_ff/
  vocab/experts-> "model"           tensor/expert parallelism
* kv_seq       -> "model"           decode: sequence-sharded KV cache
                                    (distributed flash-decode)
* seq          -> None (or "model" under sequence parallelism)

The rules are swappable per experiment — the §Perf hillclimb iterates on
exactly this table.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def default_rules(multi_pod: bool = False, seq_parallel: bool = False,
                  decode_cache_axis: str = "model") -> dict:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": "model" if seq_parallel else None,
        # seq dim INSIDE attention/MLP (Megatron-SP keeps it unsharded
        # there; the residual boundary re-shards via RS/AG)
        "attn_seq": None,
        "kv_seq": decode_cache_axis,      # decode-time KV cache sharding
        "d_model": None,
        "heads": "model",
        "kv_heads": None,                 # GQA: few KV heads -> replicate
        "head_dim": None,
        "d_ff": "model",
        "vocab": "model",
        "experts": "model",
        "capacity": None,
        "layers": None,
        "ssm_heads": "model",
        "state": None,
        "conv": None,
        "xlstm_hd": None,      # mLSTM value-dim TP (perf lever)
    }


@dataclasses.dataclass
class ModelContext:
    """Execution context threaded through model code."""

    mesh: Optional[Mesh] = None
    rules: Optional[dict] = None
    attention_impl: str = "auto"      # reference | blocked | pallas | auto
    moe_impl: str = "auto"            # dense | ep | auto
    interpret: bool = True            # pallas interpret mode (CPU)
    blocked_threshold: int = 2048     # seq len above which "auto" -> blocked
    # cost probes: unroll inner scans so XLA cost analysis counts every
    # iteration (lax.scan bodies are otherwise counted once)
    unroll_scans: bool = False

    @property
    def distributed(self) -> bool:
        return self.mesh is not None and self.rules is not None

    def spec(self, *logical_axes: Optional[str]) -> P:
        """Resolve logical names to a PartitionSpec, de-duplicating mesh
        axes (earlier dims win — e.g. under sequence parallelism a
        (batch, seq, vocab) constraint keeps `model` on seq and sheds it
        from vocab)."""
        assert self.rules is not None
        used: set = set()
        resolved = []
        for ax in logical_axes:
            r = self.rules.get(ax) if ax is not None else None
            if r is None:
                resolved.append(None)
                continue
            axes = (r,) if isinstance(r, str) else tuple(r)
            axes = tuple(a for a in axes if a not in used)
            used.update(axes)
            resolved.append(axes[0] if len(axes) == 1
                            else (axes if axes else None))
        return P(*resolved)

    def shard(self, x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
        """Apply a sharding constraint by logical axis names (no-op when
        running without a mesh, e.g. single-device smoke tests)."""
        if not self.distributed:
            return x
        assert x.ndim == len(logical_axes), (x.shape, logical_axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical_axes)))

    def named_sharding(self, *logical_axes: Optional[str]) -> Optional[NamedSharding]:
        if not self.distributed:
            return None
        return NamedSharding(self.mesh, self.spec(*logical_axes))


CPU_CTX = ModelContext()
