"""zamba2-style hybrid LM: Mamba2 backbone + one *shared* transformer block
applied periodically (weights reused at every application — Zamba2's core
parameter-efficiency trick).

Layout: ``n_macro_blocks`` macro-blocks of ``mamba_per_block`` Mamba2 layers
each, the shared attention+MLP block applied after every macro-block, then
``tail_mamba_layers`` trailing Mamba2 layers.
zamba2-7b: 13 x 6 + shared-attn + 3 = 81 Mamba2 layers, 13 attention
applications (each application has its own KV cache at serve time).

Simplification vs the released model (documented in DESIGN.md): the shared
block consumes the residual stream directly (no concat-with-embedding input
or per-application LoRA deltas).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.mamba2 import (
    init_mamba2_params, init_mamba2_state, mamba2_mixer)
from repro.models.sharding import ModelContext
from repro.models.transformer import _cache_write, transformer_block


def init_hybrid_params(key, cfg: ArchConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k_embed, k_mamba, k_attn, k_head = jax.random.split(key, 4)
    n_mamba = cfg.n_layers
    mkeys = jax.random.split(k_mamba, n_mamba)
    per_layer = [init_mamba2_params(
        mk, D, state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
        expand=cfg.ssm_expand, conv_kernel=cfg.conv_kernel)
        for mk in mkeys]
    mamba_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    aks = iter(jax.random.split(k_attn, 8))
    def dn(shape, scale=0.02):
        return L.dense_init(next(aks), shape, scale)
    shared = {
        "attn_norm": jnp.zeros((D,)),
        "wq": dn((D, H * hd)),
        "wk": dn((D, KV * hd)),
        "wv": dn((D, KV * hd)),
        "wo": dn((H * hd, D)),
        "mlp_norm": jnp.zeros((D,)),
        "wi": dn((D, 2 * cfg.d_ff)),
        "wo_mlp": dn((cfg.d_ff, D)),
    }
    return {
        "embed": L.dense_init(k_embed, (V, D)),
        "mamba": mamba_stack,
        "shared_attn": shared,
        "final_norm": jnp.zeros((D,)),
        "lm_head": L.dense_init(k_head, (D, V)),
    }


def hybrid_param_specs(cfg: ArchConfig) -> dict:
    d_in_axes = ("layers", "d_model", None)
    return {
        "embed": ("vocab", "d_model"),
        "mamba": {
            "norm": ("layers", "d_model"),
            "in_proj": d_in_axes,
            "conv": ("layers", "conv", None),
            "A_log": ("layers", "ssm_heads"),
            "D": ("layers", "ssm_heads"),
            "dt_bias": ("layers", "ssm_heads"),
            "out_norm": ("layers", None),
            "out_proj": ("layers", None, "d_model"),
        },
        "shared_attn": {
            "attn_norm": ("d_model",),
            "wq": ("d_model", "heads"),
            "wk": ("d_model", "kv_heads"),
            "wv": ("d_model", "kv_heads"),
            "wo": ("heads", "d_model"),
            "mlp_norm": ("d_model",),
            "wi": ("d_model", "d_ff"),
            "wo_mlp": ("d_ff", "d_model"),
        },
        "final_norm": ("d_model",),
        "lm_head": ("d_model", "vocab"),
    }


def _split_stacks(params, cfg: ArchConfig):
    """(81, ...) mamba stack -> macro (13, 6, ...) + tail (3, ...)."""
    nb, per = cfg.n_macro_blocks, cfg.mamba_per_block
    head = nb * per
    macro = jax.tree.map(lambda a: a[:head].reshape(nb, per, *a.shape[1:]),
                         params["mamba"])
    tail = jax.tree.map(lambda a: a[head:], params["mamba"])
    return macro, tail


def hybrid_forward(params, batch, cfg: ArchConfig,
                   ctx: Optional[ModelContext] = None,
                   last_only: bool = False) -> jax.Array:
    ctx = ctx or ModelContext()
    x = L.embed(batch["tokens"], params["embed"].astype(jnp.bfloat16), ctx)
    B, S, D = x.shape
    positions = jnp.arange(S)
    macro, tail = _split_stacks(params, cfg)
    shared = params["shared_attn"]

    def mamba_body(x, p):
        out, _ = mamba2_mixer(x, p, cfg, ctx)
        return x + out, None

    def macro_body(x, p_macro):
        x, _ = jax.lax.scan(mamba_body, x, p_macro)
        x = transformer_block(x, shared, 0, cfg, ctx, positions)
        return x, None

    body = jax.checkpoint(macro_body) if cfg.remat else macro_body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, macro)
        x, _ = jax.lax.scan(mamba_body, x, tail)
    else:
        for i in range(cfg.n_macro_blocks):
            x, _ = body(x, jax.tree.map(lambda a: a[i], macro))
        for i in range(cfg.tail_mamba_layers):
            x, _ = mamba_body(x, jax.tree.map(lambda a: a[i], tail))
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm(x, params["final_norm"])
    logits = L.unembed(x, params["lm_head"], cfg.final_logit_softcap, ctx)
    if ctx.distributed:
        logits = ctx.shard(logits, "batch", "seq", "vocab")
    return logits


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def init_hybrid_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    st = init_mamba2_state(batch, cfg, cfg.d_model)
    n_mamba = cfg.n_layers
    mamba_states = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_mamba, *a.shape)).copy(), st)
    nb = cfg.n_macro_blocks
    kv_shape = (nb, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {
        "mamba": mamba_states,
        "k": jnp.zeros(kv_shape, dtype),
        "v": jnp.zeros(kv_shape, dtype),
    }


def hybrid_cache_specs() -> dict:
    kv = (None, "batch", "kv_seq", "kv_heads", "head_dim")
    return {
        "mamba": {
            "conv": (None, "batch", None, None),
            "ssm": (None, "batch", "ssm_heads", None, None),
        },
        "k": kv, "v": kv,
    }


def hybrid_decode_step(params, cache, tokens, pos, cfg: ArchConfig,
                       ctx: Optional[ModelContext] = None):
    ctx = ctx or ModelContext()
    B = tokens.shape[0]
    x = L.embed(tokens[:, None], params["embed"].astype(jnp.bfloat16), None)
    macro, tail = _split_stacks(params, cfg)
    shared = params["shared_attn"]
    nb, per = cfg.n_macro_blocks, cfg.mamba_per_block
    head = nb * per
    mstates_macro = jax.tree.map(
        lambda a: a[:head].reshape(nb, per, *a.shape[1:]), cache["mamba"])
    mstates_tail = jax.tree.map(lambda a: a[head:], cache["mamba"])

    def mamba_body(x, xs):
        p, st = xs
        out, st_new = mamba2_mixer(x, p, cfg, ctx, decode_state=st)
        return x + out, st_new

    def shared_attn_step(x, k_c, v_c):
        h = L.rmsnorm(x, shared["attn_norm"])
        q = (h @ shared["wq"].astype(h.dtype)).reshape(
            B, 1, cfg.n_heads, cfg.hd)
        k = (h @ shared["wk"].astype(h.dtype)).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        v = (h @ shared["wv"].astype(h.dtype)).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k = L.rope(k, pos[:, None], cfg.rope_theta)
        k_c = _cache_write(k_c, k[:, 0], pos)
        v_c = _cache_write(v_c, v[:, 0], pos)
        if ctx.distributed:
            k_c = ctx.shard(k_c, "batch", "kv_seq", "kv_heads", "head_dim")
            v_c = ctx.shard(v_c, "batch", "kv_seq", "kv_heads", "head_dim")
        a = L.decode_attention(q[:, 0], k_c, v_c, pos, ctx=ctx)
        x = x + (a.reshape(B, -1) @ shared["wo"].astype(x.dtype))[:, None]
        h = L.rmsnorm(x, shared["mlp_norm"])
        x = x + L.swiglu(h, shared["wi"], shared["wo_mlp"], ctx)
        return x, k_c, v_c

    def macro_body(x, xs):
        p_macro, st_macro, k_c, v_c = xs
        x, st_new = jax.lax.scan(mamba_body, x, (p_macro, st_macro))
        x, k_c, v_c = shared_attn_step(x, k_c, v_c)
        return x, (st_new, k_c, v_c)

    if cfg.scan_layers:
        x, (mstates_macro_new, k_new, v_new) = jax.lax.scan(
            macro_body, x, (macro, mstates_macro, cache["k"], cache["v"]))
        x, mstates_tail_new = jax.lax.scan(
            mamba_body, x, (tail, mstates_tail))
    else:
        outs = []
        for i in range(nb):
            xs_i = jax.tree.map(lambda a: a[i],
                                (macro, mstates_macro, cache["k"],
                                 cache["v"]))
            x, out_i = macro_body(x, xs_i)
            outs.append(out_i)
        mstates_macro_new, k_new, v_new = jax.tree.map(
            lambda *xs: jnp.stack(xs), *outs)
        touts = []
        for i in range(cfg.tail_mamba_layers):
            xs_i = jax.tree.map(lambda a: a[i], (tail, mstates_tail))
            x, st_i = mamba_body(x, xs_i)
            touts.append(st_i)
        mstates_tail_new = jax.tree.map(lambda *xs: jnp.stack(xs), *touts)

    mamba_new = jax.tree.map(
        lambda m, t: jnp.concatenate(
            [m.reshape(head, *m.shape[2:]), t], axis=0),
        mstates_macro_new, mstates_tail_new)
    x = L.rmsnorm(x[:, 0], params["final_norm"])
    logits = L.unembed(x, params["lm_head"], cfg.final_logit_softcap, ctx)
    return logits, {"mamba": mamba_new, "k": k_new, "v": v_new}
