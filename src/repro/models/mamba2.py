"""Mamba2 (State Space Duality) mixer — the SSM substrate for zamba2.

TPU adaptation notes (DESIGN.md §2): the CUDA Mamba2 kernel is a
warp-specialized chunked scan; on TPU the same math maps naturally onto the
MXU as the *chunked SSD dual form* — batched (chunk x chunk) GEMMs for the
intra-chunk part plus a short `lax.scan` over chunk states for the
inter-chunk recurrence. Heads shard over the ``model`` axis; the chunk
dimension keeps every GEMM MXU-aligned. The perf-critical inner recurrence
also exists as a Pallas kernel (:mod:`repro.kernels.ssm_scan`).

Layer structure (simplified Mamba2 block):
  in_proj: D -> [z (d_in), x (d_in), B (N), C (N), dt (nh)]
  causal depthwise conv(k=4) on [x|B|C]; SiLU
  y = SSD(x, dt, A, B, C)  (chunked scan, heads = d_in / head_dim)
  out = out_proj( rmsnorm(y) * silu(z) )
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm
from repro.models.sharding import ModelContext

CHUNK = 256


def init_mamba2_params(key, d_model: int, *, state: int, head_dim: int,
                       expand: int, conv_kernel: int) -> dict:
    d_in = expand * d_model
    nh = d_in // head_dim
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * state + nh
    return {
        "norm": jnp.zeros((d_model,), jnp.float32),
        "in_proj": dense_init(ks[0], (d_model, proj_out)),
        "conv": dense_init(ks[1], (conv_kernel, d_in + 2 * state), scale=0.1),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_in, d_model)),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 carry: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C). Returns (y, new_carry)
    where carry holds the last K-1 inputs (decode state)."""
    K = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_carry = xp[:, -(K - 1):, :]
    return y, new_carry


def ssd_chunked(x, dt, A, B, C, chunk: int = CHUNK,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x:  (Bb, S, nh, hd)    values
    dt: (Bb, S, nh)        softplus'd step sizes (>0)
    A:  (nh,)              negative decay rates
    B:  (Bb, S, N)         input maps   (single group, shared across heads)
    C:  (Bb, S, N)         output maps
    Returns (y (Bb,S,nh,hd), final_state (Bb,nh,hd,N)).
    """
    Bb, S, nh, hd = x.shape
    N = B.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    xc = x.reshape(Bb, nc, chunk, nh, hd)
    dtc = dt.reshape(Bb, nc, chunk, nh)
    Bc = B.reshape(Bb, nc, chunk, N)
    Cc = C.reshape(Bb, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                    # (Bb,nc,Q,nh) <= 0
    cum = jnp.cumsum(dA, axis=2)                         # within-chunk cumsum
    total = cum[:, :, -1]                                # (Bb,nc,nh)

    # ---- intra-chunk (dual / attention-like form) ----
    # L[i,j] = exp(cum_i - cum_j) for j <= i else 0.
    # NB: mask the exponent BEFORE exp — masked (j > i) entries have
    # positive exponents that overflow and poison gradients through where.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (Bb,nc,Q,Q,nh)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(mask, diff, -1e30))
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)         # (Bb,nc,Q,Q)
    M = scores[..., None] * L                              # (Bb,nc,Q,Q,nh)
    xdt = xc * dtc[..., None]                              # (Bb,nc,Q,nh,hd)
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", M, xdt)

    # ---- chunk states ----
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)     # (Bb,nc,Q,nh)
    states = jnp.einsum("bcjn,bcjh,bcjhd->bchdn",
                        Bc, dtc * decay_to_end, xc)        # (Bb,nc,nh,hd,N)

    # ---- inter-chunk recurrence over nc ----
    s0 = (jnp.zeros((Bb, nh, hd, N), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def step(s, inp):
        st, tot = inp
        s_new = s * jnp.exp(tot)[:, :, None, None] + st
        return s_new, s

    (final, prev_states) = jax.lax.scan(
        step, s0,
        (states.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
         total.transpose(1, 0, 2)))
    prev = prev_states.transpose(1, 0, 2, 3, 4)            # state BEFORE chunk c

    y_inter = jnp.einsum("bcin,bchdn,bcih->bcihd",
                         Cc, prev.astype(Cc.dtype),
                         jnp.exp(cum).astype(Cc.dtype))
    y = (y_intra + y_inter).reshape(Bb, S, nh, hd)
    return y, final


def ssd_decode_step(x, dt, A, B, C, state):
    """Single-token SSD update.
    x: (Bb, nh, hd); dt: (Bb, nh); B,C: (Bb, N); state: (Bb, nh, hd, N).
    Returns (y (Bb,nh,hd), new_state)."""
    dA = jnp.exp(dt * A[None, :])                          # (Bb, nh)
    upd = jnp.einsum("bn,bh,bhd->bhdn", B, dt, x)          # dt broadcast: (Bb,nh)
    new_state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhdn->bhd", C, new_state)
    return y, new_state


def mamba2_mixer(x, params, cfg, ctx: Optional[ModelContext] = None,
                 decode_state: Optional[dict] = None):
    """Full Mamba2 block. x: (Bb, S, D).
    decode_state: None (train/prefill) or {"conv": (Bb,K-1,Cc), "ssm": ...}
    Returns (y, new_decode_state)."""
    Bb, S, D = x.shape
    d_in = cfg.ssm_expand * D
    hd = cfg.ssm_head_dim
    nh = d_in // hd
    N = cfg.ssm_state
    h = rmsnorm(x, params["norm"])
    proj = h @ params["in_proj"].astype(h.dtype)
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    carry = decode_state["conv"] if decode_state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv"].astype(h.dtype),
                                      carry)
    conv_out = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(Bb, S, nh, hd)
    if ctx is not None:
        xh = ctx.shard(xh, "batch", "seq", "ssm_heads", "head_dim")
    if decode_state is None:
        y, final = ssd_chunked(xh.astype(jnp.float32), dt, A,
                               Bm.astype(jnp.float32),
                               Cm.astype(jnp.float32),
                               chunk=min(CHUNK, S))
        new_state = {"conv": new_conv, "ssm": final}
    else:
        y1, new_ssm = ssd_decode_step(
            xh[:, 0].astype(jnp.float32), dt[:, 0], A,
            Bm[:, 0].astype(jnp.float32), Cm[:, 0].astype(jnp.float32),
            decode_state["ssm"])
        y = y1[:, None]
        new_state = {"conv": new_conv, "ssm": new_ssm}
    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(Bb, S, d_in).astype(x.dtype)
    y = rmsnorm(y, params["out_norm"]) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(y.dtype)
    return out, new_state


def init_mamba2_state(batch: int, cfg, d_model: int) -> dict:
    d_in = cfg.ssm_expand * d_model
    nh = d_in // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1,
                           d_in + 2 * cfg.ssm_state), jnp.bfloat16),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
    }
