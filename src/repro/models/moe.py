"""Mixture-of-Experts layer with two implementations.

* ``dense`` — every expert computed for every token, combined by gate
  weights. O(E) FLOPs: only for reduced smoke configs and as the numerical
  oracle for the EP path.
* ``ep`` — expert parallelism over the ``model`` mesh axis via
  ``shard_map`` + fixed-capacity ``all_to_all`` (the TPU-idiomatic dispatch:
  sort-by-expert, scatter into per-expert capacity slots, A2A to expert
  shards, batched GEMMs, A2A back, weighted combine). Tokens over capacity
  are dropped (standard Switch-style; capacity_factor controls slack) and
  their residual passes through untouched.

Weights layout (stacked per layer by the caller):
  router: (D, E)
  wi:     (E, D, 2F)   fused gate+up (SwiGLU experts)
  wo:     (E, F, D)
  shared experts (n_s >= 1, e.g. Moonlight): wi_s: (D, 2*F*n_s), wo_s: (F*n_s, D)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import swiglu
from repro.models.sharding import ModelContext


def router_probs(x: jax.Array, w_router: jax.Array, k: int):
    """Top-k routing with renormalized softmax gates (fp32 router)."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                            # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def load_balancing_loss(probs: jax.Array, idx: jax.Array, n_experts: int):
    """Switch-transformer aux loss: E * sum_e f_e * p_e."""
    counts = jnp.zeros((n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(idx.size, 1)
    p = probs.mean(axis=0)
    return n_experts * jnp.sum(f * p)


def _expert_ffn(xs: jax.Array, wi: jax.Array, wo: jax.Array) -> jax.Array:
    """xs: (E, C, D), wi: (E, D, 2F), wo: (E, F, D) -> (E, C, D)."""
    h = jnp.einsum("ecd,edf->ecf", xs, wi.astype(xs.dtype))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(xs.dtype))


# --------------------------------------------------------------------------
# dense oracle
# --------------------------------------------------------------------------


def moe_dense(x: jax.Array, params: dict, k: int,
              ctx: Optional[ModelContext] = None) -> jax.Array:
    """x: (B, S, D). Computes all experts densely; exact combine."""
    B, S, D = x.shape
    E = params["router"].shape[1]
    xt = x.reshape(B * S, D)
    gates, idx, _ = router_probs(xt, params["router"], k)
    # (E, T, D) all-experts compute
    h = jnp.einsum("td,edf->etf", xt, params["wi"].astype(xt.dtype))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("etf,efd->etd", h,
                    params["wo"].astype(xt.dtype))                 # (E, T, D)
    onehot = jax.nn.one_hot(idx, E, dtype=ye.dtype)                # (T, k, E)
    combine = jnp.einsum("tke,tk->te", onehot, gates.astype(ye.dtype))
    out = jnp.einsum("te,etd->td", combine, ye)
    return out.reshape(B, S, D)


# --------------------------------------------------------------------------
# expert-parallel shard_map path
# --------------------------------------------------------------------------


def _ep_local(xt_full, router, wi, wo, *, k: int, n_experts: int,
              capacity_factor: float, model_axis: str, n_model: int,
              tokens_replicated: bool):
    """Per-device body. xt_full: (T_full, D) local tokens; wi/wo hold
    E_loc experts; router replicated.

    When the batch shards over data only (megatron TP), tokens are
    REPLICATED across the EP/model axis: each EP rank dispatches only ITS
    1/n_model token slice (otherwise every rank ships and computes the same
    tokens and the expert GEMMs run n_model x duplicated) and outputs are
    re-assembled with one all_gather. Under FSDP (batch sharded over model
    too) every rank already owns distinct tokens — no slice/gather."""
    T_full = xt_full.shape[0]
    E = n_experts
    E_loc = wi.shape[0]
    if tokens_replicated and n_model > 1 and T_full % n_model == 0:
        T = T_full // n_model
        rank = jax.lax.axis_index(model_axis)
        xt = jax.lax.dynamic_slice_in_dim(xt_full, rank * T, T, axis=0)
    else:
        T = T_full
        xt = xt_full
    gates, idx, _ = router_probs(xt, router, k)

    # ---- build fixed-capacity send buffer (E, C, D) ----
    C = max(1, int(T * k * capacity_factor) // E)
    flat_e = idx.reshape(-1)                                 # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position of each element within its expert segment
    start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - start[se]
    keep = pos < C
    slot_e = jnp.where(keep, se, 0)
    slot_c = jnp.where(keep, pos, 0)
    send = jnp.zeros((E, C, xt.shape[1]), xt.dtype)
    send = send.at[slot_e, slot_c].add(
        jnp.where(keep[:, None], xt[st], 0.0).astype(xt.dtype))

    # ---- A2A to expert shards (tiled, split==concat: self-transpose, so
    # the VJP is the same collective — no cotangent-layout ambiguity) ----
    # out[j*E_loc + e_loc] = device j's buffer for MY local experts
    out = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0,
                             tiled=True)
    recv = out.reshape(n_model, E_loc, C, -1).transpose(1, 0, 2, 3)
    recv = recv.reshape(E_loc, n_model * C, -1)

    # ---- expert FFNs ----
    y = _expert_ffn(recv, wi, wo)

    # ---- A2A back: rows regrouped so chunk j = outputs for device j ----
    y = y.reshape(E_loc, n_model, C, -1).transpose(1, 0, 2, 3)
    back = jax.lax.all_to_all(y.reshape(E, C, -1), model_axis,
                              split_axis=0, concat_axis=0, tiled=True)
    # back[e] now holds the processed send[e] (e = owner*E_loc + e_loc)

    # ---- weighted combine ----
    contrib = back[slot_e, slot_c]                           # (T*k, D)
    contrib = jnp.where(keep[:, None], contrib, 0.0)
    out = jnp.zeros_like(xt, dtype=jnp.float32)
    out = out.at[st].add(contrib.astype(jnp.float32) * sg[:, None])
    out = out.astype(xt_full.dtype)
    if T != T_full:
        # gather every rank's token slice back into the full (replicated)
        # activation: (n_model, T, D) -> (T_full, D), slices contiguous
        out = jax.lax.all_gather(out, model_axis, axis=0).reshape(
            T_full, -1)
    return out


def moe_ep(x: jax.Array, params: dict, k: int, n_experts: int,
           capacity_factor: float, ctx: ModelContext) -> jax.Array:
    """Expert-parallel MoE via shard_map over the full mesh."""
    assert ctx.distributed, "EP MoE requires a mesh"
    mesh = ctx.mesh
    n_model = mesh.shape["model"]
    B, S, D = x.shape
    batch_axes = ctx.rules["batch"]
    replicated = "model" not in ((batch_axes,) if isinstance(
        batch_axes, str) else (batch_axes or ()))
    x_spec = P(batch_axes, None, None)

    def body(xb, router, wi, wo):
        T_loc = xb.shape[0] * xb.shape[1]
        out = _ep_local(xb.reshape(T_loc, D), router, wi, wo,
                        k=k, n_experts=n_experts,
                        capacity_factor=capacity_factor,
                        model_axis="model", n_model=n_model,
                        tokens_replicated=replicated)
        return out.reshape(xb.shape)

    from repro.compat import shard_map
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=x_spec,
        check_vma=False)
    return fn(x, params["router"], params["wi"], params["wo"])


def moe_block(x: jax.Array, params: dict, *, k: int, n_experts: int,
              n_shared: int, capacity_factor: float,
              ctx: Optional[ModelContext] = None) -> jax.Array:
    """Routed experts + optional shared experts (Moonlight-style)."""
    impl = ctx.moe_impl if ctx is not None else "dense"
    if impl == "auto":
        impl = "ep" if (ctx is not None and ctx.distributed) else "dense"
    if impl == "ep":
        y = moe_ep(x, params, k, n_experts, capacity_factor, ctx)
    else:
        y = moe_dense(x, params, k, ctx)
    if n_shared > 0:
        y = y + swiglu(x, params["wi_s"], params["wo_s"], ctx)
    return y
