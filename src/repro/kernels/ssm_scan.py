"""Fused SSD inter-chunk state scan Pallas TPU kernel (Mamba2 / mLSTM).

The chunked SSD dual form (repro.models.mamba2) splits into parallel
intra-chunk GEMMs (MXU-friendly, left in XLA) and a *sequential* inter-chunk
state recurrence. In XLA the recurrence materializes every per-chunk prev
state (B, nc, nh, hd, N) to HBM; this kernel fuses the recurrence with the
``y_inter`` contraction so the running state (hd, N) stays resident in VMEM
and only (Q, hd) output tiles stream out.

Grid: (B * nh, nc) — chunks sequential; state in VMEM scratch.

  state_c   = state_{c-1} * exp(total_c) + states_c
  y_inter_c = (C_c @ state_{c-1}^T) * exp(cum_c)        # (Q, hd)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_scan_kernel(states_ref, total_ref, c_ref, cum_ref,
                     y_ref, final_ref, s_scr, *, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    prev = s_scr[...]                                   # (hd, N) fp32
    C = c_ref[0, 0].astype(jnp.float32)                 # (Q, N)
    cum = cum_ref[0, 0].astype(jnp.float32)             # (Q,)
    # y_inter = (C @ prev^T) * exp(cum)[:, None]
    y = (C @ prev.T) * jnp.exp(cum)[:, None]            # (Q, hd)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    s_scr[...] = prev * jnp.exp(total_ref[0, 0]) + \
        states_ref[0, 0].astype(jnp.float32)

    @pl.when(ic == nc - 1)
    def _final():
        final_ref[0] = s_scr[...]


def ssd_state_scan(states, totals, C, cum, *, interpret=True):
    """states: (B, nc, nh, hd, N); totals: (B, nc, nh);
    C: (B, nc, Q, N); cum: (B, nc, Q, nh).
    Returns (y_inter (B, nc, Q, nh, hd), final_state (B, nh, hd, N))."""
    B, nc, nh, hd, N = states.shape
    Q = C.shape[2]
    # flatten (B, nh) into the leading grid dim; per-head views
    st = states.transpose(0, 2, 1, 3, 4).reshape(B * nh, nc, hd, N)
    tot = totals.transpose(0, 2, 1).reshape(B * nh, nc)
    # C is shared across heads: index_map picks the right (b, c) tile
    cumh = cum.transpose(0, 3, 1, 2).reshape(B * nh, nc, Q)

    kernel = functools.partial(_ssd_scan_kernel, nc=nc)
    y, final = pl.pallas_call(
        kernel,
        grid=(B * nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, hd, N), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1), lambda bh, ic: (bh, ic)),
            pl.BlockSpec((1, 1, Q, N), lambda bh, ic, nh=nh: (bh // nh, ic, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda bh, ic: (bh, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, hd, N), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nh, nc, Q, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * nh, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(st, tot, C, cumh)
    y = y.reshape(B, nh, nc, Q, hd).transpose(0, 2, 3, 1, 4)
    final = final.reshape(B, nh, hd, N)
    return y, final
