"""Flash-attention forward Pallas TPU kernel.

Grid: (batch*heads, q_blocks, kv_blocks) — the last grid dimension is
sequential on TPU, so the online-softmax running state (m, l, acc) lives in
VMEM scratch and is revisited across kv blocks. Fully-masked causal blocks
are skipped with ``pl.when`` (the FLOPs saving XLA's scan-based fallback
cannot express).

Supports GQA (KV heads indexed via ``head // group``), sliding windows
(gemma2 local layers) and logit softcapping. TPU alignment: block_q /
block_k should be multiples of 128 and head_dim a multiple of 128 on real
hardware; interpret mode (CPU validation) has no such restriction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  logit_cap: float, nk: int, block_q: int, block_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qp = qpos_ref[...]                                   # (block_q,)
    kp = kpos_ref[...]                                   # (block_k,)

    # block-level visibility: skip blocks that are entirely masked
    q_max, q_min = qp[-1], qp[0]
    k_min, k_max = kp[0], kp[-1]
    visible = jnp.bool_(True)
    if causal:
        visible &= k_min <= q_max
    if window > 0:
        visible &= k_max > q_min - window

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (block_q, hd)
        k = k_ref[0].astype(jnp.float32)                # (block_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                      # (block_q, block_k)
        if logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        ok = jnp.ones_like(s, dtype=bool)
        if causal:
            ok &= qp[:, None] >= kp[None, :]
        if window > 0:
            ok &= (qp[:, None] - kp[None, :]) < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                        logit_cap=0.0, scale=None, block_q=128, block_k=128,
                        interpret=True):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd); positions int32 (S,)/(T,).
    Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = (hd ** -0.5) if scale is None else scale
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    nq, nk = S // block_q, T // block_k

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, nk=nk, block_q=block_q, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((block_q,), lambda bh, iq, ik: (iq,)),
            pl.BlockSpec((block_k,), lambda bh, iq, ik: (ik,)),
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, iq, ik, G=G: (bh // G, ik, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, iq, ik, G=G: (bh // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos.astype(jnp.int32), k_pos.astype(jnp.int32), qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
