"""Pure-jnp oracles for every Pallas kernel (the allclose targets for
tests/test_kernels.py shape/dtype sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as _L


def flash_attention_ref(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                        logit_cap=0.0, scale=None):
    return _L.attention_reference(q, k, v, q_pos, k_pos, causal=causal,
                                  window=window, logit_cap=logit_cap,
                                  scale=scale)


def flash_decode_ref(q, k_cache, v_cache, pos, *, window=0, logit_cap=0.0,
                     scale=None):
    return _L.decode_attention(q, k_cache, v_cache, pos, window=window,
                               logit_cap=logit_cap, scale=scale)


def ssd_state_scan_ref(states, totals, C, cum):
    """Inter-chunk recurrence + y_inter, reference implementation.
    states: (B,nc,nh,hd,N); totals: (B,nc,nh); C: (B,nc,Q,N);
    cum: (B,nc,Q,nh)."""
    B, nc, nh, hd, N = states.shape

    def step(s, inp):
        st, tot = inp
        s_new = s * jnp.exp(tot)[:, :, None, None] + st
        return s_new, s

    final, prev = jax.lax.scan(
        step, jnp.zeros((B, nh, hd, N), jnp.float32),
        (states.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
         totals.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)
    # y[b,c,i,h,d] = sum_n C[b,c,i,n] * prev[b,c,h,d,n] * exp(cum[b,c,i,h])
    y = jnp.einsum("bcin,bchdn,bcih->bcihd",
                   C.astype(jnp.float32), prev,
                   jnp.exp(cum).astype(jnp.float32))
    return y, final


def rmsnorm_ref(x, w, eps: float = 1e-6):
    return _L.rmsnorm(x, w, eps)
