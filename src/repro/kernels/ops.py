"""Jit'd public wrappers for the Pallas kernels.

``interpret=True`` (default here) executes the kernel bodies in Python on
CPU — the validation mode this container supports. On real TPU pass
``interpret=False`` (and see the per-kernel alignment notes: block sizes
multiples of 128, head_dim padded to 128).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention import flash_decode as _flash_decode
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.ssm_scan import ssd_state_scan as _ssd_state_scan


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_cap", "scale", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                    logit_cap=0.0, scale=None, block_q=128, block_k=128,
                    interpret=True):
    return flash_attention_fwd(
        q, k, v, q_pos, k_pos, causal=causal, window=window,
        logit_cap=logit_cap, scale=scale, block_q=block_q, block_k=block_k,
        interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("window", "logit_cap", "scale", "block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, pos, *, window=0, logit_cap=0.0,
                 scale=None, block_k=128, interpret=True):
    return _flash_decode(q, k_cache, v_cache, pos, window=window,
                         logit_cap=logit_cap, scale=scale, block_k=block_k,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_state_scan(states, totals, C, cum, *, interpret=True):
    return _ssd_state_scan(states, totals, C, cum, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps=1e-6, block_rows=256, interpret=True):
    return _rmsnorm(x, w, eps=eps, block_rows=block_rows,
                    interpret=interpret)
