"""Flash-decode Pallas TPU kernel: one query token per sequence against a
long KV cache, online-softmax over KV blocks.

Grid: (B * KV_heads, kv_blocks) — kv_blocks sequential, running (m, l, acc)
for the G grouped query heads in VMEM scratch. The per-sequence cache
length arrives via scalar prefetch so masked tail blocks are skipped.
On a real pod this kernel runs per cache shard under shard_map (the
cross-shard log-sum-exp combine is a tiny psum); the dry-run path uses the
GSPMD grouped-einsum equivalent in :mod:`repro.models.layers`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, window: int, logit_cap: float,
                   nk: int, block_k: int, n_kv: int):
    bk = pl.program_id(0)
    ik = pl.program_id(1)
    b = bk // n_kv

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]
    base = ik * block_k

    @pl.when(base <= pos)                      # skip blocks past the cache
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (G, hd)
        k = k_ref[0].astype(jnp.float32)                # (block_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                     # (G, block_k)
        if logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        t_idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        ok = t_idx <= pos
        if window > 0:
            ok &= (pos - t_idx) < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, pos, *, window=0, logit_cap=0.0,
                 scale=None, block_k=128, interpret=True):
    """q: (B,H,hd); caches: (B,T,KV,hd); pos: (B,). Returns (B,H,hd)."""
    B, H, hd = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = (hd ** -0.5) if scale is None else scale
    block_k = min(block_k, T)
    assert T % block_k == 0
    nk = T // block_k

    qg = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, logit_cap=logit_cap,
        nk=nk, block_k=block_k, n_kv=KV)

    out = pl.pallas_call(
        kernel,
        grid=(B * KV, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),      # pos: scalar prefetch
            pl.BlockSpec((1, G, hd), lambda bk, ik: (bk, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bk, ik: (bk, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bk, ik: (bk, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bk, ik: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(pos.astype(jnp.int32), qg, kf, vf)
    return out.reshape(B, H, hd)
