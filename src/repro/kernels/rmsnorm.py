"""Fused RMSNorm Pallas TPU kernel: one HBM pass (read x, write y) instead
of XLA's separate square/mean/rsqrt/mul chain; fp32 statistics on-chip.

Grid: (rows / block_rows,) with the full feature dim resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + w_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret=True):
    """x: (..., D); w: (D,). Fused RMSNorm."""
    shape = x.shape
    D = shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xf.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, w)
    if pad:
        out = out[:R]
    return out.reshape(shape)
