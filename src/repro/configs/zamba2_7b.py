"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified]. 81 Mamba2 layers = 13 macro-blocks x 6 +
3 tail; the shared attention block is applied after every macro-block
(13 applications, one weight set)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_kernel=4,
    mamba_per_block=6, n_macro_blocks=13, tail_mamba_layers=3,
    microbatches=8,
)

SMOKE_CONFIG = ArchConfig(
    name="zamba2-7b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=128,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, conv_kernel=4,
    mamba_per_block=2, n_macro_blocks=2, tail_mamba_layers=1,
    remat=False,
)
