"""pixtral-12b [vlm]: pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified]. The ViT is a stub:
input_specs() provides precomputed patch embeddings occupying the first
``num_patches`` positions; the decoder is mistral-nemo-style (head_dim
128)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    frontend="vision_stub", num_patches=1024,
    microbatches=8,
)

SMOKE_CONFIG = ArchConfig(
    name="pixtral-12b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=128, head_dim=32,
    frontend="vision_stub", num_patches=8,
    remat=False,
)
