"""musicgen-large [audio]: decoder-only over EnCodec tokens
[arXiv:2306.05284; hf]. The EnCodec frontend is a stub: input_specs()
provides precomputed frame embeddings (B, S, d_model)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    frontend="audio_stub", microbatches=4,
)

SMOKE_CONFIG = ArchConfig(
    name="musicgen-large-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=128, frontend="audio_stub",
    remat=False,
)
