"""qwen3-moe-30b-a3b [moe]: 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
d_ff=768 per expert; head_dim=128 (projected q: 2048 -> 4096)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128,
    n_experts=128, experts_per_token=8, n_shared_experts=0,
    microbatches=4,
)

SMOKE_CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab_size=128, head_dim=32,
    n_experts=8, experts_per_token=2, n_shared_experts=0,
    remat=False,
)
