"""granite-3-8b [dense]: GQA [hf:ibm-granite/granite-3.0-2b-base; hf].
vocab 49155 padded to 49280 (multiple of 128) for clean TP vocab sharding
(the 125 pad rows are never produced by the tokenizer stub)."""

from repro.configs.base import ArchConfig

VOCAB_RAW = 49155

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab_size=49280, microbatches=8,
)

SMOKE_CONFIG = ArchConfig(
    name="granite-3-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=128, remat=False,
)
