"""granite-34b [dense]: llama-arch code model, MQA (kv=1)
[arXiv:2405.04324; hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, microbatches=16,
)

SMOKE_CONFIG = ArchConfig(
    name="granite-34b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab_size=128, remat=False,
)
