"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 experts top-6 + 2 shared
experts [hf:moonshotai/Moonlight-16B-A3B; hf]. d_ff=1408 per expert."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163840,
    n_experts=64, experts_per_token=6, n_shared_experts=2,
    microbatches=4,
)

SMOKE_CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab_size=128,
    n_experts=8, experts_per_token=2, n_shared_experts=2,
    remat=False,
)
