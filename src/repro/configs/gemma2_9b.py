"""gemma2-9b [dense]: local+global alternating attention, logit softcaps,
post-norms [arXiv:2408.00118; hf]. head_dim=256 (projected)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab_size=256000, head_dim=256,
    attn_pattern="local_global", window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_norms=True, microbatches=4,
)

SMOKE_CONFIG = ArchConfig(
    name="gemma2-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=128, head_dim=32,
    attn_pattern="local_global", window=16,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_norms=True, remat=False,
)
