"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
48 blocks, every 8th is sLSTM (6 sLSTM : 42 mLSTM); d_ff=0 — blocks carry
their own 2x up/down projections. Heads (4) are not TP-shardable, so
training shards batch over (data x model) instead (pure 256-way DP)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_every=8, microbatches=1, scan_layers=False,
)

SMOKE_CONFIG = ArchConfig(
    name="xlstm-1.3b-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab_size=128, slstm_every=2, scan_layers=False,
    remat=False,
)
