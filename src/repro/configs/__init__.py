"""Config registry: ``get_config(name)`` / ``get_smoke_config(name)``."""

from repro.configs import (
    gemma2_9b, granite_3_8b, granite_8b, granite_34b, moonshot_v1_16b,
    musicgen_large, pixtral_12b, qwen3_moe_30b, xlstm_1_3b, zamba2_7b)
from repro.configs.base import ArchConfig
from repro.configs.shapes import LONG_CAPABLE, SHAPES, Shape, shapes_for

_MODULES = {
    "musicgen-large": musicgen_large,
    "granite-8b": granite_8b,
    "granite-34b": granite_34b,
    "gemma2-9b": gemma2_9b,
    "granite-3-8b": granite_3_8b,
    "zamba2-7b": zamba2_7b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b,
    "xlstm-1.3b": xlstm_1_3b,
    "pixtral-12b": pixtral_12b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    return _MODULES[name].CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _MODULES[name].SMOKE_CONFIG
