"""Architecture configuration schema.

One frozen dataclass drives every model family in the zoo (dense / MoE /
hybrid-SSM / xLSTM / audio / VLM). Each assigned architecture gets a module
in this package exporting ``CONFIG`` (full size, dry-run only) and
``SMOKE_CONFIG`` (reduced same-family config for CPU tests).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // n_heads
    # --- attention variants ---
    attn_pattern: str = "global"     # "global" | "local_global" (gemma2)
    window: int = 4096               # sliding window for local layers
    attn_logit_softcap: float = 0.0  # gemma2: 50.0
    final_logit_softcap: float = 0.0 # gemma2: 30.0
    post_norms: bool = False         # gemma2: post-attn/post-ffn RMSNorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid (zamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    mamba_per_block: int = 0         # zamba2: mamba layers per macro-block
    n_macro_blocks: int = 0          # zamba2: shared-attn applications
    tail_mamba_layers: int = 0
    # --- xLSTM ---
    slstm_every: int = 0             # every k-th block is sLSTM (0 = none)
    # --- modality frontends (stubs; see DESIGN.md) ---
    frontend: str = "none"           # none | audio_stub | vision_stub
    num_patches: int = 0             # vlm: image-prefix length
    # --- training / memory knobs (per-arch, tuned for 16 GiB v5e) ---
    microbatches: int = 1
    remat: bool = True
    remat_policy: str = "full"       # full | dots
    scan_layers: bool = True
    dtype: str = "bfloat16"
    # --- implementation switches ---
    attention_impl: str = "auto"     # auto | reference | blocked | pallas
    moe_impl: str = "auto"           # auto | dense | ep
    # --- serving ---
    max_cache_len: int = 0           # set by shape at serve time

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Total parameters (for 6ND model-FLOPs accounting)."""
        return sum(x for x, _ in self._param_terms())

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-to experts)."""
        return sum(a for _, a in self._param_terms())

    def _param_terms(self) -> list[tuple[int, int]]:
        """(total, active) parameter pairs per component."""
        D, V, ff = self.d_model, self.vocab_size, self.d_ff
        hd = self.hd
        terms: list[tuple[int, int]] = []
        emb = V * D
        terms.append((emb, emb))
        if not self.tie_embeddings:
            terms.append((emb, emb))
        if self.family in ("dense", "moe", "audio", "vlm"):
            attn = D * (self.n_heads * hd) + 2 * D * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * D
            per_layer = attn + 2 * D  # norms
            if self.is_moe:
                router = D * self.n_experts
                expert = 3 * D * ff
                moe_total = router + self.n_experts * expert \
                    + self.n_shared_experts * expert
                moe_active = router + self.experts_per_token * expert \
                    + self.n_shared_experts * expert
                terms.append((self.n_layers * (per_layer + moe_total),
                              self.n_layers * (per_layer + moe_active)))
            else:
                mlp = 3 * D * ff
                t = self.n_layers * (per_layer + mlp)
                terms.append((t, t))
        elif self.family == "hybrid":   # zamba2
            d_in = self.ssm_expand * D
            nh = d_in // self.ssm_head_dim
            mamba = (D * (2 * d_in + 2 * self.ssm_state + nh)
                     + self.conv_kernel * (d_in + 2 * self.ssm_state)
                     + d_in * D + 2 * D)
            n_mamba = self.n_layers
            shared_attn = (D * (self.n_heads * hd)
                           + 2 * D * (self.n_kv_heads * hd)
                           + (self.n_heads * hd) * D + 3 * D * self.d_ff
                           + 2 * D)
            t = n_mamba * mamba + shared_attn   # shared weights counted once
            a = n_mamba * mamba + self.n_macro_blocks * shared_attn
            terms.append((t, min(a, a)))
        elif self.family == "ssm":      # xlstm
            d_in = 2 * D
            per_m = D * (3 * d_in) + d_in * D + 2 * D \
                + d_in * (3 * self.n_heads)   # qkv-ish gates
            t = self.n_layers * per_m
            terms.append((t, t))
        return terms
