"""Assigned input shapes (one set shared by all 10 LM-family archs)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}

#: long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
#: (see DESIGN.md §Arch-applicability for the per-arch skip rationale).
LONG_CAPABLE = frozenset({"zamba2-7b", "xlstm-1.3b"})


def shapes_for(arch_name: str) -> list[Shape]:
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if arch_name in LONG_CAPABLE:
        out.append(SHAPES["long_500k"])
    return out
