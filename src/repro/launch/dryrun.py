import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on 512 placeholder host devices, proving the distribution config
is coherent, and extract the roofline terms from the compiled artifact.

MUST be imported/run before anything else initializes jax (the XLA_FLAGS
line above is therefore the first statement in the module).

Per cell this records into a resumable JSON artifact:
  * memory_analysis(): per-device argument/temp/output bytes (fits-check)
  * cost_analysis(): per-device HLO FLOPs + bytes accessed
  * collective bytes by op type, parsed from the post-SPMD HLO text
  * the three roofline terms (v5e: 197 TF/s bf16, 819 GB/s HBM,
    50 GB/s/link ICI), the dominant term, MODEL_FLOPS and the
    useful-compute ratio.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k --mesh single --out results/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, shapes_for
from repro.configs.shapes import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import assemble, opt_state_shardings
from repro.launch.steps import (
    build_prefill_step, build_serve_step, build_train_step)
from repro.models.zoo import build_model
from repro.optim.adamw import AdamW

# ---- TPU v5e hardware constants (roofline) ---------------------------------
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# weight: bytes moved per result byte on a ring (all-reduce moves ~2x)
_COLLECTIVE_WEIGHT = {"all-reduce": 2.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _bytes_of_type(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective in post-SPMD HLO."""
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        out[op]["count"] += 1
        out[op]["bytes"] += _bytes_of_type(type_str)
    return out


def collective_seconds(coll: dict) -> float:
    t = 0.0
    for op, rec in coll.items():
        w = _COLLECTIVE_WEIGHT.get(op, 1.0)
        t += w * rec["bytes"] / LINK_BW
    return t


def model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), N = active params."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch          # decode: per emitted token


# ---------------------------------------------------------------------------
# Cost probes: XLA's cost analysis counts while-loop (lax.scan) bodies ONCE,
# not x trip-count, so the full (scan-based) compile wildly under-reports
# FLOPs/bytes/collectives. We therefore compile each cell twice more in an
# *unrolled* configuration at 1 and 2 "scan units" (a unit = one layer, one
# local/global pair, or one zamba macro-block), fit cost = fixed +
# per_unit * U exactly, and scale to the full depth x microbatches.
# The scanned compile is still what proves the cell lowers/fits (memory
# analysis is allocation-based and correct under scan).
# ---------------------------------------------------------------------------

import dataclasses as _dc

# analytic AdamW update terms (per parameter, per device after sharding):
# m/v/master read+write fp32 (24B) + grad read fp32 (4B) + casts ~= 40B,
# ~12 flops. Tiny vs the matmul terms; folded in analytically because the
# probe measures value_and_grad only (so microbatch scaling stays exact).
_OPT_BYTES_PER_PARAM = 40.0
_OPT_FLOPS_PER_PARAM = 12.0


def analytic_memory_bytes(cfg, kind: str, batch: int, seq: int,
                          mesh) -> float:
    """First-principles per-device HBM-traffic floor, assuming the Pallas
    attention/SSM kernels (no score materialization) and TPU-grade fusion:

      train:   M * L * [4 * P_layer(bf16)/dev + 10 * resid] + head + opt
      prefill: L * [P_layer(bf16)/TP + 6 * resid] + cache write
      decode:  all params once + full cache read/write + small vectors

    resid = one (B_mb, S, D) bf16 pass per device. Reported alongside the
    measured (XLA-fallback attention) bytes so both bounds are visible.
    """
    dev = mesh.size
    tp = mesh.shape["model"]
    dp = dev // tp
    P = cfg.param_count() * 2.0                     # bf16 bytes
    L = max(cfg.n_layers, 1)
    P_layer = P / L
    if kind == "train":
        M = max(cfg.microbatches, 1)
        b_loc = max(batch // M // dp, 1)
        resid = b_loc * seq * cfg.d_model * 2.0
        per_layer = 4.0 * P_layer / dev * tp + 10.0 * resid
        head = 3.0 * (cfg.vocab_size * cfg.d_model * 2.0) / tp \
            + 2.0 * b_loc * seq * (cfg.vocab_size / tp) * 2.0
        opt = _OPT_BYTES_PER_PARAM * cfg.param_count() / dev
        return M * (L * per_layer + head) + opt
    if kind == "prefill":
        b_loc = max(batch // dp, 1)
        resid = b_loc * seq * cfg.d_model * 2.0
        kv_write = (2.0 * b_loc * seq * cfg.n_kv_heads * cfg.hd * 2.0)
        return L * (P_layer / tp + 6.0 * resid + kv_write) \
            + (cfg.vocab_size * cfg.d_model * 2.0) / tp
    # decode
    b_loc = max(batch // dp, 1) if batch >= dp else batch
    cache = 2.0 * L * b_loc * (seq / tp) * cfg.n_kv_heads * cfg.hd * 2.0
    return P / tp + cache


def _scan_unit_info(cfg):
    """(full_units, override_fn(units) -> cfg overrides) for the probe."""
    if cfg.family == "hybrid":
        def ov(u):
            return {"n_macro_blocks": u,
                    "n_layers": u * cfg.mamba_per_block
                    + cfg.tail_mamba_layers,
                    "scan_layers": False}
        return cfg.n_macro_blocks, ov
    if cfg.attn_pattern == "local_global":
        def ov(u):
            return {"n_layers": 2 * u, "scan_layers": False}
        return cfg.n_layers // 2, ov

    def ov(u):
        return {"n_layers": u, "scan_layers": False}
    return cfg.n_layers, ov


def _probe_compile(cfg_p, shape, mesh, batch: int, parallelism: str = "tp",
                   prefill_lastonly: bool = False):
    """Compile one probe variant; returns (flops, bytes, coll_s, coll)."""
    model = build_model(cfg_p)
    ctx, sh = assemble(model, mesh, shape.kind, batch, shape.seq,
                       unroll_scans=True, parallelism=parallelism)
    abstract_params = model.abstract_params()
    if shape.kind == "train":
        def grad_fn(params, b):
            return jax.value_and_grad(
                lambda p: model.loss(p, b, ctx))(params)
        batch_abs = model.batch_shapes(batch, shape.seq)
        lowered = jax.jit(
            grad_fn, in_shardings=(sh["opt_params"], sh["batch"]),
            out_shardings=(None, sh["opt_params"])).lower(
            abstract_params, batch_abs)
    elif shape.kind == "prefill":
        bf16_params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            abstract_params)
        step_fn = build_prefill_step(model, ctx, last_only=prefill_lastonly)
        batch_abs = model.batch_shapes(batch, shape.seq)
        lowered = jax.jit(step_fn, in_shardings=(sh["params"], sh["batch"])
                          ).lower(bf16_params, batch_abs)
    else:
        bf16_params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            abstract_params)
        abstract_cache = model.abstract_cache(batch, shape.seq)
        step_fn = build_serve_step(model, ctx)
        toks = jax.ShapeDtypeStruct((batch,), jnp.int32)
        lowered = jax.jit(
            step_fn,
            in_shardings=(sh["params"], sh["cache"], sh["tokens"],
                          sh["tokens"]),
            out_shardings=(None, sh["cache"])).lower(
            bf16_params, abstract_cache, toks, toks)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            collective_seconds(coll), coll)


def probed_costs(cfg, shape, mesh, parallelism: str = "tp",
                 prefill_lastonly: bool = False) -> dict | None:
    """Trip-count-corrected per-device (flops, bytes, collective_s)."""
    if cfg.family == "ssm":
        return None            # xlstm is python-unrolled: raw costs exact
    units_full, ov = _scan_unit_info(cfg)
    M = cfg.microbatches if shape.kind == "train" else 1
    batch = shape.batch // M if shape.kind == "train" else shape.batch
    vals = []
    for u in (1, 2):
        cfg_p = _dc.replace(cfg, **ov(u))
        vals.append(_probe_compile(cfg_p, shape, mesh, batch, parallelism,
                                   prefill_lastonly))
    (f1, b1, c1, _), (f2, b2, c2, coll2) = vals
    per = (f2 - f1, b2 - b1, c2 - c1)
    fixed = (f1 - per[0], b1 - per[1], c1 - per[2])
    flops = M * (fixed[0] + per[0] * units_full)
    bytes_ = M * (fixed[1] + per[1] * units_full)
    coll_s = M * (fixed[2] + per[2] * units_full)
    if shape.kind == "train":
        n_dev_params = cfg.param_count() / mesh.size
        flops += _OPT_FLOPS_PER_PARAM * n_dev_params
        bytes_ += _OPT_BYTES_PER_PARAM * n_dev_params
    return {"flops": flops, "bytes_accessed": bytes_,
            "collective_s": coll_s,
            "probe_points": {"u1": {"flops": f1, "bytes": b1, "coll_s": c1},
                             "u2": {"flops": f2, "bytes": b2, "coll_s": c2}},
            "units_full": units_full, "microbatches": M}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None,
             parallelism: str = "tp", no_probes: bool = False,
             prefill_lastonly: bool = False) -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    model = build_model(cfg)
    ctx, sh = assemble(model, mesh, shape.kind, shape.batch, shape.seq,
                       parallelism=parallelism)

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "batch": shape.batch, "seq": shape.seq,
        "devices": int(mesh.size), "parallelism": parallelism,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "rules": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in ctx.rules.items()},
    }

    abstract_params = model.abstract_params()
    if shape.kind == "train":
        optimizer = AdamW()
        abstract_opt = jax.eval_shape(optimizer.init, abstract_params)
        step_fn = build_train_step(model, optimizer, ctx)
        batch_abs = model.batch_shapes(shape.batch, shape.seq)
        opt_sh = opt_state_shardings(sh["opt_params"], mesh)
        in_sh = (sh["opt_params"], opt_sh, sh["batch"])
        out_sh = (sh["opt_params"], opt_sh, None)
        # donate params + opt state: updates alias in place (halves the
        # optimizer-state residency, exactly as a real trainer runs)
        lowered = jax.jit(step_fn, in_shardings=in_sh,
                          out_shardings=out_sh,
                          donate_argnums=(0, 1)).lower(
            abstract_params, abstract_opt, batch_abs)
    elif shape.kind == "prefill":
        bf16_params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            abstract_params)
        step_fn = build_prefill_step(model, ctx, last_only=prefill_lastonly)
        batch_abs = model.batch_shapes(shape.batch, shape.seq)
        lowered = jax.jit(step_fn, in_shardings=(sh["params"], sh["batch"])
                          ).lower(bf16_params, batch_abs)
    else:                                   # decode
        bf16_params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            abstract_params)
        abstract_cache = model.abstract_cache(shape.batch, shape.seq)
        step_fn = build_serve_step(model, ctx)
        toks = jax.ShapeDtypeStruct((shape.batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((shape.batch,), jnp.int32)
        in_sh = (sh["params"], sh["cache"], sh["tokens"], sh["tokens"])
        out_sh = (None, sh["cache"])
        # donate the KV cache: the one-token update aliases in place
        # instead of double-buffering the (possibly 500k-long) cache
        lowered = jax.jit(step_fn, in_shardings=in_sh,
                          out_shardings=out_sh,
                          donate_argnums=(1,)).lower(
            bf16_params, abstract_cache, toks, pos)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    # ---- memory ----
    mem = compiled.memory_analysis()
    if mem is not None:
        record["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes_estimate": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)),
        }
    # ---- cost ----
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    record["cost"] = {"flops": flops, "bytes_accessed": bytes_acc}

    # ---- collectives ----
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    record["collectives"] = coll
    record["hlo_lines"] = hlo.count("\n")

    # ---- roofline (trip-count-corrected via probes; single-pod only) ----
    raw_coll_s = collective_seconds(coll)
    record["raw_cost"] = {"flops": flops, "bytes_accessed": bytes_acc,
                          "collective_s": raw_coll_s}
    corrected = None
    if mesh_kind == "single" and not no_probes:
        corrected = probed_costs(cfg, shape, mesh, parallelism,
                                 prefill_lastonly)
    if corrected is not None:
        flops = corrected["flops"]
        bytes_acc = corrected["bytes_accessed"]
        coll_s = corrected["collective_s"]
        record["probe"] = {k: corrected[k] for k in
                           ("probe_points", "units_full", "microbatches")}
    else:
        coll_s = raw_coll_s
    record["cost"] = {"flops": flops, "bytes_accessed": bytes_acc}

    comp_s = flops / PEAK_FLOPS
    mem_s = bytes_acc / HBM_BW
    mem_floor_s = analytic_memory_bytes(
        cfg, shape.kind, shape.batch, shape.seq, mesh) / HBM_BW
    mf = model_flops(cfg, shape.kind, shape.batch, shape.seq)
    per_dev_mf = mf / mesh.size
    terms = {"compute_s": comp_s, "memory_s": mem_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    record["roofline"] = {
        **terms,
        "memory_floor_s": mem_floor_s,
        "dominant": dominant,
        "model_flops_global": mf,
        "model_flops_per_device": per_dev_mf,
        "useful_compute_ratio": (per_dev_mf / flops) if flops else 0.0,
        "bound_step_s": max(terms.values()),
        "roofline_fraction": (per_dev_mf / PEAK_FLOPS)
        / max(max(terms.values()), 1e-30),
    }
    record["timings"] = {"lower_s": round(t_lower, 1),
                         "compile_s": round(t_compile, 1),
                         "total_s": round(time.time() - t0, 1)}
    record["ok"] = True
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--parallelism", default="tp",
                    choices=["tp", "tp-sp", "fsdp", "vtp", "dp", "ring"])
    ap.add_argument("--set", default="", dest="overrides",
                    help="cfg overrides, e.g. microbatches=8,remat_policy=dots")
    ap.add_argument("--tag", default="",
                    help="suffix for the result key (perf iterations)")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip cost probes (memory-only iterations)")
    ap.add_argument("--prefill-lastonly", action="store_true",
                    help="prefill computes the vocab head on the last "
                         "position only (perf lever)")
    args = ap.parse_args()

    overrides: dict = {}
    for kv in filter(None, args.overrides.split(",")):
        k, v = kv.split("=")
        overrides[k] = (int(v) if v.lstrip("-").isdigit()
                        else (v == "True" if v in ("True", "False") else v))

    archs = list(ARCH_NAMES) if (args.arch == "all" or args.all) \
        else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        shapes = [s.name for s in shapes_for(arch)]
        if args.shape != "all":
            shapes = [s for s in args.shape.split(",") if s in shapes]
        for shape_name in shapes:
            for mesh_kind in meshes:
                key = f"{arch}|{shape_name}|{mesh_kind}"
                if args.tag:
                    key += f"#{args.tag}"
                if key in results and results[key].get("ok") \
                        and not args.force:
                    print(f"[skip] {key}")
                    continue
                print(f"[run ] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape_name, mesh_kind,
                                   overrides=overrides or None,
                                   parallelism=args.parallelism,
                                   no_probes=args.no_probes,
                                   prefill_lastonly=args.prefill_lastonly)
                    rec["tag"] = args.tag
                    rl = rec["roofline"]
                    print(f"[ ok ] {key}: dominant={rl['dominant']} "
                          f"compute={rl['compute_s']:.4f}s "
                          f"memory={rl['memory_s']:.4f}s "
                          f"collective={rl['collective_s']:.4f}s "
                          f"frac={rl['roofline_fraction']:.3f} "
                          f"(compile {rec['timings']['compile_s']}s)",
                          flush=True)
                except Exception as e:                     # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[FAIL] {key}: {rec['error']}", flush=True)
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"dry-run complete: {n_ok}/{len(results)} cells OK")


if __name__ == "__main__":
    main()
