"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.

Topology (TPU v5e pods):
  single-pod:  (16, 16)    axes ("data", "model")      256 chips
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model")  512 chips
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — smoke tests and
    the subprocess multi-device tests."""
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))
