"""Train / serve step builders (the functions the dry-run lowers and the
drivers execute).

train_step: gradient-accumulation microbatching via ``lax.scan`` (the
  per-arch ``microbatches`` knob is the main memory lever), fp32 master
  params with on-the-fly bf16 casts inside the model, AdamW update. Under
  pjit the data-parallel gradient mean and the ZeRO gathers/scatters are
  GSPMD-inserted from the sharding annotations — the cross-pod all-reduce
  is the paper's broadcast&gather motif (DESIGN.md §2).

serve_step: one decode step against the sharded cache; prefill_step: full
  forward returning last-position logits.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import ModelContext
from repro.models.zoo import Model
from repro.optim.adamw import AdamW


def build_loss_fn(model: Model, ctx: ModelContext):
    def loss_fn(params, batch):
        return model.loss(params, batch, ctx)
    return loss_fn


def build_train_step(model: Model, optimizer: AdamW, ctx: ModelContext,
                     microbatches: Optional[int] = None):
    M = microbatches or model.cfg.microbatches
    loss_fn = build_loss_fn(model, ctx)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch):
        if M > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                loss, grads = grad_fn(params, mb)
                gacc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                    gacc, grads)
                return (gacc, lacc + loss), None

            mbatch = jax.tree.map(
                lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]),
                batch)
            gz = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(micro, (gz, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss / M
        else:
            loss, grads = grad_fn(params, batch)
        new_params, new_opt, metrics = optimizer.update(grads, opt_state,
                                                        params)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step


def build_serve_step(model: Model, ctx: ModelContext):
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, ctx)
    return serve_step


def build_prefill_step(model: Model, ctx: ModelContext,
                       last_only: bool = False):
    def prefill_step(params, batch):
        if last_only:
            # optimized: vocab head computed for the final position only
            return model.forward(params, batch, ctx, last_only=True)[:, 0]
        logits = model.forward(params, batch, ctx)
        return logits[:, -1]
    return prefill_step
