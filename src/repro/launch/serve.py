"""Batched serving driver: prefill + decode with the KV cache, optionally
publishing per-request results back through the feedback channel (the
paper's work-sharing-with-feedback motif at inference time — LCLS-style
"analyze between experiment runs").

Runnable at smoke scale on CPU; the decode path here is exactly what the
dry-run lowers for decode_32k / long_500k at production scale.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import build_serve_step
from repro.models.sharding import ModelContext
from repro.models.zoo import build_model


def generate(model, params, prompts: jnp.ndarray, max_new: int,
             ctx=None, greedy=True, seed=0):
    """prompts: (B, P) int32. Returns (B, P+max_new) tokens."""
    B, P = prompts.shape
    total = P + max_new
    cache = model.init_cache(B, total)
    step = jax.jit(build_serve_step(model, ctx or ModelContext()))
    toks = prompts
    out = [prompts]
    key = jax.random.key(seed)
    # prefill token-by-token (smoke scale; production prefill is the
    # lowered prefill_step)
    logits = None
    for t in range(P):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = step(params, cache, toks[:, t], pos)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(cur[:, None])
    for t in range(P, total - 1):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = step(params, cache, cur, pos)
        if greedy:
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits).astype(jnp.int32)
        out.append(cur[:, None])
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = (get_smoke_config(args.arch.removesuffix("-smoke"))
           if args.arch.endswith("-smoke") else get_config(args.arch))
    model = build_model(cfg)
    params = model.init_params(jax.random.key(args.seed))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.time()
    toks = generate(model, params, prompts, args.max_new)
    dt = time.time() - t0
    n_new = args.batch * args.max_new
    print(f"generated {toks.shape} in {dt:.1f}s "
          f"({n_new / dt:.1f} tok/s batch-aggregate)")
    print("sample:", np.asarray(toks[0])[:16], "...")


if __name__ == "__main__":
    main()
