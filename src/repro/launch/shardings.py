"""Per-(arch x shape x mesh) sharding assembly.

Three rule tables (logical axis -> mesh axes) drive everything:

* **activation rules** — threaded through model code via ModelContext;
* **parameter rules** — how the model weights land (megatron TP layout);
* **optimizer rules** — ZeRO-style: parameter rules *plus* ``d_model`` over
  the ``data`` axis, so fp32 master params + Adam moments are fully
  sharded over the whole mesh (a 34B model's optimizer state drops from
  25.5 GiB/chip replicated to ~1.6 GiB/chip).

Divisibility fallbacks are computed here (e.g. long_500k's batch=1 cannot
shard over ``data`` — the KV cache seq dim takes every mesh axis instead;
xlstm's 4 heads cannot TP-shard — training batch spreads over
``data x model``).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.sharding import ModelContext, default_rules
from repro.models.zoo import Model


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _divisible_prefix(mesh: Mesh, candidates: tuple, size: int) -> tuple:
    """Longest prefix of candidate axes whose product divides ``size``."""
    out = []
    for a in candidates:
        trial = out + [a]
        if size % _axes_size(mesh, tuple(trial)) == 0:
            out = trial
        else:
            break
    return tuple(out)


def make_rules(cfg: ArchConfig, mesh: Mesh, kind: str, batch: int,
               seq_parallel: bool = False,
               parallelism: str = "tp") -> dict:
    """parallelism:
      "tp"    - megatron TP over `model` + DP over `pod`x`data` (baseline)
      "tp-sp" - TP + sequence-parallel residuals (all-reduce ->
                reduce-scatter/all-gather pairs)
      "fsdp"  - pure data parallelism over EVERY axis + fully-sharded
                params (ZeRO-3-style weight gathering per layer)
    """
    if parallelism == "tp-sp":
        seq_parallel = True
    multi_pod = "pod" in mesh.axis_names
    rules = default_rules(multi_pod=multi_pod, seq_parallel=seq_parallel)
    dp_candidates = ("pod", "data") if multi_pod else ("data",)
    if parallelism == "fsdp" or (cfg.family == "ssm" and kind == "train"):
        # fsdp: batch over the model axis too; xlstm: 4 heads can't
        # TP-shard regardless
        dp_candidates = dp_candidates + ("model",)
    batch_axes = _divisible_prefix(mesh, dp_candidates, batch)
    rules["batch"] = batch_axes if batch_axes else None
    # heads: only shard if divisible
    if cfg.n_heads % mesh.shape["model"] != 0 or "model" in (batch_axes or ()):
        rules["heads"] = None
    if kind == "decode":
        # KV-cache seq dim takes every mesh axis the batch doesn't use
        leftover = tuple(a for a in mesh.axis_names
                         if a not in (batch_axes or ()))
        rules["kv_seq"] = leftover if leftover else None
    # ssm heads shardable?
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // max(cfg.ssm_head_dim, 1) if cfg.ssm_head_dim else 0
    if cfg.family == "ssm":
        nh = cfg.n_heads
    if nh and nh % mesh.shape["model"] != 0:
        rules["ssm_heads"] = None
    if "model" in (batch_axes or ()):
        rules["ssm_heads"] = None
        rules["d_ff"] = None
        rules["vocab"] = None
        rules["heads"] = None
    if parallelism == "dp":
        # pure data parallelism: model axis idles (replicated compute) —
        # zero TP collectives; useful when TP layouts reshard-thrash
        rules["d_ff"] = None
        rules["heads"] = None
        rules["ssm_heads"] = None
        rules["vocab"] = None
    if parallelism == "ring":
        # sequence parallelism for SSM/xLSTM: S over `model`; projections
        # are position-wise (zero comm); the mLSTM inter-chunk state
        # crosses ranks via the affine all_gather exchange (shard_map)
        rules["seq"] = "model"
        rules["d_ff"] = None
        rules["ssm_heads"] = None
        rules["heads"] = None
        rules["vocab"] = None
    if parallelism == "vtp":
        # mLSTM value-dim TP: q/k replicated, v (and the matrix-memory
        # value dim) sharded over `model`; only down_proj all-reduces
        rules["xlstm_hd"] = "model"
        rules["d_ff"] = None
        rules["ssm_heads"] = None
    rules["_parallelism"] = parallelism
    return rules


def zero_rules(rules: dict) -> dict:
    """Optimizer-state / master-param rules: fully shard the largest
    remaining dim. Under TP: d_model over `data` (params: TP x ZeRO-data).
    Under FSDP: d_model over (data, model) — fully sharded everywhere."""
    out = dict(rules)
    if rules.get("_parallelism") == "fsdp":
        out["d_model"] = ("data", "model")
    else:
        out["d_model"] = "data"
    return out


def _spec_from_names(names, rules: dict) -> P:
    """Resolve logical names to a PartitionSpec, de-duplicating mesh axes:
    earlier dims win (e.g. an expert-sharded dim keeps `model`; a later
    ZeRO d_model entry then sheds `model` and keeps `data`)."""
    used: set = set()
    out = []
    for n in names:
        r = rules.get(n) if n is not None else None
        if r is None:
            out.append(None)
            continue
        axes = (r,) if isinstance(r, str) else tuple(r)
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def tree_shardings(spec_names_tree, rules: dict, mesh: Mesh):
    """Map a pytree of logical-axis-name tuples to NamedShardings."""
    def conv(names):
        return NamedSharding(mesh, _spec_from_names(names, rules))
    return jax.tree.map(conv, spec_names_tree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, type(None))) for e in x))


def assemble(model: Model, mesh: Mesh, kind: str, batch: int, seq: int,
             seq_parallel: bool = False, attention_impl: str = "auto",
             moe_impl: str = "auto", unroll_scans: bool = False,
             parallelism: str = "tp", rules: Optional[dict] = None):
    """Returns (ctx, shardings dict) for one dry-run / launch cell."""
    cfg = model.cfg
    rules = rules or make_rules(cfg, mesh, kind, batch, seq_parallel,
                                parallelism)
    ctx = ModelContext(mesh=mesh, rules=rules,
                       attention_impl=attention_impl, moe_impl=moe_impl,
                       unroll_scans=unroll_scans)
    param_sh = tree_shardings(model.param_specs(), rules, mesh)
    opt_param_sh = tree_shardings(model.param_specs(), zero_rules(rules),
                                  mesh)
    batch_sh = tree_shardings(model.batch_logical_axes(), rules, mesh)
    out = {"params": param_sh, "opt_params": opt_param_sh, "batch": batch_sh}
    if kind == "decode":
        out["cache"] = tree_shardings(model.cache_specs(), rules, mesh)
        out["tokens"] = NamedSharding(mesh, _spec_from_names(
            ("batch",), rules))
    return ctx, out


def opt_state_shardings(opt_param_sh, mesh: Mesh):
    """AdamW state shardings: moments follow the (ZeRO) param shardings."""
    return {
        "m": opt_param_sh,
        "v": opt_param_sh,
        "step": NamedSharding(mesh, P()),
    }
