"""End-to-end training driver (runnable at smoke scale on CPU; the same
code path the dry-run lowers at production scale).

Features exercised here and tested in tests/test_train_loop.py:
  * streamed data (edge producers -> broker -> StreamingDataLoader) or the
    local synthetic pipeline (--data local)
  * checkpoint/restart (async writer, atomic commit, resume-determinism)
  * steering feedback (work sharing with feedback) every --feedback-every
  * elastic consumer group + consumer-crash tolerance (fault injection via
    --crash-consumer-at)

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b-smoke \
      --steps 100 --data stream --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import (
    AsyncCheckpointer, latest_checkpoint, restore_checkpoint)
from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.core.workloads import DSTREAM
from repro.data import SyntheticTokens
from repro.launch.steps import build_train_step
from repro.models.sharding import ModelContext
from repro.models.zoo import build_model
from repro.optim import AdamW, cosine_warmup
from repro.streaming import (
    EdgeProducer, RealtimeBroker, SteeringFeedback, StreamingDataLoader)


def make_stream(cfg, batch, seq, n_producers=2, n_consumers=2):
    broker = RealtimeBroker()
    loader = StreamingDataLoader(
        broker, DSTREAM, vocab_size=cfg.vocab_size, seq_len=seq,
        batch_size=batch, n_consumers=n_consumers)
    fb = SteeringFeedback(broker, [f"edge-{i}" for i in range(n_producers)])
    producers = []
    for i in range(n_producers):
        pid = f"edge-{i}"
        p = EdgeProducer(
            broker, DSTREAM,
            lambda j, i=i: f"work:{(i + j) % 2}",
            rate_msgs_s=500.0, producer_id=pid,
            reply_queue=fb.reply_queue(pid))
        producers.append(p.start())
    return broker, loader, fb, producers


def run(args) -> dict:
    cfg = (get_smoke_config(args.arch.removesuffix("-smoke"))
           if args.arch.endswith("-smoke") else get_config(args.arch))
    model = build_model(cfg)
    ctx = ModelContext()
    optimizer = AdamW(learning_rate=cosine_warmup(
        args.lr, warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps))
    train_step = jax.jit(build_train_step(
        model, optimizer, ctx, microbatches=args.microbatches))

    params = model.init_params(jax.random.key(args.seed))
    opt_state = optimizer.init(params)
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)
        latest = latest_checkpoint(args.ckpt_dir)
        if latest and args.resume:
            start_step, (params, opt_state) = restore_checkpoint(
                latest, (params, opt_state))
            print(f"resumed from {latest} at step {start_step}")

    stream = None
    if args.data == "stream":
        broker, loader, fb, producers = make_stream(cfg, args.batch, args.seq)
        stream = (broker, loader, fb, producers)
        batches = iter(loader)
    else:
        batches = iter(SyntheticTokens(cfg.vocab_size, args.seq,
                                       seed=args.seed,
                                       batch_size=args.batch))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        if stream and args.crash_consumer_at == step:
            n = stream[1].crash_consumer("ingest-0")
            stream[1].add_consumer()
            print(f"[fault] crashed ingest-0 at step {step}; "
                  f"{n} messages redelivered; respawned")
        batch = next(batches)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            rate = (step - start_step + 1) / (time.time() - t0)
            print(f"step {step:5d} loss {loss:7.4f} "
                  f"gnorm {float(metrics['grad_norm']):6.3f} "
                  f"({rate:.2f} steps/s)", flush=True)
        if stream and step % args.feedback_every == 0:
            depth = stream[0].queue_depth("work:0")
            stream[2].publish_step(step, loss, backpressure=depth > 64)
            for p in stream[3]:
                p.poll_feedback(timeout=0.01)
        if ckpt and step > 0 and step % args.ckpt_every == 0:
            ckpt.save(step, (params, opt_state))
    if ckpt:
        ckpt.save(args.steps, (params, opt_state))
        ckpt.close()
    if stream:
        for p in stream[3]:
            p.stop(join=False)
        stream[1].close()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "params": params}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b-smoke",
                    help=f"one of {ARCH_NAMES} or '<name>-smoke'")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data", choices=["local", "stream"], default="local")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true", default=True)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--feedback-every", type=int, default=10)
    ap.add_argument("--crash-consumer-at", type=int, default=-1)
    args = ap.parse_args()
    out = run(args)
    print(f"done: first loss {out['losses'][0]:.4f} "
          f"final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
