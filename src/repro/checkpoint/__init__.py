from repro.checkpoint.checkpointer import (
    AsyncCheckpointer, latest_checkpoint, restore_checkpoint,
    save_checkpoint)
