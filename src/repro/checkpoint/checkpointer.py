"""Fault-tolerant checkpointing.

Design for 1000+-node posture (DESIGN.md §3):
* **atomic commit** — leaves stream into ``<dir>.tmp``, the manifest (tree
  structure, shapes, dtypes, step) is written last, then one rename
  publishes the checkpoint; a crashed writer can never produce a
  half-checkpoint that restore() would accept.
* **mesh-agnostic restore** — leaves are stored unsharded (numpy); the
  restorer re-shards via ``jax.device_put`` with whatever sharding the
  *current* mesh prescribes, so a job can restart elastically on a
  different topology.
* **async writer** — a background thread drains a bounded queue, so the
  train loop is blocked only by ``device_get``, not the filesystem.
* retention of the newest K checkpoints; corrupted/partial dirs are
  ignored by ``latest_checkpoint``.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"
_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _flatten(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_SAFE.sub("_", str(getattr(p, "key", getattr(p, "idx", p))))
                        for p in path)
        out.append((name or "leaf", leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    keep: int = 3) -> str:
    """Blocking atomic save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    entries = []
    for i, (name, leaf) in enumerate(_flatten(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        entries.append({"name": name, "file": fname,
                        "shape": list(arr.shape), "dtype": str(arr.dtype)})
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {"step": step, "entries": entries,
                "treedef": str(treedef)}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _apply_retention(directory, keep)
    return final


def _apply_retention(directory: str, keep: int) -> None:
    ckpts = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(directory, d, MANIFEST)))
    for stale in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, stale))


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in sorted(os.listdir(directory)):
        p = os.path.join(directory, d)
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(p, MANIFEST)):
            best = p
    return best


def restore_checkpoint(path: str, target_tree: Any,
                       shardings: Any = None) -> tuple[int, Any]:
    """Restore into the structure of ``target_tree``; optionally re-shard
    each leaf with the matching entry of ``shardings`` (elastic restart on
    a different mesh)."""
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    leaves_meta = manifest["entries"]
    target_leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    if len(target_leaves) != len(leaves_meta):
        raise ValueError(
            f"checkpoint has {len(leaves_meta)} leaves; target expects "
            f"{len(target_leaves)}")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_meta))
    out = []
    for meta, tgt, shd in zip(leaves_meta, target_leaves, shard_leaves):
        arr = np.load(os.path.join(path, meta["file"]))
        if list(arr.shape) != list(tgt.shape):
            raise ValueError(
                f"shape mismatch for {meta['name']}: "
                f"{arr.shape} vs {tgt.shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=tgt.dtype))
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with a bounded queue."""

    def __init__(self, directory: str, keep: int = 3, max_pending: int = 2):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=max_pending)
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save_checkpoint(self.directory, step, host_tree, self.keep)
            except BaseException as e:          # surfaced on next save/wait
                self._error = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree: Any) -> None:
        if self._error:
            raise RuntimeError("async checkpoint failed") from self._error
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._q.put((step, host_tree))

    def wait(self) -> None:
        self._q.join()
        if self._error:
            raise RuntimeError("async checkpoint failed") from self._error

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join()
