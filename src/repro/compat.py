"""Version-compatibility shims over the installed JAX.

The codebase targets the modern JAX surface (``jax.shard_map`` with
``check_vma``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``).  Older JAX releases (<= 0.4.x) expose the same
functionality under different names/signatures:

* ``jax.sharding.AxisType`` does not exist — meshes are implicitly
  ``Auto``-typed, so the shim enum is accepted and dropped.
* ``jax.make_mesh`` takes no ``axis_types`` keyword.
* ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the
  replication check ``check_rep`` instead of ``check_vma``.

Import the names from here instead of from ``jax`` directly; each resolves
to the native implementation when the installed JAX has it.
"""

from __future__ import annotations

import enum
from typing import Any, Optional, Sequence

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    _HAVE_AXIS_TYPE = True
except ImportError:  # pragma: no cover - depends on installed jax
    _HAVE_AXIS_TYPE = False

    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` on older JAX.

        Pre-AxisType meshes behave as ``Auto`` on every axis, which is the
        only mode this repo requests, so carrying the value is enough."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types: Optional[Sequence[Any]] = None,
              **kw: Any) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` accepting ``axis_types`` on every JAX version."""
    if _HAVE_AXIS_TYPE and axis_types is not None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=tuple(axis_types), **kw)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def shard_map(f: Any = None, /, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: Optional[bool] = None, **kw: Any) -> Any:
    """``jax.shard_map`` with ``check_vma`` on any JAX version.

    On older JAX this resolves to ``jax.experimental.shard_map.shard_map``
    and translates ``check_vma`` to ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if f is None:
            return lambda g: jax.shard_map(g, mesh=mesh, in_specs=in_specs,
                                           out_specs=out_specs, **kw)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if f is None:
        return lambda g: _sm(g, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
