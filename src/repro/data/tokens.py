"""Deterministic synthetic token pipeline (local fallback when not
streaming from the edge). Produces a learnable distribution (Zipfian
unigrams + short-range bigram structure) so example training losses
decrease meaningfully."""

from __future__ import annotations

import numpy as np


class SyntheticTokens:
    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 batch_size: int = 8):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # deterministic "successor" structure: each token strongly predicts
        # (token * 7 + 3) % vocab, giving a model something to learn
        self.successor = (np.arange(vocab_size) * 7 + 3) % vocab_size

    def sample_batch(self) -> dict:
        B, S = self.batch, self.seq
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = self.rng.choice(self.vocab, size=B, p=self.unigram)
        for t in range(1, S + 1):
            follow = self.rng.random(B) < 0.8
            toks[:, t] = np.where(
                follow, self.successor[toks[:, t - 1]],
                self.rng.choice(self.vocab, size=B, p=self.unigram))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        while True:
            yield self.sample_batch()
