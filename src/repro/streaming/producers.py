"""Edge producers: synthetic detector-event sources shaped like the
paper's workloads (Dstream/Lstream/generic), publishing into the realtime
broker over a chosen architecture's ingest path.

Each producer runs in a thread, generating deterministic payloads (see
Workload.payload) at a target rate, honoring reject-publish backpressure,
and — under the work-sharing-with-feedback pattern — reading steering
replies from its direct reply queue and adapting its event rate.
"""

from __future__ import annotations

import itertools
import threading
import time
from typing import Optional

from repro.core.broker import Message
from repro.core.workloads import Workload
from repro.streaming.rtbroker import RealtimeBroker

_pid = itertools.count()


class EdgeProducer:
    def __init__(self, broker: RealtimeBroker, workload: Workload,
                 queue_of, *, rate_msgs_s: float = 200.0,
                 n_messages: Optional[int] = None,
                 producer_id: Optional[str] = None,
                 reply_queue: Optional[str] = None):
        self.broker = broker
        self.workload = workload
        self.queue_of = queue_of          # fn(i) -> routing key
        self.rate = rate_msgs_s
        self.n_messages = n_messages
        self.id = producer_id or f"edge-{next(_pid)}"
        self.reply_queue = reply_queue
        self.sent = 0
        self.rejected = 0
        self.feedback_seen = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> "EdgeProducer":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        if join and self._thread is not None:
            self._thread.join(timeout=10)

    def join(self, timeout: float = 60.0) -> None:
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    # -- main loop -------------------------------------------------------------
    def _run(self) -> None:
        i = 0
        while not self._stop.is_set():
            if self.n_messages is not None and i >= self.n_messages:
                break
            payload = self.workload.payload(seed=hash(self.id) % 10**6 + i)
            msg = Message(routing_key=self.queue_of(i),
                          size=len(payload), body=payload,
                          producer_id=self.id,
                          reply_to=self.reply_queue,
                          headers={"seq": i, "producer": self.id})
            if self.broker.publish(msg, block=True, timeout=5.0):
                self.sent += 1
                i += 1
            else:
                self.rejected += 1
            if self.rate > 0:
                time.sleep(1.0 / self.rate)

    # -- steering --------------------------------------------------------------
    def poll_feedback(self, timeout: float = 0.1) -> Optional[dict]:
        """Consume one steering reply (work sharing with feedback). The
        trainer publishes metrics; the producer adapts its rate (a stand-in
        for 'adjust beam settings' in the paper's workflows)."""
        if self.reply_queue is None:
            return None
        d = self.broker.consume(self.id, timeout=timeout)
        if d is None:
            return None
        self.broker.ack(self.id, d.delivery_tag)
        self.feedback_seen += 1
        fb = d.message.headers
        if fb.get("slow_down"):
            self.rate = max(1.0, self.rate * 0.5)
        elif fb.get("speed_up"):
            self.rate = self.rate * 1.25
        return fb
