from repro.streaming.feedback import SteeringFeedback
from repro.streaming.ingest import WORK_QUEUES, StreamingDataLoader
from repro.streaming.producers import EdgeProducer
from repro.streaming.rtbroker import RealtimeBroker

__all__ = ["EdgeProducer", "RealtimeBroker", "SteeringFeedback",
           "StreamingDataLoader", "WORK_QUEUES"]
