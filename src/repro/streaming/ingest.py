"""StreamingDataLoader: the edge→HPC work-sharing data plane feeding the
training loop (paper pattern #1 mapped onto data parallelism, DESIGN.md §2).

N consumer threads pull detector messages from the shared work queues
(round-robin, prefetch, batch acks), map payloads to token sequences
deterministically, and assemble global training batches into a bounded
staging buffer (backpressure: when training stalls, consumers stop acking,
prefetch windows close, the broker queues absorb the burst, and producers
eventually see reject-publish — the full paper §5.2 flow-control chain).

Fault tolerance: a consumer crash mid-batch requeues its unacked messages
(redelivered=True) and a respawned consumer picks them up — no event loss
(tests/test_streaming_ingest.py kills consumers mid-stream and checks
batch-content integrity). Straggler mitigation is inherent to the
work-queue model: a slow consumer simply takes fewer messages (its
prefetch window stays full), exactly the property the paper highlights for
GRETA/Deleria.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

from repro.core.workloads import Workload, tokens_from_payload
from repro.streaming.rtbroker import RealtimeBroker

WORK_QUEUES = ("work:0", "work:1")          # paper: two shared work queues


class StreamingDataLoader:
    def __init__(self, broker: RealtimeBroker, workload: Workload, *,
                 vocab_size: int, seq_len: int, batch_size: int,
                 n_consumers: int = 2, prefetch_batches: int = 2,
                 ack_batch: int = 8, queues: tuple = WORK_QUEUES):
        self.broker = broker
        self.workload = workload
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.queues = queues
        self.ack_batch = ack_batch
        self._staging: "queue.Queue[dict]" = queue.Queue(
            maxsize=prefetch_batches)
        self._row_q: "queue.Queue[np.ndarray]" = queue.Queue(
            maxsize=batch_size * (prefetch_batches + 1))
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._consumer_ids: list[str] = []
        self.messages_consumed = 0
        self.redeliveries_seen = 0
        self._lock = threading.Lock()
        for q in queues:
            broker.declare_queue(q)
        for c in range(n_consumers):
            self.add_consumer()
        self._assembler = threading.Thread(target=self._assemble, daemon=True)
        self._assembler.start()

    # -- elastic consumer group -------------------------------------------------
    def add_consumer(self) -> str:
        cid = f"ingest-{len(self._consumer_ids)}"
        q = self.queues[len(self._consumer_ids) % len(self.queues)]
        self.broker.register_consumer(cid, q)
        t = threading.Thread(target=self._consume_loop, args=(cid,),
                             daemon=True)
        self._consumer_ids.append(cid)
        self._threads.append(t)
        t.start()
        return cid

    def crash_consumer(self, cid: str) -> int:
        """Fault injection: kill one consumer; returns #redelivered."""
        return self.broker.consumer_crash(cid)

    # -- consumer threads -----------------------------------------------------
    def _consume_loop(self, cid: str) -> None:
        since_ack = 0
        last_tag = 0
        while not self._stop.is_set():
            d = self.broker.consume(cid, timeout=0.5)
            if d is None:
                continue
            msg = d.message
            if msg.redelivered:
                with self._lock:
                    self.redeliveries_seen += 1
            toks = tokens_from_payload(msg.body, self.vocab, self.seq + 1)
            self._row_q.put(toks)           # backpressure point
            with self._lock:
                self.messages_consumed += 1
            since_ack += 1
            last_tag = max(last_tag, d.delivery_tag)
            if since_ack >= self.ack_batch:
                self.broker.ack(cid, last_tag, multiple=True)
                since_ack = 0

    def _assemble(self) -> None:
        while not self._stop.is_set():
            rows = []
            while len(rows) < self.batch and not self._stop.is_set():
                try:
                    rows.append(self._row_q.get(timeout=0.5))
                except queue.Empty:
                    continue
            if len(rows) < self.batch:
                return
            arr = np.stack(rows)            # (B, S+1)
            self._staging.put({
                "tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32),
            })

    # -- training-side API -------------------------------------------------------
    def next_batch(self, timeout: float = 60.0) -> dict:
        return self._staging.get(timeout=timeout)

    def __iter__(self):
        while True:
            yield self.next_batch()

    def close(self) -> None:
        self._stop.set()
        self.broker.close()
