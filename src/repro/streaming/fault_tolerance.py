"""Fault-tolerance and elasticity helpers for the streaming data plane.

The primitives live where they act — redelivery in the broker state
machine (`core.broker.BrokerCluster.consumer_crash`), crash injection +
elastic consumer groups on the loader (`streaming.ingest`), atomic/async
checkpointing in `repro.checkpoint`. This module composes them into the
operations a cluster controller would drive.
"""

from __future__ import annotations

import dataclasses
import time

from repro.streaming.ingest import StreamingDataLoader


@dataclasses.dataclass
class FailureEvent:
    t: float
    kind: str          # consumer-crash | consumer-respawn | resize
    detail: str
    redelivered: int = 0


class ElasticConsumerGroup:
    """Controller-view of the loader's consumer group: crash, respawn,
    resize — every transition logged with its redelivery count (the
    paper's 'rare events will not be lost' guarantee, §6)."""

    def __init__(self, loader: StreamingDataLoader):
        self.loader = loader
        self.log: list[FailureEvent] = []

    @property
    def size(self) -> int:
        return len(self.loader._consumer_ids)

    def crash(self, consumer_id: str) -> int:
        n = self.loader.crash_consumer(consumer_id)
        self.log.append(FailureEvent(time.time(), "consumer-crash",
                                     consumer_id, redelivered=n))
        return n

    def respawn(self) -> str:
        cid = self.loader.add_consumer()
        self.log.append(FailureEvent(time.time(), "consumer-respawn", cid))
        return cid

    def scale_to(self, n: int) -> None:
        """Grow the group to n consumers (work-queue semantics rebalance
        automatically; shrink happens by crashing stragglers — their
        unacked messages redistribute)."""
        while self.size < n:
            self.respawn()
        self.log.append(FailureEvent(time.time(), "resize", f"-> {n}"))

    def kill_straggler(self, consumer_id: str) -> str:
        """Straggler mitigation beyond the work-queue's natural balancing:
        forcibly reassign a slow consumer's in-flight work and respawn."""
        self.crash(consumer_id)
        return self.respawn()
