"""Real-time (wall-clock, threaded) engine over the same broker state
machine the DES uses (:class:`repro.core.broker.BrokerCluster`).

This is the data plane the training integration runs on: edge producers
publish detector payloads, the StreamingDataLoader's consumers pull them
with prefetch/ack semantics, and the architecture (DTS/PRS/MSS) optionally
imposes its modeled per-message latency so experiments can compare ingest
paths end-to-end.
"""

from __future__ import annotations

import threading
import time
from typing import Optional

from repro.core.broker import BrokerCluster, Delivery, Message


class RealtimeBroker:
    def __init__(self, n_nodes: int = 3, default_prefetch: int = 64,
                 per_message_latency_s: float = 0.0):
        self._b = BrokerCluster(n_nodes=n_nodes,
                                default_prefetch=default_prefetch)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.per_message_latency_s = per_message_latency_s
        self._closed = False
        # deliveries popped round-robin for other consumers while one
        # consumer polls; drained before new broker pops
        self._pending: dict[str, list[Delivery]] = {}

    # -- topology -------------------------------------------------------------
    def declare_queue(self, name: str, **kw) -> None:
        with self._lock:
            self._b.declare_queue(name, **kw)

    def declare_fanout(self, exchange: str, queues: list[str]) -> None:
        with self._lock:
            self._b.declare_fanout(exchange, queues)

    def register_consumer(self, consumer_id: str, queue: str,
                          prefetch: Optional[int] = None) -> None:
        with self._cv:
            self._b.register_consumer(consumer_id, queue, prefetch)
            self._cv.notify_all()

    # -- data plane -------------------------------------------------------------
    def publish(self, msg: Message, block: bool = True,
                timeout: float = 10.0) -> bool:
        """Publish with reject-publish backpressure: blocks and retries
        until accepted (or timeout) when the queue is full."""
        if self.per_message_latency_s:
            time.sleep(self.per_message_latency_s)
        deadline = time.monotonic() + timeout
        while True:
            with self._cv:
                ok, _ = self._b.publish(msg)
                if ok:
                    self._cv.notify_all()
                    return True
            if not block or time.monotonic() >= deadline:
                return False
            time.sleep(0.005)

    def consume(self, consumer_id: str, timeout: float = 5.0
                ) -> Optional[Delivery]:
        """Blocking pull of the next delivery for this consumer."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while not self._closed:
                ch = self._b.channels.get(consumer_id)
                if ch is None:
                    return None
                d = self._next_for(consumer_id)
                if d is not None:
                    return d
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                self._cv.wait(timeout=min(remaining, 0.25))
        return None

    def _next_for(self, consumer_id: str) -> Optional[Delivery]:
        pend = self._pending.get(consumer_id)
        if pend:
            return pend.pop(0)
        ch = self._b.channels[consumer_id]
        if ch.window_available <= 0:
            return None
        # pump until this consumer gets one (round-robin may pick others
        # first; their deliveries stay pending on their channels)
        d = self._b.next_delivery(ch.queue)
        while d is not None and d.consumer_id != consumer_id:
            self._pending.setdefault(d.consumer_id, []).append(d)
            d = self._b.next_delivery(ch.queue)
        return d

    def ack(self, consumer_id: str, delivery_tag: int,
            multiple: bool = False) -> int:
        with self._cv:
            n = self._b.ack(consumer_id, delivery_tag, multiple)
            self._cv.notify_all()
            return n

    # -- fault injection -------------------------------------------------------
    def consumer_crash(self, consumer_id: str) -> int:
        """Kill a consumer: its unacked messages are redelivered (paper §6:
        'rare events will not be lost')."""
        with self._cv:
            self._pending.pop(consumer_id, None)
            n = self._b.consumer_crash(consumer_id)
            self._cv.notify_all()
            return n

    def queue_depth(self, name: str) -> int:
        with self._lock:
            return len(self._b.queues[name])

    def stats(self, name: str):
        with self._lock:
            return self._b.queues[name].stats

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
