"""Steering feedback channel: work sharing *with feedback* (paper pattern
#2) mapped onto the training loop — the HPC side publishes per-step
metrics/decisions to per-producer direct reply queues, closing the
edge↔HPC loop (LCLS 'recommend parameter changes while the sample is still
in the beam'; SNS 'adjust beam settings in minutes')."""

from __future__ import annotations

from typing import Iterable

from repro.core.broker import Message
from repro.streaming.rtbroker import RealtimeBroker


class SteeringFeedback:
    def __init__(self, broker: RealtimeBroker, producer_ids: Iterable[str]):
        self.broker = broker
        self.producer_ids = list(producer_ids)
        for pid in self.producer_ids:
            rq = f"reply:{pid}"
            broker.declare_queue(rq, control=True)
            broker.register_consumer(pid, rq)   # producer consumes its queue
        self.published = 0

    def reply_queue(self, pid: str) -> str:
        return f"reply:{pid}"

    def publish_step(self, step: int, loss: float, *,
                     backpressure: bool = False) -> None:
        """Direct-routed metric replies — one per producer, so each reply
        reaches exactly the producer it steers (paper §5.2: dedicated reply
        queues prevent misrouting)."""
        for pid in self.producer_ids:
            headers = {"step": step, "loss": float(loss),
                       "slow_down": bool(backpressure),
                       "speed_up": not backpressure}
            self.broker.publish(
                Message(routing_key=self.reply_queue(pid), size=256,
                        body=None, headers=headers, producer_id="trainer"),
                block=False)
            self.published += 1
