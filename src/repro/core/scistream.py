"""SciStream control plane (paper §3.2, §4.4 — the machinery behind the
PRS architecture).

What each paper section contributes here
----------------------------------------

* **§3.2 (SciStream)** — the three components and their trust model:

  - **S2UC** (user client, :class:`S2UC`) — brokers requests, gathers
    short-lived credentials, runs the inbound/outbound request
    sequence;
  - **S2CS** (control server, :class:`S2CS`, one per gateway node) —
    allocates local resources (port 5000 control + 5100-5110 streaming
    in the paper's pods) and launches data servers;
  - **S2DS** (data server, :class:`S2DS`) — the on-demand proxy
    bridging internal network and WAN; authenticates external peers by
    proxy certificate (:class:`ProxyCertificate`), internal peers by
    source address.

  The §3.2 handshake: S2UC contacts producer-side and consumer-side
  S2CS to negotiate parallel channels + bandwidth; on acceptance, S2DS
  instances launch, ports are assigned, a connection map is built
  (:attr:`StreamingSession.connection_map`) and the applications are
  signaled.  Data then flows producer → local proxy → overlay tunnel →
  remote proxy → consumer (:attr:`StreamingSession.hops`).

* **§4.4 (PRS deployment)** — the concrete CLI sequence the paper runs
  (``s2uc inbound-request`` returning ``(PROXY port, UID)``, then
  ``s2uc outbound-request``), reproduced end-to-end by
  :func:`establish_prs_session` on the paper's topology (producer-side
  S2CS at 198.51.100.1, consumer-side at 198.51.100.0), including the
  failure modes the control protocol guards (certificate mismatch,
  unknown UID, ``num_conn`` mismatch, port-range exhaustion).

Consumed by: :class:`repro.core.architectures.ProxiedStreaming` — a
negotiated :class:`StreamingSession` names the tunnel realization
(Stunnel's serialized single TLS flow with its hard 16-connection cap,
vs HAProxy's load-balanced pipe) whose contention resources the PRS hop
graph charges; exercised by ``tests/test_core_system.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Optional

CONTROL_PORT = 5000
STREAM_PORT_RANGE = (5100, 5110)

_uid_counter = itertools.count(1)


class SciStreamError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class ProxyCertificate:
    subject: str
    fingerprint: str

    @staticmethod
    def self_signed(subject: str) -> "ProxyCertificate":
        fp = hashlib.sha256(f"cert:{subject}".encode()).hexdigest()[:32]
        return ProxyCertificate(subject, fp)


@dataclasses.dataclass
class S2DS:
    """A launched on-demand proxy instance."""

    side: str                 # "producer" | "consumer"
    gateway_ip: str
    listen_port: int
    forward_ports: tuple[int, ...]
    num_conn: int
    session_uid: str


class S2CS:
    """Control server on one gateway node: port allocation + S2DS launch."""

    def __init__(self, gateway_ip: str,
                 cert: Optional[ProxyCertificate] = None) -> None:
        self.gateway_ip = gateway_ip
        self.cert = cert or ProxyCertificate.self_signed(gateway_ip)
        self._allocated: set[int] = set()
        self.data_servers: list[S2DS] = []

    def _alloc_port(self) -> int:
        lo, hi = STREAM_PORT_RANGE
        for p in range(lo, hi + 1):
            if p not in self._allocated:
                self._allocated.add(p)
                return p
        raise SciStreamError(
            f"S2CS@{self.gateway_ip}: streaming port range "
            f"{STREAM_PORT_RANGE} exhausted")

    def launch_s2ds(self, side: str, forward_ports: tuple[int, ...],
                    num_conn: int, session_uid: str) -> S2DS:
        if num_conn < 1:
            raise SciStreamError("num_conn must be >= 1")
        ds = S2DS(side=side, gateway_ip=self.gateway_ip,
                  listen_port=self._alloc_port(),
                  forward_ports=forward_ports, num_conn=num_conn,
                  session_uid=session_uid)
        self.data_servers.append(ds)
        return ds

    def release(self, session_uid: str) -> None:
        kept = []
        for ds in self.data_servers:
            if ds.session_uid == session_uid:
                self._allocated.discard(ds.listen_port)
            else:
                kept.append(ds)
        self.data_servers = kept


@dataclasses.dataclass
class StreamingSession:
    """Negotiated end-to-end overlay: the connection map of §3.2."""

    uid: str
    num_conn: int
    bandwidth_gbps: float
    consumer_proxy: S2DS
    producer_proxy: S2DS
    connection_map: list[tuple[str, str]]   # (producer endpoint, consumer endpoint)
    tunnel: str = "haproxy"

    @property
    def hops(self) -> list[str]:
        """producer → local proxy → remote proxy → consumer (3 transparent hops)."""
        return [
            "producer",
            f"{self.producer_proxy.gateway_ip}:{self.producer_proxy.listen_port}",
            f"{self.consumer_proxy.gateway_ip}:{self.consumer_proxy.listen_port}",
            "consumer",
        ]


class S2UC:
    """User client: runs the inbound/outbound request sequence of §4.4."""

    def __init__(self) -> None:
        self._pending: dict[str, dict] = {}
        self.sessions: dict[str, StreamingSession] = {}

    def inbound_request(self, *, server_cert: ProxyCertificate,
                        remote_ip: str, s2cs: S2CS,
                        receiver_ports: tuple[int, ...],
                        num_conn: int = 1) -> tuple[int, str]:
        """Create the consumer-side proxy. Returns (PROXY port, UID) exactly
        as the paper's CLI does — both feed the outbound request."""
        if server_cert.fingerprint != s2cs.cert.fingerprint:
            raise SciStreamError("consumer-side certificate mismatch")
        uid = f"uid-{next(_uid_counter):06d}"
        ds = s2cs.launch_s2ds("consumer", receiver_ports, num_conn, uid)
        self._pending[uid] = {
            "consumer_proxy": ds, "remote_ip": remote_ip, "num_conn": num_conn,
        }
        return ds.listen_port, uid

    def outbound_request(self, *, server_cert: ProxyCertificate,
                         remote_ip: str, s2cs: S2CS,
                         receiver_port: int, uid: str,
                         num_conn: int = 1,
                         bandwidth_gbps: float = 1.0,
                         tunnel: str = "haproxy") -> StreamingSession:
        """Create the producer-side proxy and seal the session."""
        if server_cert.fingerprint != s2cs.cert.fingerprint:
            raise SciStreamError("producer-side certificate mismatch")
        if uid not in self._pending:
            raise SciStreamError(f"unknown session UID {uid}")
        pend = self._pending.pop(uid)
        if pend["num_conn"] != num_conn:
            raise SciStreamError(
                f"num_conn mismatch: inbound {pend['num_conn']} vs outbound {num_conn}")
        cons: S2DS = pend["consumer_proxy"]
        if receiver_port != cons.listen_port:
            raise SciStreamError("outbound receiver_port must be the inbound PROXY port")
        prod = s2cs.launch_s2ds("producer", (receiver_port,), num_conn, uid)
        cmap = [
            (f"{prod.gateway_ip}:{prod.listen_port}+{c}",
             f"{cons.gateway_ip}:{cons.listen_port}+{c}")
            for c in range(num_conn)
        ]
        sess = StreamingSession(
            uid=uid, num_conn=num_conn, bandwidth_gbps=bandwidth_gbps,
            consumer_proxy=cons, producer_proxy=prod,
            connection_map=cmap, tunnel=tunnel)
        self.sessions[uid] = sess
        return sess

    def teardown(self, uid: str, producer_s2cs: S2CS, consumer_s2cs: S2CS) -> None:
        self.sessions.pop(uid, None)
        producer_s2cs.release(uid)
        consumer_s2cs.release(uid)


def provision_tenant_tunnels(tenants: int, *, num_conn: int = 1,
                             bandwidth_gbps: float = 1.0,
                             tunnel: str = "stunnel"
                             ) -> list[StreamingSession]:
    """Provision the per-tenant dedicated tunnel pairs of the
    multi-tenant DTS deployment model (paper §6's feasibility argument,
    control-plane side): each tenant runs the full §3.2 handshake
    against the *same* facility gateway pair, getting its own S2DS
    data path (the ``ttun:{t}`` resources the tenant-aware
    :class:`~repro.core.architectures.DirectStreaming` hop graph
    charges).

    This is where per-user DTS provisioning stops scaling in a very
    concrete way: every tenant's session allocates a streaming port on
    each gateway's S2CS, and the §3.2 port range (:data:`STREAM_PORT_RANGE`,
    11 ports) is exhausted after 11 tenants — the control plane refuses
    (:class:`SciStreamError`) long before the 64-tenant sweeps the
    shared-ingress architectures handle.  The data-plane simulator
    deliberately does *not* enforce this cap (so the §6 curves span the
    full sweep); the quantitative study reports it alongside the
    throughput crossover."""
    if tenants < 1:
        raise SciStreamError(f"tenants must be >= 1, got {tenants}")
    s2uc = S2UC()
    cons_s2cs = S2CS("198.51.100.0")
    prod_s2cs = S2CS("198.51.100.1")
    sessions = []
    for t in range(tenants):
        proxy_port, uid = s2uc.inbound_request(
            server_cert=cons_s2cs.cert, remote_ip=f"10.1.1.{100 + t}",
            s2cs=cons_s2cs, receiver_ports=(5672,), num_conn=num_conn)
        sessions.append(s2uc.outbound_request(
            server_cert=prod_s2cs.cert, remote_ip="198.51.100.0",
            s2cs=prod_s2cs, receiver_port=proxy_port, uid=uid,
            num_conn=num_conn, bandwidth_gbps=bandwidth_gbps,
            tunnel=tunnel))
    return sessions


def establish_prs_session(num_conn: int = 1, tunnel: str = "haproxy",
                          bandwidth_gbps: float = 1.0) -> StreamingSession:
    """Convenience: run the full §4.4 handshake on the paper's topology
    (producer-side S2CS at 198.51.100.1, consumer-side at 198.51.100.0)."""
    s2uc = S2UC()
    cons_s2cs = S2CS("198.51.100.0")
    prod_s2cs = S2CS("198.51.100.1")
    proxy_port, uid = s2uc.inbound_request(
        server_cert=cons_s2cs.cert, remote_ip="10.1.1.100",
        s2cs=cons_s2cs, receiver_ports=(5672,), num_conn=num_conn)
    return s2uc.outbound_request(
        server_cert=prod_s2cs.cert, remote_ip="198.51.100.0",
        s2cs=prod_s2cs, receiver_port=proxy_port, uid=uid,
        num_conn=num_conn, bandwidth_gbps=bandwidth_gbps, tunnel=tunnel)
