"""Streaming workload definitions (paper Table 1).

Three workloads drive every experiment in the paper:

* **Dstream** — Deleria/GRETA-like: ~KiB-range binary event batches. The paper
  fixes 2 KiB/event and 8 events/message => 16 KiB messages, ~32 Gbps detector
  rate, non-MPI parallel producers/consumers.
* **Lstream** — LCLS-like: ~1 MiB HDF5-formatted event messages, ~30 Gbps,
  MPI-launched producers/consumers.
* **generic** — 4 MiB binary, one item per message, 25 Gbps, MPI-based; used
  for the broadcast & gather pattern.

The classes here are consumed by both the discrete-event simulator
(:mod:`repro.core.simulator`) and the real-time ingest path
(:mod:`repro.streaming`), so the payload generators are deterministic.
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
from typing import Iterator

import numpy as np

KIB = 1024
MIB = 1024 * 1024
GBIT = 1e9  # network giga (decimal), as in "1 Gbps Ethernet"


class PayloadFormat(enum.Enum):
    BINARY = "binary"
    HDF5 = "hdf5"
    JSON = "json"


class Parallelism(enum.Enum):
    MPI = "mpi"
    NON_MPI = "non-mpi"


@dataclasses.dataclass(frozen=True)
class Workload:
    """Streaming characteristics of one workload (one column of Table 1)."""

    name: str
    payload_bytes: int           # bytes per *message* as streamed
    payload_format: PayloadFormat
    payload_element: str         # "events" | "variables"
    events_per_message: int      # 1 => one item per message
    event_bytes: int             # bytes per element (payload_bytes / events)
    data_rate_gbps: float        # nominal source data rate (detector-side)
    consumption_parallelism: Parallelism
    production_parallelism: Parallelism
    #: consumer-side parse+handle cost (seconds/message) on the Andes
    #: clients; None derives it from payload size at Dstream's per-byte rate
    consumer_proc_s: "float | None" = None

    @property
    def message_bits(self) -> int:
        return self.payload_bytes * 8

    def proc_time_s(self) -> float:
        """Per-message consumer processing time, used by both StreamSim
        engines (binary decode / HDF5 parse / 4 MiB handling)."""
        if self.consumer_proc_s is not None:
            return self.consumer_proc_s
        return 80e-6 * self.payload_bytes / 16384

    def messages_per_second_at_rate(self, gbps: float | None = None) -> float:
        """Message rate needed to sustain ``gbps`` (defaults to nominal)."""
        rate = self.data_rate_gbps if gbps is None else gbps
        return rate * GBIT / self.message_bits

    def payload(self, seed: int) -> bytes:
        """Deterministic pseudo-payload of exactly ``payload_bytes`` bytes.

        Uses a counter-mode SHA256 expansion so tests can assert integrity
        end-to-end without storing real detector data.
        """
        out = bytearray()
        counter = 0
        stem = f"{self.name}:{seed}".encode()
        while len(out) < self.payload_bytes:
            out += hashlib.sha256(stem + counter.to_bytes(8, "little")).digest()
            counter += 1
        return bytes(out[: self.payload_bytes])

    def payload_digest(self, seed: int) -> str:
        return hashlib.sha256(self.payload(seed)).hexdigest()

    def event_stream(self, seed: int, n_messages: int) -> Iterator[bytes]:
        for i in range(n_messages):
            yield self.payload(seed * 1_000_003 + i)


# --- Table 1 ----------------------------------------------------------------

DSTREAM = Workload(
    name="dstream",
    payload_bytes=16 * KIB,          # 8 events x 2 KiB (paper fixes these)
    payload_format=PayloadFormat.BINARY,
    payload_element="events",
    events_per_message=8,
    event_bytes=2 * KIB,
    data_rate_gbps=32.0,
    consumption_parallelism=Parallelism.NON_MPI,
    production_parallelism=Parallelism.NON_MPI,
    consumer_proc_s=80e-6,
)

LSTREAM = Workload(
    name="lstream",
    payload_bytes=1 * MIB,
    payload_format=PayloadFormat.HDF5,
    payload_element="events",
    events_per_message=1,            # one HDF5 file per message
    event_bytes=1 * MIB,
    data_rate_gbps=30.0,
    consumption_parallelism=Parallelism.MPI,
    production_parallelism=Parallelism.MPI,
    consumer_proc_s=1.2e-3,
)

GENERIC = Workload(
    name="generic",
    payload_bytes=4 * MIB,
    payload_format=PayloadFormat.BINARY,
    payload_element="variables",
    events_per_message=1,            # one item per message
    event_bytes=4 * MIB,
    data_rate_gbps=25.0,
    consumption_parallelism=Parallelism.MPI,
    production_parallelism=Parallelism.MPI,
    consumer_proc_s=3.0e-3,
)

WORKLOADS: dict[str, Workload] = {
    w.name: w for w in (DSTREAM, LSTREAM, GENERIC)
}


def get_workload(name: str) -> Workload:
    try:
        return WORKLOADS[name]
    except KeyError:
        raise KeyError(
            f"unknown workload {name!r}; options: {sorted(WORKLOADS)}"
        ) from None


def tokens_from_payload(payload: bytes, vocab_size: int, n_tokens: int) -> np.ndarray:
    """Deterministically map a streamed payload to a token sequence.

    This is the bridge the edge-to-HPC training integration uses: a streamed
    detector message becomes training tokens. (Synthetic, but deterministic so
    a redelivered message yields identical training data — required for the
    fault-tolerance guarantees tested in tests/test_streaming_ingest.py.)
    """
    raw = np.frombuffer(payload, dtype=np.uint8)
    if raw.size < n_tokens * 4:
        reps = int(np.ceil(n_tokens * 4 / max(raw.size, 1)))
        raw = np.tile(raw, reps)
    words = raw[: n_tokens * 4].view("<u4").astype(np.int64)
    return (words % np.int64(vocab_size)).astype(np.int32)
