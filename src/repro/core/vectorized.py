"""Vectorized batched StreamSim engine.

The reference engine in :mod:`repro.core.simulator` pushes one heap event
per message-hop, which is exact but caps interactive sweeps at ~10^5
messages.  This engine runs the same experiment as a *batched* discrete-
event simulation: whole message cohorts move through an architecture's hop
graph together, and every FIFO resource's busy intervals are resolved with
prefix-scan (``cumsum`` + ``maximum.accumulate``) recurrences instead of
per-message heap events.

Key ideas
---------

* **FIFO pipes as scans.**  For arrivals ``a_j`` (sorted) and hold times
  ``h_j``, the busy-interval recurrence ``e_j = max(a_j, e_{j-1}) + h_j``
  has the closed form ``e_j = H_j + max_{m<=j}(a_m - H_{m-1})`` with
  ``H = cumsum(h)`` — one ``maximum.accumulate`` per resource per cohort.
* **k-server pools as k interleaved scans.**  With near-uniform service
  times the FIFO pool recurrence ``e_j = max(a_j, e_{j-k}) + h_j`` splits
  into ``k`` independent pipe scans over strided sub-sequences.
* **Window generations.**  Publisher-confirm flow control couples message
  ``i`` to the confirm of message ``i - W``; messages are processed in
  per-producer *rounds* of ``SimParams.vec_round`` (a sub-multiple of the
  window), so every round is a feed-forward array computation.
* **Batch event loop.**  Each cohort leg (publish, delivery, reply) is an
  event keyed by its earliest arrival time at its next hop; one heap pop
  serves one hop for a whole cohort.  Cohorts therefore hit shared
  resources (NICs, tunnel, ingress, broker CPU pools) in close to true
  arrival order — the property that makes the FIFO carries honest — while
  the heap engine needs ~10^7 pops for what this loop does in ~10^3.
* **A batched broker pump.**  Queue deliveries release FIFO to the next
  consumer with an open basic.qos window (rotated round-robin, so load
  shifts toward less-congested consumers exactly when windows close, as
  in RabbitMQ); departs are gated on ack arrivals, and acks follow the
  broker's ack-multiple batching (every ``ack_batch`` deliveries, or
  immediately once the window is full).
* **Hop-graph slot alignment.**  Paths that differ only by optional
  broker-internal hops (queue homed on another node) are aligned on their
  longest common prefix/suffix of resource classes so shared bottlenecks
  are served in one merged batch per hop.

Fidelity
--------

The engine reproduces the heap engine's aggregate metrics (throughput,
median/p95 RTT, overhead ratios) to ~1% on most of the paper's operating
points (see tests/test_engine_parity.py); the two known exceptions are
DTS work-sharing throughput and DTS/PRS gather-leg RTTs, which sit within
~5-6% — both residuals trace to second-order FIFO-interleaving detail at
the saturated DSN NICs that batch serving cannot reproduce exactly.
Not modeled: reject-publish overflow and credit-flow confirm withholding —
the paper's configurations keep queue backlogs far below both limits
(bounded by the confirm windows) — and message redelivery (no consumer
crashes occur inside an engine run).
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional

import numpy as np

from repro.core.architectures import (
    Architecture, PathElement, ResourceSpec, make_architecture)
from repro.core.ds2hpc import ClusterInventory
from repro.core.simulator import (
    ENGINES, ExperimentSpec, RunResult, check_feasibility)

# ---------------------------------------------------------------------------
# Batched FIFO resources
# ---------------------------------------------------------------------------


def _fifo_scan(a: np.ndarray, h: np.ndarray, carry: float) -> np.ndarray:
    """End times for FIFO service: e_j = max(a_j, e_{j-1}) + h_j, with the
    server busy until ``carry`` before the first arrival."""
    a = np.maximum(a, carry)
    H = np.cumsum(h)
    return H + np.maximum.accumulate(a - (H - h))


class _VecResource:
    """Busy-interval state for one shared resource, served in batches."""

    __slots__ = ("spec", "_free_pipe", "_free_pool")

    def __init__(self, spec: ResourceSpec):
        self.spec = spec
        self._free_pipe = 0.0
        self._free_pool = (np.zeros(max(1, spec.servers))
                           if spec.kind == "pool" else None)

    def hold_times(self, nbytes: np.ndarray) -> np.ndarray:
        s = self.spec
        if s.kind == "pipe":
            return s.service_s + (nbytes / s.rate_Bps if s.rate_Bps else 0.0)
        return s.service_s + nbytes * s.per_byte_s

    def serve(self, t_arr: np.ndarray, nbytes: np.ndarray,
              jit: np.ndarray) -> np.ndarray:
        """FIFO-serve a batch (any order); returns per-message end times."""
        hold = self.hold_times(nbytes) * (1.0 + jit)
        order = np.argsort(t_arr, kind="stable")
        a, h = t_arr[order], hold[order]
        end_sorted = np.empty_like(a)
        if self.spec.kind == "pipe":
            end_sorted = _fifo_scan(a, h, self._free_pipe)
            self._free_pipe = float(end_sorted[-1])
        else:
            # k-server pool: k interleaved chains; earliest-free server
            # takes the next arrival (exact for near-uniform hold times)
            carry = np.sort(self._free_pool)
            k = carry.size
            n = a.size
            for c in range(min(k, n)):
                end_sorted[c::k] = _fifo_scan(a[c::k], h[c::k], carry[c])
                carry[c] = end_sorted[c + ((n - 1 - c) // k) * k]
            self._free_pool = carry
        out = np.empty_like(end_sorted)
        out[order] = end_sorted
        return out


# ---------------------------------------------------------------------------
# Hop-graph slot alignment
# ---------------------------------------------------------------------------


def _res_class(el: Optional[PathElement]) -> Optional[str]:
    if el is None or el.resource is None:
        return None
    return el.resource.split(":", 1)[0]


def _align_paths(paths: dict) -> tuple[dict, int]:
    """Pad each path's *middle* (between the longest common prefix and
    suffix of resource classes) with Nones so shared bottlenecks land on
    the same slot across path variants.  Returns ({key: padded}, n_slots).
    """
    sigs = {k: [_res_class(e) for e in p] for k, p in paths.items()}
    sig_list = list(sigs.values())
    min_len = min(len(s) for s in sig_list)
    lcp = 0
    while lcp < min_len and len({s[lcp] for s in sig_list}) == 1:
        lcp += 1
    lcs = 0
    while (lcs < min_len - lcp
           and len({s[len(s) - 1 - lcs] for s in sig_list}) == 1):
        lcs += 1
    max_mid = max(len(s) - lcp - lcs for s in sig_list)
    out = {}
    for k, p in paths.items():
        mid = list(p[lcp:len(p) - lcs])
        out[k] = (list(p[:lcp]) + mid + [None] * (max_mid - len(mid))
                  + list(p[len(p) - lcs:]))
    return out, lcp + max_mid + lcs


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class VectorizedStreamSim:
    """Batched engine; same constructor/run contract as ``StreamSim``."""

    def __init__(self, spec: ExperimentSpec,
                 inventory: Optional[ClusterInventory] = None,
                 arch: Optional[Architecture] = None):
        self.spec = spec
        self.p = spec.params
        self.inv = inventory or ClusterInventory()
        self.arch = arch or make_architecture(spec.arch, self.inv)
        self.arch.configure(spec.n_producers, spec.n_consumers)
        check_feasibility(self.arch, spec)
        self.rng = np.random.default_rng(self.p.seed)
        self.resources = {k: _VecResource(s)
                          for k, s in self.arch.resources.items()}
        self._proc_s = (self.p.consumer_proc_s
                        if self.p.consumer_proc_s is not None
                        else spec.workload.proc_time_s())
        self.n_events = 0
        self._path_cache: dict = {}
        self._align_cache: dict = {}
        self._channels: dict = {}
        self._queues: dict = {}
        self._chan_queue: dict = {}
        self._heap: list = []
        self._seq = itertools.count()
        #: how far past the next event's key a batch may serve ahead: 0 is
        #: strictest global time ordering (max fidelity, max fragmentation);
        #: auto mode widens with client count — the more concurrent flows,
        #: the less aggregate metrics depend on exact cross-flow ordering
        if self.p.vec_horizon_s is not None:
            self._slack = self.p.vec_horizon_s
        else:
            self._slack = max(1e-3, 1e-3 * (spec.n_producers
                                            + spec.n_consumers) / 16.0)

    # -- helpers ---------------------------------------------------------------
    def _jit(self, n: int) -> np.ndarray:
        j = self.p.jitter
        return self.rng.uniform(-j, j, n) if j else np.zeros(n)

    def _recv_latency(self, size: int) -> float:
        return self.arch.recv_latency_s(size)

    def _chan(self, cid: int) -> dict:
        """Broker-channel state: per-delivery seen/ack times (the ack
        clock), the ack-multiple coverage cursor, and the consumer's
        serial-processing carry."""
        ch = self._channels.get(cid)
        if ch is None:
            ch = {"assigned": 0, "acked": 0, "seen": np.zeros(0),
                  "ack_time": np.zeros(0), "free": 0.0,
                  "since": 0, "last_tag": 0}
            self._channels[cid] = ch
        return ch

    @staticmethod
    def _chan_grow(ch: dict, extra: int) -> None:
        """Amortized growth of the per-delivery bookkeeping arrays."""
        need = ch["assigned"] + extra
        if ch["seen"].size < need:
            cap = max(need, 2 * ch["seen"].size, 64)
            for f in ("seen", "ack_time"):
                a = np.full(cap, np.nan)
                a[:ch[f].size] = ch[f]
                ch[f] = a

    def _resolve_paths(self, flow: str, combos: np.ndarray):
        """Per-combo aligned paths + member indices for one cohort leg."""
        ctor = getattr(self.arch, flow)
        uniq, inv = np.unique(combos, axis=0, return_inverse=True)
        inv = inv.ravel()
        raw = {}
        for u, key in enumerate(map(tuple, uniq)):
            ck = (flow, key)
            if ck not in self._path_cache:
                self._path_cache[ck] = ctor(*key)
            raw[u] = self._path_cache[ck]
        ak = (flow, tuple(map(tuple, uniq)))
        if ak not in self._align_cache:
            self._align_cache[ak] = _align_paths(raw)
        aligned, n_slots = self._align_cache[ak]
        idx_by = {u: np.nonzero(inv == u)[0] for u in aligned}
        return aligned, idx_by, n_slots

    # -- batch event loop ------------------------------------------------------
    def _push_transit(self, t0: np.ndarray, size: int, flow: str,
                      combos: np.ndarray,
                      on_done: Optional[Callable[[np.ndarray], None]] = None,
                      on_part: Optional[Callable[[np.ndarray, np.ndarray],
                                                 None]] = None) -> None:
        """Queue a cohort to traverse ``flow``'s hop graph, one hop per
        event pop, interleaved with every other in-flight cohort.

        ``on_done(times)`` fires once, when every member has exited;
        ``on_part(member_indices, times)`` instead fires per finishing
        sub-batch, in event order — use it when downstream state (ack
        clocks) must advance as individual messages land."""
        aligned, idx_by, n_slots = self._resolve_paths(flow, combos)
        t0 = np.asarray(t0, dtype=float)
        n = t0.size
        inv = np.empty(n, dtype=int)
        for u, idx in idx_by.items():
            inv[idx] = u
        cohort = {"out": np.empty(n), "remaining": n, "on_done": on_done,
                  "on_part": on_part, "aligned": aligned, "size": size}
        batch = {"t": t0.copy(), "members": np.arange(n), "inv": inv,
                 "slot": 0, "n_slots": n_slots, "cohort": cohort}
        self._push(batch)

    def _push(self, batch: dict) -> None:
        heapq.heappush(self._heap,
                       (float(batch["t"].min()), next(self._seq), batch))

    def _serve_slot(self, batch: dict) -> None:
        """Serve one hop for the head of one cohort batch.

        Only members whose current time is at or before the next event's
        key are served — the tail is split back into the heap — so every
        resource sees its customers in near-global arrival order even when
        cohort spans overlap.  Members at the same hop hitting the same
        resource instance (across path variants) are merged into one FIFO
        batch."""
        if self._heap:
            horizon = self._heap[0][0] + self._slack
            head = batch["t"] <= horizon
            if not head.all():
                if not head.any():
                    head[np.argmin(batch["t"])] = True
                tail = {"t": batch["t"][~head],
                        "members": batch["members"][~head],
                        "inv": batch["inv"][~head],
                        "slot": batch["slot"],
                        "n_slots": batch["n_slots"],
                        "cohort": batch["cohort"]}
                self._push(tail)
                batch = {"t": batch["t"][head],
                         "members": batch["members"][head],
                         "inv": batch["inv"][head],
                         "slot": batch["slot"],
                         "n_slots": batch["n_slots"],
                         "cohort": batch["cohort"]}
        cohort = batch["cohort"]
        t, s = batch["t"], batch["slot"]
        aligned = cohort["aligned"]
        size = cohort["size"]
        inv = batch["inv"]
        if len(aligned) == 1:
            groups = [(0, np.arange(t.size))]
        else:
            order = np.argsort(inv, kind="stable")
            uniq, starts = np.unique(inv[order], return_index=True)
            bounds = np.append(starts, inv.size)
            groups = [(u, order[bounds[i]:bounds[i + 1]])
                      for i, u in enumerate(uniq)]
        by_instance: dict[str, list] = {}
        for u, idx in groups:
            el = aligned[u][s]
            if el is None:
                continue
            if el.resource is None:
                t[idx] += el.latency_s
                continue
            by_instance.setdefault(el.resource, []).append((idx, el))
        for key, parts in by_instance.items():
            if len(parts) == 1:
                idx, el = parts[0]
                nbytes = size * el.byte_factor + el.extra_bytes
                lat = el.latency_s
            else:
                idx = np.concatenate([p[0] for p in parts])
                nbytes = np.concatenate([
                    np.full(p[0].size, size * p[1].byte_factor
                            + p[1].extra_bytes) for p in parts])
                lat = np.concatenate([
                    np.full(p[0].size, p[1].latency_s) for p in parts])
            t[idx] = (self.resources[key].serve(
                t[idx], nbytes, self._jit(idx.size)) + lat)
            self.n_events += idx.size
        batch["slot"] += 1
        if batch["slot"] < batch["n_slots"]:
            self._push(batch)
        else:
            if cohort["on_part"] is not None:
                cohort["on_part"](batch["members"], t)
            cohort["out"][batch["members"]] = t
            cohort["remaining"] -= t.size
            if cohort["remaining"] == 0 and cohort["on_done"] is not None:
                cohort["on_done"](cohort["out"])

    def _drain(self) -> None:
        while self._heap:
            key, _, batch = heapq.heappop(self._heap)
            # honor the same safety caps the heap engine enforces
            if (self.n_events > self.p.max_events
                    or key > self.p.max_sim_time):
                self._heap.clear()
                break
            self._serve_slot(batch)

    def _drain_all(self) -> None:
        """Drain the event heap; when only unflushed batch acks hold back
        window-waiting deliveries (the tail of a run), force-flush them —
        the heap engine's expected-consumed flush — and keep draining."""
        while True:
            self._drain()
            flushed = []
            for c, ch in self._channels.items():
                if ch["last_tag"] > ch["acked"]:
                    j = np.arange(ch["acked"], ch["last_tag"])
                    if not np.isfinite(ch["seen"][j]).all():
                        continue
                    ch["ack_time"][j] = (ch["seen"][j]
                                         + self.arch.control_latency_s())
                    ch["acked"] = ch["last_tag"]
                    ch["since"] = 0
                    if c in self._chan_queue:
                        flushed.append(self._chan_queue[c])
            if not flushed:
                return
            self._pump_queues(flushed)
            if not self._heap:
                return

    # -- prefetch-windowed delivery (the batched broker pump) ------------------
    def _deliver_queue(self, qkey, consumers, t_ready: np.ndarray,
                       member_idx: np.ndarray, combos_fn: Callable,
                       size: int, flow: str, consumer: bool, recv: float,
                       on_seen: Callable) -> None:
        """Enqueue a cohort on one broker queue and pump it through
        ``flow``.

        Deliveries leave the queue in FIFO order; each is assigned to the
        next consumer *with an open basic.qos window* in rotated
        round-robin order (the heap broker's ``next_delivery``), so load
        shifts toward faster/less-congested consumers exactly when windows
        close.  A delivery's depart time is gated on the ack that freed
        its window slot (acks are ack-multiple: a seen message acks every
        lower delivery tag).  ``combos_fn(member_idx, cons)`` builds the
        per-message path-constructor arguments once consumers are known;
        ``on_seen(member_idx, seen_times, cons)`` fires per landed batch —
        partial cohorts are normal."""
        cohort = {"combos_fn": combos_fn, "size": size, "flow": flow,
                  "consumer": consumer, "recv": recv, "on_seen": on_seen}
        q = self._queues.get(qkey)
        if q is None:
            q = {"consumers": [int(c) for c in consumers], "pending": []}
            self._queues[qkey] = q
            for c in q["consumers"]:
                self._chan_queue[c] = qkey
        o = np.argsort(t_ready, kind="stable")
        q["pending"].append({"cohort": cohort, "idx": member_idx[o],
                             "t": t_ready[o], "pos": 0})
        self._pump_queues([qkey])

    def _pump_queues(self, qkeys) -> None:
        """Release every window-admissible pending delivery on the given
        queues and push the released groups as transit batches."""
        P = max(1, self.p.prefetch)
        releases: dict[int, list] = {}
        for qk in dict.fromkeys(qkeys):
            q = self._queues[qk]
            ids = q["consumers"]
            while q["pending"]:
                seg = q["pending"][0]
                n_rem = seg["idx"].size - seg["pos"]
                k = len(ids)
                caps = {c: P - (self._chan(c)["assigned"]
                                - self._chan(c)["acked"]) for c in ids}
                # fast path: every window stays open through a strict
                # round-robin split of the whole segment remainder
                if all(caps[ids[r]] >= (n_rem - r + k - 1) // k
                       for r in range(k)):
                    sl = slice(seg["pos"], seg["pos"] + n_rem)
                    t_sl, m_sl = seg["t"][sl], seg["idx"][sl]
                    cons = np.array(ids)[np.arange(n_rem) % k]
                    j_all = np.empty(n_rem, dtype=int)
                    depart = np.empty(n_rem)
                    for r, c in enumerate(ids):
                        pos = np.arange(r, n_rem, k)
                        ch = self._chan(c)
                        self._chan_grow(ch, pos.size)
                        j = ch["assigned"] + np.arange(pos.size)
                        gate = np.full(pos.size, -np.inf)
                        m_g = j >= P
                        gate[m_g] = ch["ack_time"][j[m_g] - P]
                        j_all[pos] = j
                        depart[pos] = np.maximum(t_sl[pos], gate)
                        ch["assigned"] += pos.size
                    q["consumers"] = ids = ids[n_rem % k:] + ids[:n_rem % k]
                    releases.setdefault(id(seg["cohort"]), []).append(
                        (seg["cohort"], m_sl, cons, j_all, depart))
                    seg["pos"] += n_rem
                    q["pending"].pop(0)
                    continue
                # slow path: per message, next consumer with an open
                # window.  Released in small chunks so ack arrivals (the
                # commits that re-pump this queue) interleave with the
                # assignment like they do in the heap engine — releasing a
                # whole segment at once against a frozen ack clock
                # over-steals toward whichever windows happen to be open.
                chunk = max(1, self.p.ack_batch)
                open_ids = [c for c in ids if caps[c] > 0]
                rel = []
                oi = 0
                while (seg["pos"] < seg["idx"].size and len(rel) < chunk
                       and open_ids):
                    chosen = open_ids[oi % len(open_ids)]
                    caps[chosen] -= 1
                    if caps[chosen] <= 0:
                        open_ids.remove(chosen)
                    else:
                        oi += 1
                    ids.remove(chosen)
                    ids.append(chosen)
                    ch = self._chan(chosen)
                    self._chan_grow(ch, 1)
                    j = ch["assigned"]
                    ch["assigned"] += 1
                    gate = ch["ack_time"][j - P] if j >= P else -np.inf
                    rel.append((seg["idx"][seg["pos"]], chosen, j,
                                max(seg["t"][seg["pos"]], gate)))
                    seg["pos"] += 1
                if rel:
                    releases.setdefault(id(seg["cohort"]), []).append(
                        (seg["cohort"],
                         np.array([r[0] for r in rel]),
                         np.array([r[1] for r in rel]),
                         np.array([r[2] for r in rel]),
                         np.array([r[3] for r in rel])))
                if seg["pos"] == seg["idx"].size:
                    q["pending"].pop(0)
                # leave after one slow-path chunk: the commits of what was
                # just released re-pump this queue with a fresh ack clock
                break
        for parts in releases.values():
            cohort = parts[0][0]
            idx = np.concatenate([p[1] for p in parts])
            cons = np.concatenate([p[2] for p in parts])
            j_all = np.concatenate([p[3] for p in parts])
            depart = np.concatenate([p[4] for p in parts])
            self._push_transit(
                depart, cohort["size"], cohort["flow"],
                cohort["combos_fn"](idx, cons),
                on_part=lambda members, t, cohort=cohort, idx=idx,
                cons=cons, j_all=j_all:
                    self._commit(cohort, idx[members], j_all[members],
                                 cons[members], t))

    def _commit(self, cohort: dict, cidx: np.ndarray, j: np.ndarray,
                chan: np.ndarray, t_land: np.ndarray) -> None:
        """Some released deliveries landed: run the consumer processing
        chains (or stamp producer receive times), advance the channels' ack
        clocks (basic.ack multiple=True — a seen message acks every lower
        tag), and pump deliveries the freed window slots now admit."""
        seen = np.empty_like(t_land)
        recv = cohort["recv"]
        ctrl = self.arch.control_latency_s()
        touched = []
        for c in np.unique(chan):
            m = np.nonzero(chan == c)[0]
            ch = self._chan(c)
            if cohort["consumer"]:
                # serial parse/handle chain on the consumer client
                o = m[np.argsort(t_land[m], kind="stable")]
                proc = self._proc_s * (1.0 + self._jit(o.size))
                ends = _fifo_scan(t_land[o] + recv, proc, ch["free"])
                seen[o] = ends
                ch["free"] = float(ends[-1])
            else:
                seen[m] = t_land[m] + recv
            ch["seen"][j[m]] = seen[m]
            # batched acks (ack-multiple every ack_batch deliveries, or
            # immediately once the basic.qos window is full)
            B = max(1, self.p.ack_batch)
            P = max(1, self.p.prefetch)
            for mi in m[np.argsort(seen[m], kind="stable")]:
                ch["last_tag"] = max(ch["last_tag"], int(j[mi]) + 1)
                ch["since"] += 1
                if (ch["since"] >= B
                        or ch["assigned"] - ch["acked"] >= P):
                    if ch["last_tag"] > ch["acked"]:
                        ch["ack_time"][ch["acked"]:ch["last_tag"]] = \
                            seen[mi] + ctrl
                        ch["acked"] = ch["last_tag"]
                    ch["since"] = 0
            touched.append(c)
        cohort["on_seen"](cidx, seen, chan)
        self._pump_queues([self._chan_queue[c] for c in touched])

    # -- main ------------------------------------------------------------------
    def run(self) -> RunResult:
        pat = self.spec.pattern
        if pat in ("work_sharing", "feedback"):
            return self._run_work(feedback=(pat == "feedback"))
        if pat in ("broadcast", "broadcast_gather"):
            return self._run_broadcast(gather=(pat == "broadcast_gather"))
        raise ValueError(f"unknown pattern {pat!r}")

    # -- work sharing (+ feedback) --------------------------------------------
    def _run_work(self, feedback: bool) -> RunResult:
        spec, p, inv = self.spec, self.p, self.inv
        nP, nC = spec.n_producers, spec.n_consumers
        per_producer = spec.total_messages // nP
        size = spec.workload.payload_bytes
        flush = self.arch.client_flush_s()
        ctrl = self.arch.control_latency_s()
        W = max(2, min(p.confirm_window, p.window_bytes // size))

        nq = min(p.n_work_queues, nC)
        # declare order matches the heap engine: work queues first (homes
        # round-robin from 0), then per-producer reply queues
        q_home = np.arange(nq) % inv.n_dsn
        reply_home = (nq + np.arange(nP)) % inv.n_dsn
        q_consumers = [np.arange(nC)[np.arange(nC) % nq == q]
                       for q in range(nq)]

        pr_node = np.arange(nP) % inv.n_producer_nodes
        pr_bnode = np.arange(nP) % inv.n_dsn
        c_node = np.arange(nC) % inv.n_consumer_nodes
        c_bnode = (np.arange(nC) + 1) % inv.n_dsn

        i_idx = np.broadcast_to(np.arange(per_producer), (nP, per_producer))
        pr_idx = np.broadcast_to(np.arange(nP)[:, None], (nP, per_producer))
        msg_q = (pr_idx + i_idx) % nq

        confirms = np.zeros((nP, per_producer))
        pub_start = np.zeros((nP, per_producer))
        consume_t = np.full(nP * per_producer, np.nan)
        rtts = np.full(nP * per_producer, np.nan) if feedback else None
        recv_req = self._recv_latency(size)
        reply_size = max(1, int(size * p.reply_factor))
        recv_rep = self._recv_latency(reply_size)

        R = max(1, min(W, p.vec_round))
        n_rounds = -(-per_producer // R)
        pub_done = np.zeros(n_rounds, dtype=bool)
        state = {"frontier": 0, "next_launch": 0}

        def gate_round(r: int) -> int:
            """Last publish round whose confirms gate round ``r``'s sends
            (message (r+1)*R-1 waits on the confirm of that index - W)."""
            return ((r + 1) * R - 1 - W) // R

        def advance_pubs() -> None:
            while (state["frontier"] < n_rounds
                   and pub_done[state["frontier"]]):
                state["frontier"] += 1
            while (state["next_launch"] < n_rounds
                   and gate_round(state["next_launch"]) < state["frontier"]):
                r = state["next_launch"]
                state["next_launch"] += 1
                launch_pub(r)

        def launch_pub(r: int) -> None:
            lo, hi = r * R, min((r + 1) * R, per_producer)
            i_blk = np.arange(lo, hi)
            gate = np.zeros((nP, i_blk.size))
            m_g = i_blk >= W
            gate[:, m_g] = confirms[:, i_blk[m_g] - W]
            s_blk = gate + flush
            pub_start[:, i_blk] = s_blk
            flat_pr = pr_idx[:, i_blk].ravel()
            flat_i = i_idx[:, i_blk].ravel()
            flat_q = msg_q[:, i_blk].ravel()
            combos = np.stack([pr_node[flat_pr], pr_bnode[flat_pr],
                               q_home[flat_q]], axis=1)

            def part(members: np.ndarray, t_enq: np.ndarray) -> None:
                # messages enqueue (and confirm, and become deliverable)
                # as they land — not when the whole round has finished
                confirms[flat_pr[members], flat_i[members]] = t_enq + ctrl
                gidx = (flat_pr[members] * per_producer
                        + flat_i[members])
                launch_del(gidx, flat_q[members], t_enq)

            def done(_t: np.ndarray) -> None:
                pub_done[r] = True
                advance_pubs()

            self._push_transit(s_blk.ravel(), size, "publish_path", combos,
                               on_done=done, on_part=part)

        def launch_del(gidx, qs, t_enq) -> None:
            # members are global message indices (pr * per_producer + i)
            for q in range(nq):
                m = np.nonzero(qs == q)[0]
                if m.size == 0:
                    continue

                def combos_fn(mem, cons, q=q):
                    return np.stack([c_bnode[cons],
                                     np.full(cons.size, q_home[q]),
                                     c_node[cons]], axis=1)

                def on_seen(mem, t_done, cons):
                    consume_t[mem] = t_done
                    if feedback:
                        launch_reply(mem, t_done, cons)

                self._deliver_queue(
                    ("work", q), q_consumers[q], t_enq[m], gidx[m],
                    combos_fn, size, "delivery_path", consumer=True,
                    recv=recv_req, on_seen=on_seen)

        def launch_reply(members, t_done, cons) -> None:
            # members are global message indices; producer = index // n
            pr_m = members // per_producer
            combos = np.stack([c_node[cons], c_bnode[cons],
                               reply_home[pr_m]], axis=1)

            def part(sub: np.ndarray, t_renq: np.ndarray) -> None:
                prs = pr_m[sub]
                for pr in np.unique(prs):
                    pos = np.nonzero(prs == pr)[0]

                    def combos_fn(mem, _cons, pr=pr):
                        return np.broadcast_to(
                            [reply_home[pr], pr_bnode[pr], pr_node[pr]],
                            (mem.size, 3))

                    def on_seen(mem, t_seen, _cons):
                        rtts[mem] = t_seen - pub_start.ravel()[mem]

                    self._deliver_queue(
                        ("reply", int(pr)), [nC + int(pr)], t_renq[pos],
                        members[sub[pos]], combos_fn, reply_size,
                        "reply_delivery_path", consumer=False,
                        recv=recv_rep, on_seen=on_seen)

            self._push_transit(t_done, reply_size, "reply_publish_path",
                               combos, on_part=part)

        advance_pubs()
        self._drain_all()
        return self._result(consume_t, rtts, pub_start.ravel())

    # -- broadcast (+ gather) --------------------------------------------------
    def _run_broadcast(self, gather: bool) -> RunResult:
        spec, p, inv = self.spec, self.p, self.inv
        nC = spec.n_consumers
        assert spec.n_producers == 1, "broadcast patterns use one producer"
        per_producer = spec.total_messages  # // nP with nP == 1
        size = spec.workload.payload_bytes
        flush = self.arch.client_flush_s()
        ctrl = self.arch.control_latency_s()
        W = max(2, min(p.confirm_window, p.window_bytes // size))

        bq_home = np.arange(nC) % inv.n_dsn        # bq:c declared in order
        gather_home = nC % inv.n_dsn               # declared after the bqs
        pnode, pbnode = 0 % inv.n_producer_nodes, 0
        c_node = np.arange(nC) % inv.n_consumer_nodes
        c_bnode = (np.arange(nC) + 1) % inv.n_dsn

        confirms = np.zeros(per_producer)
        pub_start = np.zeros(per_producer)
        consume_t = np.full(per_producer * nC, np.nan)
        rtts = np.full(per_producer * nC, np.nan) if gather else None
        recv_req = self._recv_latency(size)
        reply_size = max(1, int(size * p.reply_factor))
        recv_rep = self._recv_latency(reply_size)

        R = max(1, min(W, p.vec_round))
        n_rounds = -(-per_producer // R)
        pub_done = np.zeros(n_rounds, dtype=bool)
        state = {"frontier": 0, "next_launch": 0}

        def gate_round(r: int) -> int:
            return ((r + 1) * R - 1 - W) // R

        def advance_pubs() -> None:
            while (state["frontier"] < n_rounds
                   and pub_done[state["frontier"]]):
                state["frontier"] += 1
            while (state["next_launch"] < n_rounds
                   and gate_round(state["next_launch"]) < state["frontier"]):
                r = state["next_launch"]
                state["next_launch"] += 1
                launch_pub(r)

        def launch_pub(r: int) -> None:
            lo, hi = r * R, min((r + 1) * R, per_producer)
            i_blk = np.arange(lo, hi)
            gate = np.zeros(i_blk.size)
            m_g = i_blk >= W          # rounds can straddle the window edge
            gate[m_g] = confirms[i_blk[m_g] - W]
            s_blk = gate + flush
            pub_start[i_blk] = s_blk
            # a fanout publish transits once, to the exchange's home node 0
            combos = np.broadcast_to([pnode, pbnode, 0], (i_blk.size, 3))

            def part(members: np.ndarray, t_enq: np.ndarray) -> None:
                confirms[i_blk[members]] = t_enq + ctrl
                launch_del(i_blk[members], t_enq)

            def done(_t: np.ndarray) -> None:
                pub_done[r] = True
                advance_pubs()

            self._push_transit(s_blk, size, "publish_path", combos,
                               on_done=done, on_part=part)

        def launch_del(i_part, t_enq) -> None:
            # replicate to every per-consumer queue; deliver each copy
            for c in range(nC):
                gidx_c = c * per_producer + i_part

                def combos_fn(members, cons, c=c):
                    return np.broadcast_to(
                        [c_bnode[c], bq_home[c], c_node[c]],
                        (members.size, 3))

                def on_seen(members, t_done, cons, c=c):
                    consume_t[members] = t_done
                    if gather:
                        launch_reply(members, t_done, c)

                self._deliver_queue(
                    ("bq", c), [c], t_enq, gidx_c, combos_fn, size,
                    "delivery_path", consumer=True, recv=recv_req,
                    on_seen=on_seen)

        def launch_reply(members, t_done, c) -> None:
            # members are global copy indices (c * per_producer + i)
            combos = np.broadcast_to(
                [c_node[c], c_bnode[c], gather_home], (members.size, 3))

            def on_enq(t_renq: np.ndarray) -> None:
                def combos_fn(sub_members, _cons):
                    return np.broadcast_to(
                        [gather_home, pbnode, pnode], (sub_members.size, 3))

                def on_seen(sub_members, t_seen, _cons):
                    rtts[sub_members] = (
                        t_seen - pub_start[sub_members % per_producer])

                self._deliver_queue(
                    ("gather",), [nC], t_renq, members, combos_fn,
                    reply_size, "reply_delivery_path", consumer=False,
                    recv=recv_rep, on_seen=on_seen)

            self._push_transit(t_done, reply_size, "reply_publish_path",
                               combos, on_done=on_enq)

        advance_pubs()
        self._drain_all()
        return self._result(consume_t, rtts, pub_start)

    # -- shared result assembly ------------------------------------------------
    def _result(self, consume_t: np.ndarray, rtts: Optional[np.ndarray],
                pub_start: np.ndarray) -> RunResult:
        consume_t = consume_t[np.isfinite(consume_t)]
        r = (rtts[np.isfinite(rtts)] if rtts is not None
             else np.zeros(0))
        top = float(consume_t.max()) if consume_t.size else 0.0
        if r.size:
            top = max(top, float(r.max()))
        return RunResult(
            spec=self.spec, feasible=True,
            consume_times=consume_t,
            rtts=r,
            publish_starts=np.sort(pub_start),
            rejected_publishes=0, redelivered=0,
            sim_time=top, n_events=self.n_events)


ENGINES["vectorized"] = VectorizedStreamSim
