"""Vectorized batched StreamSim engine (the default engine).

The reference engine in :mod:`repro.core.simulator` pushes one heap event
per message-hop, which is exact but caps interactive sweeps at ~10^5
messages.  This engine runs the same experiment as a *batched* discrete-
event simulation: whole message cohorts move through an architecture's hop
graph together, and every FIFO resource's busy intervals are resolved with
prefix-scan (``cumsum`` + ``maximum.accumulate``) recurrences instead of
per-message heap events.

Key ideas
---------

* **FIFO pipes as scans.**  For arrivals ``a_j`` (sorted) and hold times
  ``h_j``, the busy-interval recurrence ``e_j = max(a_j, e_{j-1}) + h_j``
  has the closed form ``e_j = H_j + max_{m<=j}(a_m - H_{m-1})`` with
  ``H = cumsum(h)`` — one ``maximum.accumulate`` per resource per cohort.
* **k-server pools as k interleaved scans.**  With near-uniform service
  times the FIFO pool recurrence ``e_j = max(a_j, e_{j-k}) + h_j`` splits
  into ``k`` independent pipe scans over strided sub-sequences.
* **Window generations.**  Publisher-confirm flow control couples message
  ``i`` to the confirm of message ``i - W``; messages are processed in
  per-producer *rounds* of ``SimParams.vec_round`` (a sub-multiple of the
  window), so every round is a feed-forward array computation.
* **Batch event loop.**  Each cohort leg (publish, delivery, reply) is an
  event keyed by its earliest arrival time at its next hop; one heap pop
  serves one hop for a whole cohort.  Cohorts therefore hit shared
  resources (NICs, tunnel, ingress, broker CPU pools) in close to true
  arrival order — the property that makes the FIFO carries honest — while
  the heap engine needs ~10^7 pops for what this loop does in ~10^3.
* **A batched broker pump.**  Queue deliveries release FIFO to the next
  consumer with an open basic.qos window (rotated round-robin, so load
  shifts toward less-congested consumers exactly when windows close, as
  in RabbitMQ); departs are gated on ack arrivals, and acks follow the
  broker's ack-multiple batching (every ``ack_batch`` deliveries, or
  immediately once the window is full).
* **Batched credit flow.**  Each queue tracks its un-drained backlog with
  an enqueue counter and a min-heap of release (depart) times.  When a
  cohort's enqueues push the backlog past the RabbitMQ credit threshold
  (``credit_flow_default_credit x publishers``, as in the heap broker),
  those members' publisher confirms are *withheld*: they resolve only
  once the batched pump has drained the queue back to half the threshold,
  at the depart time that crossed the resume mark (+ control latency).
  Withheld confirms stall the publish-round frontier exactly like the
  heap engine's channel blocking.
* **Reject-publish overflow as re-injection rounds.**  When a queue (or
  any fanout target, atomically) is at its byte cap at a member's arrival
  time, the publish is rejected and the member re-enters the publish
  path as a retry cohort after ``publish_retry_s`` — the producer
  re-publish backoff — repeating until the drain admits it.  Reply
  publishes get the same treatment on reply/gather queues.
* **Lane-resolved flow control.**  In stacked multi-seed execution every
  piece of flow-control state is per-lane: credit backlogs, depart
  cursors (one min-heap per lane, keyed by that lane's own clock),
  byte-capped admission, reject-retry cadences, deferred-confirm
  resume clocks and the rejected/blocked counters.  Scheduling stays
  the pilot's (a member joins a retry cohort iff lane 0 rejected it),
  but each lane's admission arithmetic is the exact solo sequence run
  against its own clocks — so overflow-regime cells stack, and each
  lane's counters are its own, not clones of the pilot's.
* **Utilization-triggered finer interleaving.**  A static bottleneck
  analysis of the hop graph estimates each shared DSN-side pipe's
  (``dsn_*``, ``tunnel``) utilization at the configured demand.  When one
  is saturated and few flows are in play (ordering detail then matters
  most), auto mode shrinks ``vec_round`` and ``vec_horizon_s`` so cohorts
  interleave at close to per-message granularity through the contended
  resource.  Explicit ``vec_round``/``vec_horizon_s`` settings are always
  honored.
* **Hop-graph slot alignment.**  Paths that differ only by optional
  broker-internal hops (queue homed on another node) are aligned on their
  longest common prefix/suffix of resource classes so shared bottlenecks
  are served in one merged batch per hop.

Fidelity
--------

The engine reproduces the heap engine's aggregate metrics (throughput,
median/p95 RTT, overhead ratios) to ~1% on most of the paper's operating
points, and to <=3% on the previously-documented outliers (DTS
work-sharing throughput, DTS feedback RTT, PRS gather RTT) thanks to the
utilization-triggered interleaving — see tests/test_engine_parity.py.
Credit-flow confirm withholding and reject-publish overflow (with the
producer re-publish backoff) *are* modeled, in batched form, and parity
in the overflow regime (nonzero ``rejected_publishes``, active channel
blocking) is enforced by the overflow block of the parity suite.  Still
not modeled: message redelivery (no consumer crashes occur inside an
engine run).
"""

from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Iterable, Iterator, Optional, Sequence

import numpy as np

from repro.core.architectures import (
    Architecture, PathElement, ResourceSpec, make_architecture)
from repro.core.broker import ClassicQueue
from repro.core.ds2hpc import ClusterInventory
from repro.core.simulator import (
    ENGINES, ExperimentSpec, InfeasibleConfiguration, RunResult,
    check_feasibility)

#: RabbitMQ credit_flow_default_credit, shared with the heap broker model
FLOW_CREDIT = ClassicQueue.FLOW_CREDIT

#: shrink vec_round/vec_horizon_s (auto mode) when a shared DSN-side pipe
#: is estimated at >= this fraction of the run's bottleneck...
SATURATION_UTIL = 0.85
#: ...and no more than this many concurrent flows are in play (aggregate
#: metrics stop depending on exact cross-flow ordering beyond it)
SATURATION_MAX_CLIENTS = 64

# ---------------------------------------------------------------------------
# Batched FIFO resources
# ---------------------------------------------------------------------------


def _fifo_scan(a: np.ndarray, h: np.ndarray,
               carry: float | np.ndarray) -> np.ndarray:
    """End times for FIFO service: e_j = max(a_j, e_{j-1}) + h_j, with the
    server busy until ``carry`` before the first arrival.

    Dimension-generic: arrays may carry a trailing *lane* axis (stacked
    multi-seed execution — see :meth:`VectorizedStreamSim.run_stacked`);
    the recurrence always runs along axis 0, independently per lane."""
    a = np.maximum(a, carry)
    H = np.cumsum(h, axis=0)
    return H + np.maximum.accumulate(a - (H - h), axis=0)


def _lane0(a: np.ndarray) -> np.ndarray:
    """The scheduling view of a possibly lane-stacked time array: lane 0
    (the pilot lane) drives every ordering/branching decision."""
    return a if a.ndim == 1 else a[:, 0]


class _VecResource:
    """Busy-interval state for one shared resource, served in batches.

    With ``lanes > 1`` the resource holds one carry per lane and serves
    ``(n, lanes)`` time arrays — same FIFO arithmetic per lane, with the
    pilot lane's arrival order deciding the (shared) service order."""

    __slots__ = ("spec", "_free_pipe", "_free_pool", "_scan")

    def __init__(self, spec: ResourceSpec, lanes: int = 1,
                 scan: Optional[Callable] = None) -> None:
        self.spec = spec
        #: the FIFO-scan kernel (``_fifo_scan`` or an engine-injected
        #: port of it, e.g. the JAX engine's jitted scan)
        self._scan = scan if scan is not None else _fifo_scan
        self._free_pipe = 0.0
        if spec.kind == "pool":
            k = max(1, spec.servers)
            self._free_pool = (np.zeros(k) if lanes == 1
                               else np.zeros((k, lanes)))
        else:
            self._free_pool = None

    def hold_times(self, nbytes: np.ndarray) -> np.ndarray:
        s = self.spec
        if s.kind == "pipe":
            return s.service_s + (nbytes / s.rate_Bps if s.rate_Bps else 0.0)
        return s.service_s + nbytes * s.per_byte_s

    def serve(self, t_arr: np.ndarray, nbytes: np.ndarray,
              jit: np.ndarray) -> np.ndarray:
        """FIFO-serve a batch (any order); returns per-message end times."""
        ht = self.hold_times(nbytes)
        if jit.ndim > 1 and np.ndim(ht) == 1:
            ht = ht[:, None]
        hold = ht * (1.0 + jit)
        order = np.argsort(_lane0(t_arr), kind="stable")
        a, h = t_arr[order], hold[order]
        end_sorted = np.empty_like(a)
        if self.spec.kind == "pipe":
            end_sorted = self._scan(a, h, self._free_pipe)
            self._free_pipe = (float(end_sorted[-1]) if a.ndim == 1
                               else end_sorted[-1].copy())
        else:
            # k-server pool: k interleaved chains; earliest-free server
            # takes the next arrival (exact for near-uniform hold times)
            if self._free_pool.ndim == 1:
                carry = np.sort(self._free_pool)
            else:
                carry = self._free_pool[
                    np.argsort(self._free_pool[:, 0], kind="stable")]
            k = carry.shape[0]
            n = a.shape[0]
            for c in range(min(k, n)):
                end_sorted[c::k] = self._scan(a[c::k], h[c::k], carry[c])
                carry[c] = end_sorted[c + ((n - 1 - c) // k) * k]
            self._free_pool = carry
        out = np.empty_like(end_sorted)
        out[order] = end_sorted
        return out


# ---------------------------------------------------------------------------
# Hop-graph slot alignment
# ---------------------------------------------------------------------------


def _res_class(el: Optional[PathElement]) -> Optional[str]:
    if el is None or el.resource is None:
        return None
    return el.resource.split(":", 1)[0]


def _align_paths(paths: dict) -> tuple[dict, int]:
    """Pad each path's *middle* (between the longest common prefix and
    suffix of resource classes) with Nones so shared bottlenecks land on
    the same slot across path variants.  Returns ({key: padded}, n_slots).
    """
    sigs = {k: [_res_class(e) for e in p] for k, p in paths.items()}
    sig_list = list(sigs.values())
    min_len = min(len(s) for s in sig_list)
    lcp = 0
    while lcp < min_len and len({s[lcp] for s in sig_list}) == 1:
        lcp += 1
    lcs = 0
    while (lcs < min_len - lcp
           and len({s[len(s) - 1 - lcs] for s in sig_list}) == 1):
        lcs += 1
    max_mid = max(len(s) - lcp - lcs for s in sig_list)
    out = {}
    for k, p in paths.items():
        mid = list(p[lcp:len(p) - lcs])
        out[k] = (list(p[:lcp]) + mid + [None] * (max_mid - len(mid))
                  + list(p[len(p) - lcs:]))
    return out, lcp + max_mid + lcs


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class VectorizedStreamSim:
    """Batched engine; same constructor/run contract as ``StreamSim``."""

    #: bound on the memoized (flow, combos) -> resolved-paths cache
    COMBO_CACHE_MAX = 8192

    #: the FIFO-scan kernel every busy-interval recurrence runs through
    #: (resources and the consumer processing chains); subclass engines
    #: (repro.core.jax_engine) swap in their own port
    _scan_impl = staticmethod(_fifo_scan)

    def __init__(self, spec: ExperimentSpec,
                 inventory: Optional[ClusterInventory] = None,
                 arch: Optional[Architecture] = None,
                 stack_seeds: Optional[list[int]] = None) -> None:
        """``stack_seeds``: run this many seed-lanes of the same cell in
        one batched event loop (cohort stacking — see
        :meth:`run_stacked`); ``None``/single-seed is the exact solo
        engine.  ``stack_seeds[0]`` becomes the *pilot* lane whose clock
        drives all scheduling decisions; its results are bit-identical
        to a solo run with that seed."""
        self.spec = spec
        self.p = spec.params
        self.inv = inventory or ClusterInventory()
        self.arch = arch or make_architecture(spec.arch, self.inv)
        self.arch.configure(spec.n_producers, spec.n_consumers,
                            tenants=spec.tenants)
        # tenant-aware hop graphs (DTS per-tenant tunnels): path combos
        # carry the client's tenant as a trailing column, so _resolve_paths
        # builds each tenant's own variant; non-tenant archs keep the
        # 3-column combos (bit-identical to the single-tenant engine)
        self._tenant_cols = bool(self.arch.tenant_paths)
        self._ppt = max(1, spec.n_producers // spec.tenants)
        self._cpt = max(1, spec.n_consumers // spec.tenants)
        check_feasibility(self.arch, spec)
        self.stack_seeds = (list(stack_seeds) if stack_seeds is not None
                            else [self.p.seed])
        self._lanes = len(self.stack_seeds)
        if self._lanes < 1:
            raise ValueError("stack_seeds must name at least one seed")
        if self.stack_seeds[0] != self.p.seed:
            raise ValueError("stack_seeds[0] (the pilot lane) must equal "
                             "params.seed")
        self._rngs = [np.random.default_rng(s) for s in self.stack_seeds]
        self.rng = self._rngs[0]
        self.resources = {k: _VecResource(s, self._lanes,
                                          scan=self._scan_impl)
                          for k, s in self.arch.resources.items()}
        self._proc_s = (self.p.consumer_proc_s
                        if self.p.consumer_proc_s is not None
                        else spec.workload.proc_time_s())
        self.n_events = 0
        #: per-lane flow-control counters (lane 0 = the pilot = the solo
        #: run's values); scalars in RunResult come from the lane's entry
        self.rejected = np.zeros(self._lanes, dtype=np.int64)
        self.blocked = np.zeros(self._lanes, dtype=np.int64)
        self._path_cache: dict = {}
        self._align_cache: dict = {}
        self._combo_cache: dict = {}
        self._channels: dict = {}
        self._queues: dict = {}
        self._chan_queue: dict = {}
        self._heap: list = []
        self._seq = itertools.count()
        #: how far past the next event's key a batch may serve ahead: 0 is
        #: strictest global time ordering (max fidelity, max fragmentation);
        #: auto mode widens with client count — the more concurrent flows,
        #: the less aggregate metrics depend on exact cross-flow ordering
        if self.p.vec_horizon_s is not None:
            self._slack = self.p.vec_horizon_s
        else:
            self._slack = max(1e-3, 1e-3 * (spec.n_producers
                                            + spec.n_consumers) / 16.0)
        # utilization-triggered finer interleaving (auto knobs only): at
        # low flow counts with a saturated shared DSN-side pipe, ordering
        # detail dominates the residual — interleave near per-message
        self._round = self.p.vec_round if self.p.vec_round is not None else 8
        self._fine_pump = False
        self.dsn_utilization, self.publish_surplus = self._cost_model()
        n_clients = spec.n_producers + spec.n_consumers
        if n_clients <= SATURATION_MAX_CLIENTS:
            if self.dsn_utilization >= SATURATION_UTIL:
                # window-aware per-message release: with few flows on a
                # saturated pipe, the adaptive consumer shift (windows on
                # congested NICs close, round-robin skips them) is a
                # first-order throughput effect the batched fast path
                # cannot reproduce
                self._fine_pump = True
                if self.p.vec_round is None:
                    self._round = 2
                if self.p.vec_horizon_s is None:
                    self._slack *= 0.25

    # -- work-pattern topology (shared vs per-tenant vhost queues) -------------
    def _work_topology(self) -> tuple[int, list[list[int]],
                                      list[list[int]], list[int]]:
        """Queue topology of the work-sharing/feedback patterns.

        Returns ``(nq, q_consumers, prod_queues, q_publishers)``:
        ``q_consumers[qi]`` — consumer indices attached to queue ``qi``;
        ``prod_queues[pr]`` — the queues producer ``pr`` round-robins
        over; ``q_publishers[qi]`` — how many producers publish to
        ``qi`` (its credit-flow threshold multiplier).  Queue indices
        follow the heap engine's declare order, so home nodes line up.
        With ``tenants > 1`` and vhost isolation, tenant ``t`` owns
        queues ``[t*nq_t, (t+1)*nq_t)`` and only its own producers/
        consumers touch them."""
        spec, p = self.spec, self.p
        nP, nC = spec.n_producers, spec.n_consumers
        if spec.tenants > 1 and spec.tenant_isolation == "vhost":
            T = spec.tenants
            ppt, cpt = nP // T, nC // T
            nq_t = min(p.n_work_queues, cpt)
            nq = T * nq_t
            q_consumers = [
                t * cpt + np.flatnonzero(np.arange(cpt) % nq_t == qi)
                for t in range(T) for qi in range(nq_t)]
            prod_queues = [
                [(pr // ppt) * nq_t + qi for qi in range(nq_t)]
                for pr in range(nP)]
            q_publishers = [ppt] * nq
        else:
            nq = min(p.n_work_queues, nC)
            q_consumers = [np.flatnonzero(np.arange(nC) % nq == qi)
                           for qi in range(nq)]
            prod_queues = [list(range(nq))] * nP
            q_publishers = [nP] * nq
        return nq, q_consumers, prod_queues, q_publishers

    def flow_events_possible(self) -> bool:
        """Static reachability test for broker flow-control events
        (credit-flow confirm withholding / reject-publish overflow):
        True when producers can pile a queue's backlog past its credit
        threshold, or a byte cap sits below the per-queue volume.  Used
        by the auto ``vec_round`` heuristic (drop to per-message rounds
        at the blocking boundary).  Since flow control became
        lane-resolved, :func:`run_many` stacks these cells like any
        other — this probe no longer gates stacking."""
        spec, p = self.spec, self.p
        size = spec.workload.payload_bytes
        cap = (p.queue_max_bytes // size) if p.queue_max_bytes else None
        per_producer = spec.total_messages // max(1, spec.n_producers)
        if spec.pattern in ("work_sharing", "feedback"):
            nq, _, _, q_pubs = self._work_topology()
            per_q = per_producer * spec.n_producers / nq
            credit = FLOW_CREDIT * min(q_pubs)
        else:
            per_q = per_producer
            credit = FLOW_CREDIT
        return ((cap is not None and cap < per_q)
                or credit < self.publish_surplus * per_q)

    # -- static bottleneck analysis --------------------------------------------
    def _cost_model(self) -> tuple[float, float]:
        """Returns ``(dsn_utilization, publish_surplus)``.

        Accumulates, per resource, the busy seconds one *system message*
        (one consumed copy) induces, using the same node/queue placement
        as the run methods.  The resource with the largest per-message
        busy time is the bottleneck.

        ``dsn_utilization`` — the busiest shared DSN-side pipe
        (``dsn_*``/``tunnel``) as a fraction of the bottleneck; a pipe
        near 1.0 serves back-to-back, where batch-order detail matters
        most.

        ``publish_surplus`` — ``1 - (publish-leg bottleneck / overall
        bottleneck)``: the fraction of published messages that pile up as
        queue backlog because producers outpace the drain.  Scaled by the
        per-queue message volume this bounds the reachable backlog, which
        decides whether credit-flow blocking / overflow can fire."""
        spec, p, inv = self.spec, self.p, self.inv
        nP, nC = spec.n_producers, spec.n_consumers
        size = spec.workload.payload_bytes
        rsize = max(1, int(size * p.reply_factor))
        legs: list[tuple[str, tuple, float, int]] = []
        pat = spec.pattern
        # tenant-aware hop graphs: the path-constructor combos carry the
        # client's tenant as a trailing argument (same convention as the
        # run methods' combo columns)
        tcols = self._tenant_cols
        p_t = (lambda pr: ((pr // self._ppt,) if tcols else ()))
        c_t = (lambda c: ((c // self._cpt,) if tcols else ()))
        if pat in ("work_sharing", "feedback"):
            nq, q_consumers, prod_queues, _ = self._work_topology()
            q_home = [q % inv.n_dsn for q in range(nq)]
            reply_home = [(nq + pr) % inv.n_dsn for pr in range(nP)]
            for pr in range(nP):
                for qi in prod_queues[pr]:
                    legs.append(("publish_path",
                                 (pr % inv.n_producer_nodes, pr % inv.n_dsn,
                                  q_home[qi]) + p_t(pr),
                                 1.0 / (nP * len(prod_queues[pr])), size))
            for qi in range(nq):
                members = q_consumers[qi]
                for c in members:
                    legs.append(("delivery_path",
                                 ((int(c) + 1) % inv.n_dsn, q_home[qi],
                                  int(c) % inv.n_consumer_nodes)
                                 + c_t(int(c)),
                                 1.0 / (nq * len(members)), size))
            if pat == "feedback":
                # collapse the (consumer x producer) cross product over
                # the <= n_dsn distinct reply homes, tenant by tenant (a
                # vhosted consumer replies only to its own producers)
                T = (spec.tenants if spec.tenant_isolation == "vhost"
                     else 1)
                ppt, cpt = nP // T, nC // T
                for t in range(T):
                    home_w: dict[int, float] = {}
                    for pr in range(t * ppt, (t + 1) * ppt):
                        h = reply_home[pr]
                        home_w[h] = home_w.get(h, 0.0) + 1.0 / ppt
                    for c in range(t * cpt, (t + 1) * cpt):
                        for h, w in home_w.items():
                            legs.append(("reply_publish_path",
                                         (c % inv.n_consumer_nodes,
                                          (c + 1) % inv.n_dsn, h)
                                         + c_t(c),
                                         w / nC, rsize))
                for pr in range(nP):
                    legs.append(("reply_delivery_path",
                                 (reply_home[pr], pr % inv.n_dsn,
                                  pr % inv.n_producer_nodes) + p_t(pr),
                                 1.0 / nP, rsize))
        else:
            gather_home = nC % inv.n_dsn
            legs.append(("publish_path", (0, 0, 0), 1.0 / nC, size))
            for c in range(nC):
                legs.append(("delivery_path",
                             ((c + 1) % inv.n_dsn, c % inv.n_dsn,
                              c % inv.n_consumer_nodes), 1.0 / nC, size))
            if pat == "broadcast_gather":
                for c in range(nC):
                    legs.append(("reply_publish_path",
                                 (c % inv.n_consumer_nodes,
                                  (c + 1) % inv.n_dsn, gather_home),
                                 1.0 / nC, rsize))
                legs.append(("reply_delivery_path", (gather_home, 0, 0),
                             1.0, rsize))
        cost: dict[str, float] = {}
        pub_cost: dict[str, float] = {}
        for flow, combo, w, sz in legs:
            for el in getattr(self.arch, flow)(*combo):
                if el.resource is None:
                    continue
                rs = self.resources[el.resource].spec
                nb = sz * el.byte_factor + el.extra_bytes
                if rs.kind == "pipe":
                    sec = rs.service_s + (nb / rs.rate_Bps
                                          if rs.rate_Bps else 0.0)
                else:
                    sec = ((rs.service_s + nb * rs.per_byte_s)
                           / max(1, rs.servers))
                cost[el.resource] = cost.get(el.resource, 0.0) + w * sec
                if flow == "publish_path":
                    pub_cost[el.resource] = (pub_cost.get(el.resource, 0.0)
                                             + w * sec)
        c_max = max(max(cost.values(), default=0.0),
                    self._proc_s / max(1, nC))
        #: per-resource busy seconds per system message + the bottleneck,
        #: kept for external probes (patterns.deployment_feasibility reads
        #: the shared facility-ingress utilization off a built engine)
        self.resource_cost = dict(cost)
        self.bottleneck_cost = c_max
        if c_max <= 0.0:
            return 0.0, 0.0
        shared = [v for k, v in cost.items()
                  if k.startswith(("dsn_in", "dsn_out", "dsn_int", "tunnel",
                                   "dts_gw", "ttun"))]
        pub_max = max(pub_cost.values(), default=0.0)
        return (max(shared, default=0.0) / c_max,
                max(0.0, 1.0 - pub_max / c_max))

    # -- helpers ---------------------------------------------------------------
    def _jit(self, n: int) -> np.ndarray:
        """Service-time jitter draws: ``(n,)`` solo, ``(n, lanes)`` when
        stacked — each lane consumes its own generator in the (shared)
        event order, so the pilot lane's stream matches a solo run."""
        j = self.p.jitter
        if self._lanes == 1:
            return self.rng.uniform(-j, j, n) if j else np.zeros(n)
        if not j:
            return np.zeros((n, self._lanes))
        out = np.empty((n, self._lanes))
        for lane, g in enumerate(self._rngs):
            out[:, lane] = g.uniform(-j, j, n)
        return out

    def _recv_latency(self, size: int) -> float:
        return self.arch.recv_latency_s(size)

    def _chan(self, cid: int) -> dict:
        """Broker-channel state: per-delivery seen/ack times (the ack
        clock), the ack-multiple coverage cursor, and the consumer's
        serial-processing carry.  The clock arrays carry a trailing lane
        axis in stacked mode."""
        ch = self._channels.get(cid)
        if ch is None:
            shape = (0,) if self._lanes == 1 else (0, self._lanes)
            ch = {"assigned": 0, "acked": 0, "seen": np.zeros(shape),
                  "ack_time": np.zeros(shape), "free": 0.0,
                  "since": 0, "last_tag": 0}
            self._channels[cid] = ch
        return ch

    @staticmethod
    def _chan_grow(ch: dict, extra: int) -> None:
        """Amortized growth of the per-delivery bookkeeping arrays."""
        need = ch["assigned"] + extra
        if ch["seen"].shape[0] < need:
            cap = max(need, 2 * ch["seen"].shape[0], 64)
            for f in ("seen", "ack_time"):
                a = np.full((cap,) + ch[f].shape[1:], np.nan)
                a[:ch[f].shape[0]] = ch[f]
                ch[f] = a

    def _resolve_paths(self, flow: str, combos: np.ndarray) -> tuple:
        """Per-combo aligned paths + member indices for one cohort leg.

        The full resolution is a pure function of ``(flow, combos)``, so
        repeated cohort shapes (the same consumer rotation recurring
        across pump chunks, or the same cohort in another stacked lane)
        hit ``_combo_cache`` and skip the row-dedup entirely."""
        ckey = (flow, combos.shape[0], combos.tobytes())
        hit = self._combo_cache.get(ckey)
        if hit is not None:
            return hit
        ctor = getattr(self.arch, flow)
        uniq, inv = np.unique(combos, axis=0, return_inverse=True)
        inv = inv.ravel()
        raw = {}
        for u, key in enumerate(map(tuple, uniq)):
            ck = (flow, key)
            if ck not in self._path_cache:
                self._path_cache[ck] = ctor(*key)
            raw[u] = self._path_cache[ck]
        ak = (flow, tuple(map(tuple, uniq)))
        if ak not in self._align_cache:
            self._align_cache[ak] = _align_paths(raw)
        aligned, n_slots = self._align_cache[ak]
        idx_by = {u: np.nonzero(inv == u)[0] for u in aligned}
        if len(self._combo_cache) >= self.COMBO_CACHE_MAX:
            self._combo_cache.clear()     # crude but bounded
        self._combo_cache[ckey] = (aligned, idx_by, n_slots)
        return aligned, idx_by, n_slots

    # -- queue backlog accounting (credit flow + overflow) ---------------------
    def _queue_state(self, qkey: tuple, consumers: list[int],
                     size: int, *,
                     credit: Optional[int] = None,
                     cap_msgs: Optional[int] = None) -> dict:
        """Get/create one broker queue's batched state.

        Beyond the pump state (consumers + pending segments), queues whose
        publishers are subject to credit flow or whose byte budget can
        overflow track their un-drained backlog **per lane**: ``n_enq[l]``
        counts lane ``l``'s enqueues, released depart times sit in one
        min-heap per lane (keyed by that lane's own clock) and are popped
        (in time order) into ``departed[l]`` as the backlog is queried —
        so ``n_enq[l] - departed[l]`` is lane ``l``'s ready count at the
        query time, exactly the heap broker's ``len(q.ready)`` in that
        lane's solo run.  ``hwm[l]`` records the admission path's
        backlog high-water mark (exact in the slow path, the zero-drain
        upper bound in the fast path) — the invariant ``hwm <= cap`` is
        property-tested.  ``released`` counts recorded depart *entries*
        (each entry carries every lane), shared across lanes."""
        q = self._queues.get(qkey)
        if q is None:
            L = self._lanes
            q = {"consumers": [int(c) for c in consumers], "pending": [],
                 "size": size, "credit": credit, "cap": cap_msgs,
                 "track": credit is not None or cap_msgs is not None,
                 "n_enq": np.zeros(L, dtype=np.int64), "released": 0,
                 "departed": np.zeros(L, dtype=np.int64),
                 "depart_heap": [[] for _ in range(L)],
                 "last_pop_t": np.zeros(L), "deferred": [],
                 "hwm": np.zeros(L, dtype=np.int64),
                 "forced": np.zeros(L, dtype=np.int64)}
            self._queues[qkey] = q
            for c in q["consumers"]:
                self._chan_queue[c] = qkey
        return q

    def _pop_lane(self, q: dict, lane: int, t: float) -> None:
        """Advance one lane's depart cursor: count that lane's releases
        that left by ``t`` (the lane's own clock)."""
        h = q["depart_heap"][lane]
        while h and h[0] <= t:
            q["last_pop_t"][lane] = heapq.heappop(h)
            q["departed"][lane] += 1

    def _next_drain(self, q: dict, lane: int) -> Optional[float]:
        """Earliest recorded, not-yet-popped depart time on one lane
        (``None`` when the lane has no known future drain).  The
        depart-store read the admission retry logic keys on — engines
        with a different store (the JAX engine's masked arrays) override
        this and the pop methods, nothing else."""
        h = q["depart_heap"][lane]
        return h[0] if h else None

    def _pop_to_target(self, q: dict, lane: int, target: int) -> None:
        """Advance one lane's depart cursor until ``target`` total
        releases have been popped (best effort — stops when no recorded
        drain remains)."""
        h = q["depart_heap"][lane]
        while q["departed"][lane] < target and h:
            q["last_pop_t"][lane] = heapq.heappop(h)
            q["departed"][lane] += 1

    def _record_departs(self, q: dict, departs: np.ndarray) -> None:
        """Register released deliveries' depart times (each lane's column
        into that lane's heap); resolves any credit-flow-deferred
        confirms the new drains now admit."""
        if not q["track"]:
            return
        heaps = q["depart_heap"]
        cols = departs.reshape(departs.shape[0], self._lanes)
        for lane in range(self._lanes):
            h = heaps[lane]
            for d in cols[:, lane]:
                heapq.heappush(h, float(d))
        q["released"] += departs.shape[0]
        if q["deferred"]:
            self._try_resume(q)

    def _lane_resume_time(self, q: dict, lane: int) -> float:
        """One lane's ``flow_resume`` clock: pop that lane's departs
        until it has drained to half the credit threshold (best effort —
        with no further known drains the last release stands) and return
        the crossing depart time + control latency."""
        target = q["n_enq"][lane] - q["credit"] // 2
        self._pop_to_target(q, lane, target)
        return float(q["last_pop_t"][lane]) + self.arch.control_latency_s()

    def _try_resume(self, q: dict, force: bool = False) -> bool:
        """Release the queue's withheld confirms once drained to half the
        credit threshold (the heap broker's ``flow_resume``), at the
        depart time that crossed the mark + control latency.

        Scheduling is the pilot's: the gate and the resume clock passed
        to the resolvers are lane 0's; a resolver for a multi-lane
        deferral computes the other blocked lanes' resume clocks from
        their own depart heaps (:meth:`_lane_resume_time`) when it
        fires."""
        if not q["deferred"]:
            return False
        target = int(q["n_enq"][0]) - q["credit"] // 2
        if q["released"] < target and not force:
            return False
        self._pop_to_target(q, 0, target)
        t_resume = float(q["last_pop_t"][0]) + self.arch.control_latency_s()
        resolvers, q["deferred"] = q["deferred"], []
        for fn in resolvers:
            fn(t_resume)
        return True

    def _lane_admit(self, tracked: list, lane: int, t_rej: float
                    ) -> tuple[float, int, Optional[dict]]:
        """Resolve one non-pilot lane's reject-retry loop locally: the
        lane's producer re-publishes every ``publish_retry_s`` until the
        lane's own backlog admits the message (checked against the
        lane's depart heap — the drains this lane has already computed).
        The re-publish transits themselves are not re-served through the
        lane's resources (the member's schedule is the pilot's); the
        admission *time* and the per-attempt reject counts are the
        lane's own.  Called after the lane's attempt at ``t_rej`` was
        already rejected (and counted).  Returns ``(t_admit,
        extra_rejects, blocked_on)``; with no further known drains the
        next attempt is admitted optimistically."""
        p = self.p
        t = t_rej + p.publish_retry_s
        extra = 0
        while True:
            full_q = None
            for q in tracked:
                self._pop_lane(q, lane, t)
                if (q["cap"] is not None
                        and q["n_enq"][lane] - q["departed"][lane]
                        >= q["cap"]):
                    full_q = q
                    break
            if full_q is None:
                break
            nd = self._next_drain(full_q, lane)
            if nd is None:
                # no known future drain: count this failed attempt and
                # admit on the next one rather than spinning forever —
                # the one admission that may push a lane's backlog past
                # the cap, recorded in ``forced`` (the property suite
                # bounds hwm by cap + forced)
                extra += 1
                t += p.publish_retry_s
                for q in tracked:
                    q["forced"][lane] += 1
                break
            # every retry until the next known drain fails too: jump the
            # retry cadence straight past it
            k = max(1, int(np.ceil((nd - t) / p.publish_retry_s)))
            extra += k
            t += k * p.publish_retry_s
        blocked_on = None
        for q in tracked:
            q["n_enq"][lane] += 1
            q["hwm"][lane] = max(q["hwm"][lane],
                                 q["n_enq"][lane] - q["departed"][lane])
        for q in tracked:
            if (q["credit"] is not None
                    and q["n_enq"][lane] - q["departed"][lane]
                    > q["credit"]):
                blocked_on = q
                break
        return t, extra, blocked_on

    def _enqueue_batch(self, qs: list, t_enq: np.ndarray,
                       skip: Optional[np.ndarray] = None
                       ) -> tuple[np.ndarray, Optional[np.ndarray]]:
        """Admit a publish cohort onto one queue (or atomically onto all
        fanout targets), independently per lane.  ``t_enq`` is ``(n,)``
        solo or ``(n, lanes)``; ``skip[k, l]`` marks members a previous
        attempt already admitted in lane ``l`` (they are neither
        re-enqueued nor re-counted).  Returns ``(accepted, blocked_on)``:
        ``accepted[k, l]`` — admitted in lane ``l`` by *this* attempt;
        ``blocked_on`` — ``None`` when no lane crossed a credit
        threshold, else an ``(n, lanes)`` object array whose entries name
        the queue whose threshold that member crossed in that lane.

        Each lane runs the exact solo admission sequence against its own
        clocks and depart cursor: a fast path when even a zero-drain
        upper bound on every target's backlog stays below both the byte
        cap and the credit threshold, else the per-message arrival-order
        walk (the heap engine's ``offer()``/``flow_blocked`` sequence).
        Lanes choose fast/slow independently, so a lane near its
        threshold never drags the others onto the slow path (and the
        pilot's arithmetic stays bit-identical to a solo run)."""
        L = self._lanes
        n = t_enq.shape[0]
        T = t_enq.reshape(n, L)
        tracked = [q for q in qs if q["track"]]
        if not tracked:
            acc = np.ones((n, L), dtype=bool)
            if skip is not None:
                acc &= ~skip
            return acc, None
        accept = np.zeros((n, L), dtype=bool)
        blocked_on: Optional[np.ndarray] = None
        for lane in range(L):
            att = (np.ones(n, dtype=bool) if skip is None
                   else ~skip[:, lane])
            n_att = int(att.sum())
            if n_att == 0:
                continue
            tl = T[att, lane]
            t_min = float(tl.min())
            fast = True
            for q in tracked:
                self._pop_lane(q, lane, t_min)
                hi = q["n_enq"][lane] + n_att - q["departed"][lane]
                if ((q["cap"] is not None and hi > q["cap"])
                        or (q["credit"] is not None and hi > q["credit"])):
                    fast = False
                    break
            if fast:
                for q in tracked:
                    q["n_enq"][lane] += n_att
                    q["hwm"][lane] = max(
                        q["hwm"][lane],
                        q["n_enq"][lane] - q["departed"][lane])
                accept[att, lane] = True
                continue
            ks = np.nonzero(att)[0][np.argsort(tl, kind="stable")]
            admitted, blocked = self._admit_walk(tracked, lane, ks, T)
            accept[admitted, lane] = True
            for k, q in blocked:
                if blocked_on is None:
                    blocked_on = np.full((n, L), None, dtype=object)
                blocked_on[k, lane] = q
        return accept, blocked_on

    def _admit_walk(self, tracked: list, lane: int, ks: np.ndarray,
                    T: np.ndarray) -> tuple[np.ndarray, list]:
        """One lane's per-message arrival-order admission walk (the heap
        engine's ``offer()``/``flow_blocked`` sequence): members ``ks``
        — already sorted by this lane's arrival time — are admitted
        unless a tracked queue's backlog sits at its byte cap at the
        member's arrival clock; each admission bumps every target's
        enqueue count and high-water mark, and the first credit
        threshold it crosses is recorded.  Returns ``(admitted_members,
        [(member, blocking_queue), ...])``.  The JAX engine overrides
        this with a ``lax.scan`` over the same recurrence."""
        admitted = []
        blocked = []
        for k in ks:
            t = float(T[k, lane])
            full = False
            for q in tracked:
                self._pop_lane(q, lane, t)
                if (q["cap"] is not None
                        and q["n_enq"][lane] - q["departed"][lane]
                        >= q["cap"]):
                    full = True
                    break
            if full:
                continue
            admitted.append(int(k))
            for q in tracked:
                q["n_enq"][lane] += 1
                q["hwm"][lane] = max(
                    q["hwm"][lane],
                    q["n_enq"][lane] - q["departed"][lane])
            for q in tracked:
                if (q["credit"] is not None
                        and q["n_enq"][lane] - q["departed"][lane]
                        > q["credit"]):
                    blocked.append((int(k), q))
                    break
        return np.asarray(admitted, dtype=int), blocked

    # -- batch event loop ------------------------------------------------------
    def _push_transit(self, t0: np.ndarray, size: int, flow: str,
                      combos: np.ndarray,
                      on_done: Optional[Callable[[np.ndarray], None]] = None,
                      on_part: Optional[Callable[[np.ndarray, np.ndarray],
                                                 None]] = None) -> None:
        """Queue a cohort to traverse ``flow``'s hop graph, one hop per
        event pop, interleaved with every other in-flight cohort.

        ``on_done(times)`` fires once, when every member has exited;
        ``on_part(member_indices, times)`` instead fires per finishing
        sub-batch, in event order — use it when downstream state (ack
        clocks) must advance as individual messages land."""
        aligned, idx_by, n_slots = self._resolve_paths(flow, combos)
        t0 = np.asarray(t0, dtype=float)
        n = t0.shape[0]
        inv = np.empty(n, dtype=int)
        for u, idx in idx_by.items():
            inv[idx] = u
        cohort = {"out": np.empty(t0.shape), "remaining": n,
                  "on_done": on_done, "on_part": on_part,
                  "aligned": aligned, "size": size, "flow": flow}
        batch = {"t": t0.copy(), "members": np.arange(n), "inv": inv,
                 "slot": 0, "n_slots": n_slots, "cohort": cohort}
        self._push(batch)

    def _push(self, batch: dict) -> None:
        heapq.heappush(self._heap,
                       (float(_lane0(batch["t"]).min()),
                        next(self._seq), batch))

    def _split_horizon(self, batch: dict) -> dict:
        """Split off the members past the event horizon (next event's key
        + slack) back into the heap; returns the head sub-batch.  This is
        what keeps every resource seeing its customers in near-global
        arrival order even when cohort spans overlap."""
        if self._heap:
            horizon = self._heap[0][0] + self._slack
            head = _lane0(batch["t"]) <= horizon
            if not head.all():
                if not head.any():
                    head[np.argmin(_lane0(batch["t"]))] = True
                tail = {"t": batch["t"][~head],
                        "members": batch["members"][~head],
                        "inv": batch["inv"][~head],
                        "slot": batch["slot"],
                        "n_slots": batch["n_slots"],
                        "cohort": batch["cohort"]}
                self._push(tail)
                batch = {"t": batch["t"][head],
                         "members": batch["members"][head],
                         "inv": batch["inv"][head],
                         "slot": batch["slot"],
                         "n_slots": batch["n_slots"],
                         "cohort": batch["cohort"]}
        return batch

    def _prepare_slot(self, batch: dict) -> list:
        """Resolve one hop for a cohort batch into servable parts.

        Applies latency-only elements in place and returns
        ``[(resource_key, idx, nbytes, latency, jitter), ...]`` — members
        at the same hop hitting the same resource instance (across path
        variants) merged into one FIFO part, with the per-part jitter
        already drawn (in deterministic part order, so a stacked multi-
        lane run consumes each lane's RNG exactly like a solo run)."""
        cohort = batch["cohort"]
        t, s = batch["t"], batch["slot"]
        aligned = cohort["aligned"]
        size = cohort["size"]
        inv = batch["inv"]
        if len(aligned) == 1:
            groups = [(0, np.arange(t.shape[0]))]
        else:
            order = np.argsort(inv, kind="stable")
            uniq, starts = np.unique(inv[order], return_index=True)
            bounds = np.append(starts, inv.size)
            groups = [(u, order[bounds[i]:bounds[i + 1]])
                      for i, u in enumerate(uniq)]
        by_instance: dict[str, list] = {}
        for u, idx in groups:
            el = aligned[u][s]
            if el is None:
                continue
            if el.resource is None:
                t[idx] += el.latency_s
                continue
            by_instance.setdefault(el.resource, []).append((idx, el))
        parts = []
        for key, ps in by_instance.items():
            if len(ps) == 1:
                idx, el = ps[0]
                nbytes = size * el.byte_factor + el.extra_bytes
                lat = el.latency_s
            else:
                idx = np.concatenate([p[0] for p in ps])
                nbytes = np.concatenate([
                    np.full(p[0].size, size * p[1].byte_factor
                            + p[1].extra_bytes) for p in ps])
                lat = np.concatenate([
                    np.full(p[0].size, p[1].latency_s) for p in ps])
                if self._lanes > 1:
                    lat = lat[:, None]
            parts.append((key, idx, nbytes, lat, self._jit(idx.size)))
        return parts

    def _finish_slot(self, batch: dict) -> None:
        """Advance a served batch: requeue the next hop, or complete the
        cohort (fire ``on_part``/``on_done``)."""
        cohort = batch["cohort"]
        t = batch["t"]
        batch["slot"] += 1
        if batch["slot"] < batch["n_slots"]:
            self._push(batch)
        else:
            if cohort["on_part"] is not None:
                cohort["on_part"](batch["members"], t)
            cohort["out"][batch["members"]] = t
            cohort["remaining"] -= t.shape[0]
            if cohort["remaining"] == 0 and cohort["on_done"] is not None:
                cohort["on_done"](cohort["out"])

    def _serve_slot(self, batch: dict) -> None:
        """Serve one hop for the head of one cohort batch."""
        batch = self._split_horizon(batch)
        for key, idx, nbytes, lat, jit in self._prepare_slot(batch):
            batch["t"][idx] = (self.resources[key].serve(
                batch["t"][idx], nbytes, jit) + lat)
            self.n_events += idx.size
        self._finish_slot(batch)

    def _pop_batch(self) -> Optional[dict]:
        """Pop the next cohort batch, honoring the same safety caps the
        heap engine enforces; None when drained (or capped out)."""
        if not self._heap:
            return None
        key, _, batch = heapq.heappop(self._heap)
        if (self.n_events > self.p.max_events
                or key > self.p.max_sim_time):
            self._heap.clear()
            return None
        return batch

    def _drain(self) -> None:
        while True:
            batch = self._pop_batch()
            if batch is None:
                break
            self._serve_slot(batch)

    def _force_resume(self) -> bool:
        """Last-resort deadlock breaker for the drained-out tail: resolve
        any still-deferred confirms at the release clock."""
        any_resolved = False
        for q in self._queues.values():
            if q["deferred"] and self._try_resume(q, force=True):
                any_resolved = True
        return any_resolved

    def _tail_step(self) -> bool:
        """One end-of-drain recovery step, called with the heap empty:
        force-flush unflushed batch acks that hold back window-waiting
        deliveries (the heap engine's expected-consumed flush), then
        force-resume deferred confirms.  True when new events appeared."""
        flushed = []
        for c, ch in self._channels.items():
            if ch["last_tag"] > ch["acked"]:
                j = np.arange(ch["acked"], ch["last_tag"])
                if not np.isfinite(ch["seen"][j]).all():
                    continue
                ch["ack_time"][j] = (ch["seen"][j]
                                     + self.arch.control_latency_s())
                ch["acked"] = ch["last_tag"]
                ch["since"] = 0
                if c in self._chan_queue:
                    flushed.append(self._chan_queue[c])
        if flushed:
            self._pump_queues(flushed)
            if self._heap:
                return True
        if self._force_resume() and self._heap:
            return True
        return False

    def _drain_all(self) -> None:
        """Drain the event heap; when only unflushed batch acks hold back
        window-waiting deliveries (the tail of a run), force-flush them —
        the heap engine's expected-consumed flush — and keep draining."""
        while True:
            self._drain()
            if not self._tail_step():
                return

    # -- prefetch-windowed delivery (the batched broker pump) ------------------
    def _deliver_queue(self, qkey: tuple, consumers: list[int],
                       t_ready: np.ndarray,
                       member_idx: np.ndarray, combos_fn: Callable,
                       size: int, flow: str, consumer: bool, recv: float,
                       on_seen: Callable) -> None:
        """Enqueue a cohort on one broker queue and pump it through
        ``flow``.

        Deliveries leave the queue in FIFO order; each is assigned to the
        next consumer *with an open basic.qos window* in rotated
        round-robin order (the heap broker's ``next_delivery``), so load
        shifts toward faster/less-congested consumers exactly when windows
        close.  A delivery's depart time is gated on the ack that freed
        its window slot (acks are ack-multiple: a seen message acks every
        lower delivery tag).  ``combos_fn(member_idx, cons)`` builds the
        per-message path-constructor arguments once consumers are known;
        ``on_seen(member_idx, seen_times, cons)`` fires per landed batch —
        partial cohorts are normal."""
        cohort = {"combos_fn": combos_fn, "size": size, "flow": flow,
                  "consumer": consumer, "recv": recv, "on_seen": on_seen}
        q = self._queue_state(qkey, consumers, size)
        o = np.argsort(_lane0(t_ready), kind="stable")
        q["pending"].append({"cohort": cohort, "idx": member_idx[o],
                             "t": t_ready[o], "pos": 0})
        self._pump_queues([qkey])

    def _rr_assign(self, ids: list, t_sl: np.ndarray, P: int
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Strict round-robin split of one whole released segment across
        consumers with open windows (the pump fast path): message ``r``
        goes to ``ids[r % k]`` and its depart gates on the ack that
        freed its basic.qos window slot.  Advances each channel's
        assigned cursor.  Returns ``(consumer_ids, delivery_tags,
        depart_times)``.  The JAX engine overrides the gate/depart
        arithmetic with one fused device computation."""
        n_rem = t_sl.shape[0]
        k = len(ids)
        cons = np.array(ids)[np.arange(n_rem) % k]
        j_all = np.empty(n_rem, dtype=int)
        depart = np.empty(t_sl.shape)
        for r, c in enumerate(ids):
            pos = np.arange(r, n_rem, k)
            ch = self._chan(c)
            self._chan_grow(ch, pos.size)
            j = ch["assigned"] + np.arange(pos.size)
            gate = np.full(t_sl[pos].shape, -np.inf)
            m_g = j >= P
            gate[m_g] = ch["ack_time"][j[m_g] - P]
            j_all[pos] = j
            depart[pos] = np.maximum(t_sl[pos], gate)
            ch["assigned"] += pos.size
        return cons, j_all, depart

    def _pump_queues(self, qkeys: Iterable[tuple]) -> None:
        """Release every window-admissible pending delivery on the given
        queues and push the released groups as transit batches."""
        P = max(1, self.p.prefetch)
        releases: dict[int, list] = {}
        for qk in dict.fromkeys(qkeys):
            q = self._queues[qk]
            ids = q["consumers"]
            while q["pending"]:
                seg = q["pending"][0]
                n_rem = seg["idx"].size - seg["pos"]
                k = len(ids)
                # fast path: every window stays open through a strict
                # round-robin split of the whole segment remainder
                # (skipped in fine-pump mode — see __init__)
                if not self._fine_pump and \
                        all((P - (self._chan(c)["assigned"]
                                  - self._chan(c)["acked"]))
                            >= (n_rem - r + k - 1) // k
                            for r, c in enumerate(ids)):
                    sl = slice(seg["pos"], seg["pos"] + n_rem)
                    t_sl, m_sl = seg["t"][sl], seg["idx"][sl]
                    cons, j_all, depart = self._rr_assign(ids, t_sl, P)
                    q["consumers"] = ids = ids[n_rem % k:] + ids[:n_rem % k]
                    releases.setdefault(id(seg["cohort"]), []).append(
                        (seg["cohort"], m_sl, cons, j_all, depart))
                    self._record_departs(q, depart)
                    seg["pos"] += n_rem
                    q["pending"].pop(0)
                    continue
                # slow path: per message, the heap broker's next_delivery
                # in virtual time — the first consumer (rotated
                # round-robin) whose basic.qos window is *open at the
                # message's ready time* takes it; with every window
                # closed, the earliest known re-opening (the ack-arrival
                # pump that would pop it) takes the delivery.  This is
                # what shifts load toward less-congested consumers: a
                # consumer behind a saturated NIC acks late, its window
                # stays closed, and the round-robin skips it.  Released
                # in small chunks so ack arrivals (the commits that
                # re-pump this queue) interleave with the assignment.
                rel, ids = self._assign_chunk(seg, ids, P)
                q["consumers"] = ids
                if rel:
                    rel_depart = np.array([r[3] for r in rel])
                    releases.setdefault(id(seg["cohort"]), []).append(
                        (seg["cohort"],
                         np.array([r[0] for r in rel]),
                         np.array([r[1] for r in rel]),
                         np.array([r[2] for r in rel]),
                         rel_depart))
                    self._record_departs(q, rel_depart)
                if seg["pos"] == seg["idx"].size:
                    q["pending"].pop(0)
                # leave after one slow-path chunk: the commits of what was
                # just released re-pump this queue with a fresh ack clock
                break
        for parts in releases.values():
            cohort = parts[0][0]
            idx = np.concatenate([p[1] for p in parts])
            cons = np.concatenate([p[2] for p in parts])
            j_all = np.concatenate([p[3] for p in parts])
            depart = np.concatenate([p[4] for p in parts])
            self._push_transit(
                depart, cohort["size"], cohort["flow"],
                cohort["combos_fn"](idx, cons),
                on_part=lambda members, t, cohort=cohort, idx=idx,
                cons=cons, j_all=j_all:
                    self._commit(cohort, idx[members], j_all[members],
                                 cons[members], t))

    def _assign_chunk(self, seg: dict, ids: list, P: int
                      ) -> tuple[list, list]:
        """One slow-path assignment chunk: per message, the heap
        broker's ``next_delivery`` in virtual time — the first consumer
        (rotated round-robin) whose basic.qos window is open at the
        message's ready time takes it; with every window closed, the
        earliest known re-opening takes the delivery.  Consumes up to
        ``ack_batch`` messages off ``seg``; returns ``(released,
        rotated_ids)`` where each released entry is ``(member_idx,
        consumer, delivery_tag, depart)``.  The JAX engine overrides
        this with a ``lax.scan`` over the same selection recurrence."""
        chunk = max(1, self.p.ack_batch)
        chans = [self._chan(c) for c in ids]
        # next-assignment window gate per consumer (NaN = the ack that
        # would re-open it hasn't been computed yet); in stacked mode one
        # gate vector per lane, decisions on the pilot lane's column
        gshape = ((len(ids),) if self._lanes == 1
                  else (len(ids), self._lanes))
        g = np.empty(gshape)
        for x, ch in enumerate(chans):
            j = ch["assigned"]
            g[x] = -np.inf if j < P else ch["ack_time"][j - P]
        order = np.arange(len(ids))     # rotated round-robin
        rel = []
        while seg["pos"] < seg["idx"].size and len(rel) < chunk:
            tv = seg["t"][seg["pos"]]
            t = float(_lane0(seg["t"])[seg["pos"]])
            go = g[order]
            go0 = _lane0(go)
            with np.errstate(invalid="ignore"):
                open_pos = np.nonzero(go0 <= t)[0]
            if open_pos.size:
                pos = int(open_pos[0])
            else:
                finite = np.isfinite(go0)
                if not finite.any():
                    break   # re-openings unknown: wait for acks
                pos = int(np.argmin(np.where(finite, go0, np.inf)))
            gate = go[pos]
            x = int(order[pos])
            order = np.append(np.delete(order, pos), x)
            ch = chans[x]
            self._chan_grow(ch, 1)
            j = ch["assigned"]
            ch["assigned"] += 1
            g[x] = (-np.inf if j + 1 < P
                    else ch["ack_time"][j + 1 - P])
            rel.append((seg["idx"][seg["pos"]], ids[x], j,
                        np.maximum(tv, gate)))
            seg["pos"] += 1
        return rel, [ids[x] for x in order]

    def _commit(self, cohort: dict, cidx: np.ndarray, j: np.ndarray,
                chan: np.ndarray, t_land: np.ndarray) -> None:
        """Some released deliveries landed: run the consumer processing
        chains (or stamp producer receive times), advance the channels' ack
        clocks (basic.ack multiple=True — a seen message acks every lower
        tag), and pump deliveries the freed window slots now admit."""
        seen = np.empty_like(t_land)
        recv = cohort["recv"]
        ctrl = self.arch.control_latency_s()
        touched = []
        for c in np.unique(chan):
            m = np.nonzero(chan == c)[0]
            ch = self._chan(c)
            if cohort["consumer"]:
                # serial parse/handle chain on the consumer client
                o = m[np.argsort(_lane0(t_land)[m], kind="stable")]
                proc = self._proc_s * (1.0 + self._jit(o.size))
                ends = self._scan_impl(t_land[o] + recv, proc, ch["free"])
                seen[o] = ends
                ch["free"] = (float(ends[-1]) if ends.ndim == 1
                              else ends[-1].copy())
            else:
                seen[m] = t_land[m] + recv
            ch["seen"][j[m]] = seen[m]
            # batched acks (ack-multiple every ack_batch deliveries, or
            # immediately once the basic.qos window is full)
            B = max(1, self.p.ack_batch)
            P = max(1, self.p.prefetch)
            for mi in m[np.argsort(_lane0(seen)[m], kind="stable")]:
                ch["last_tag"] = max(ch["last_tag"], int(j[mi]) + 1)
                ch["since"] += 1
                if (ch["since"] >= B
                        or ch["assigned"] - ch["acked"] >= P):
                    if ch["last_tag"] > ch["acked"]:
                        ch["ack_time"][ch["acked"]:ch["last_tag"]] = \
                            seen[mi] + ctrl
                        ch["acked"] = ch["last_tag"]
                    ch["since"] = 0
            touched.append(c)
        cohort["on_seen"](cidx, seen, chan)
        self._pump_queues([self._chan_queue[c] for c in touched])

    # -- the one reject-retry / deferred-confirm publish shape ----------------
    def _publish_with_retry(self, members: np.ndarray, t0: np.ndarray, *,
                            flow: str, size: int,
                            combos_of: Callable[[np.ndarray], np.ndarray],
                            groups_of: Callable,
                            deliver: Callable,
                            set_confirms: Optional[Callable] = None,
                            mark_confirmed: Optional[Callable] = None
                            ) -> None:
        """Push a publish cohort through ``flow`` with the full broker
        admission treatment, shared by all four publish legs (work
        publish, feedback reply, broadcast fanout, gather reply):

        * **reject-publish overflow** — members rejected at their target
          queue's byte cap re-enter the publish path after
          ``publish_retry_s`` (the producer re-publish backoff), as a
          retry cohort;
        * **credit-flow deferred confirms** — accepted members that push
          a tracked queue past its credit threshold have their publisher
          confirm withheld on that queue's ``deferred`` list until the
          pump drains it to ``flow_resume`` (only when ``set_confirms``
          is given — reply/gather legs never gate producer windows).

        ``members`` is an opaque index array (positions into whatever
        per-leg arrays the callbacks capture); retries thread subsets of
        it back through ``combos_of``.  ``groups_of(members)`` yields
        ``(group_key, queue_states, positions)`` — one admission group
        per target queue (``len(queue_states) > 1`` = atomic fanout).
        ``deliver(group_key, members, t_enq)`` hands accepted members to
        the delivery pump; ``set_confirms(members, t_conf)`` /
        ``mark_confirmed(members)`` record resolved publisher confirms.

        **Stacked lanes diverge here.**  Admission runs per lane
        (:meth:`_enqueue_batch`), so a member may be admitted in one
        lane and rejected in another.  Scheduling stays the pilot's: a
        member joins a retry cohort iff *lane 0* rejected it (exactly
        the pilot's solo retry schedule — lanes that already admitted it
        keep their frozen admission times and ignore the re-served
        transit); conversely a lane that rejects a pilot-admitted member
        resolves its own retry cadence locally against its own depart
        heap (:meth:`_lane_admit`).  Confirm times, credit blocks and
        reject counts are all per-lane; every lane's ``rejected`` /
        ``blocked`` counters and clocks match what its solo run's
        admission sequence would produce, up to the shared-schedule
        approximation bounded by the stacked-overflow parity tests.
        """
        p = self.p
        ctrl = self.arch.control_latency_s()
        L = self._lanes
        solo = L == 1
        n_state = int(members.max()) + 1 if members.size else 0
        #: per-member per-lane admission state, indexed by member value:
        #: admission time (NaN until admitted), admitted flag, and the
        #: queue whose credit threshold the admission crossed (if any)
        st_t = np.full((n_state, L), np.nan)
        st_in = np.zeros((n_state, L), dtype=bool)
        st_blk = np.full((n_state, L), None, dtype=object)

        def out(a: np.ndarray) -> np.ndarray:
            """Engine-facing view of an ``(m, L)`` time array."""
            return a[:, 0] if solo else a

        def attempt(mem: np.ndarray, t_arr: np.ndarray) -> None:
            def part(mb: np.ndarray, t_enq: np.ndarray) -> None:
                land(mem[mb], t_enq)

            self._push_transit(t_arr, size, flow, combos_of(mem),
                               on_part=part)

        def land(mem: np.ndarray, t_enq: np.ndarray) -> None:
            T = t_enq.reshape(mem.size, L)
            for gkey, queues, pos in groups_of(mem):
                sub = mem[pos]
                already = st_in[sub]
                t_use = np.where(already, st_t[sub], T[pos])
                acc, blocked_on = self._enqueue_batch(queues, t_use,
                                                      skip=already)
                st_t[sub] = np.where(acc, t_use, st_t[sub])
                in_now = already | acc
                st_in[sub] = in_now
                if blocked_on is not None:
                    blk_mask = np.not_equal(blocked_on, None)
                    for r, lane in zip(*np.nonzero(blk_mask)):
                        st_blk[sub[r], lane] = blocked_on[r, lane]
                    self.blocked += blk_mask.sum(axis=0)
                # attempted lanes that stayed out: one reject each
                self.rejected += (~already & ~in_now).sum(axis=0)
                pilot_in = in_now[:, 0]
                rej = np.nonzero(~pilot_in)[0]
                if rej.size:
                    attempt(sub[rej], out(t_use[rej]) + p.publish_retry_s)
                ok = np.nonzero(pilot_in)[0]
                if ok.size == 0:
                    continue
                if not solo:
                    # pilot admitted: the member's schedule is fixed;
                    # lanes that still rejected it resolve their retry
                    # cadence locally against their own depart cursor
                    tracked = [q for q in queues if q["track"]]
                    for k in ok:
                        for lane in np.nonzero(~in_now[k, 1:])[0] + 1:
                            t_adm, extra, bq = self._lane_admit(
                                tracked, lane, float(t_use[k, lane]))
                            self.rejected[lane] += extra
                            st_t[sub[k], lane] = t_adm
                            st_in[sub[k], lane] = True
                            if bq is not None:
                                st_blk[sub[k], lane] = bq
                                self.blocked[lane] += 1
                t_fin = st_t[sub]
                if set_confirms is None:
                    deliver(gkey, sub[ok], out(t_fin[ok]))
                    continue
                if bool(acc.all()) and blocked_on is None:
                    # hot path (no reject, no credit event, anywhere):
                    # bulk confirms, one prefix advance
                    set_confirms(sub, out(t_fin) + ctrl)
                    deliver(gkey, sub, out(t_fin))
                    mark_confirmed(sub)
                    continue
                now = []
                any_deferred = None
                for k in ok:
                    mk = int(sub[k])
                    tc = t_fin[k] + ctrl
                    blk = st_blk[mk]
                    if blk[0] is None:
                        # non-pilot blocked lanes: best-effort resume
                        # clock from the lane's own depart heap, now
                        for lane in range(1, L):
                            if blk[lane] is not None:
                                tc[lane] = max(tc[lane],
                                               self._lane_resume_time(
                                                   blk[lane], lane))
                        set_confirms(np.array([mk]), out(tc[None, :]))
                        now.append(mk)
                    else:
                        # credit flow: withhold this confirm until the
                        # pump drains the pilot's queue to flow_resume;
                        # other blocked lanes read their own resume
                        # clocks when the resolver fires
                        any_deferred = blk[0]

                        def resolver(t_res: float, mk: Any = mk,
                                     tc: Any = tc,
                                     blk: Any = blk) -> None:
                            tv = tc.copy()
                            tv[0] = t_res
                            for lane in range(1, L):
                                if blk[lane] is not None:
                                    tv[lane] = max(
                                        tv[lane], self._lane_resume_time(
                                            blk[lane], lane))
                            set_confirms(np.array([mk]), out(tv[None, :]))
                            mark_confirmed(np.array([mk]))
                        blk[0]["deferred"].append(resolver)
                deliver(gkey, sub[ok], out(t_fin[ok]))
                if now:
                    mark_confirmed(np.asarray(now, dtype=int))
                if any_deferred is not None:
                    self._try_resume(any_deferred)

        attempt(members, t0)

    # -- main ------------------------------------------------------------------
    def _setup(self) -> None:
        """Build the pattern topology and launch the initial publish
        rounds (everything up to draining the event heap)."""
        pat = self.spec.pattern
        if pat in ("work_sharing", "feedback"):
            self._setup_work(feedback=(pat == "feedback"))
        elif pat in ("broadcast", "broadcast_gather"):
            self._setup_broadcast(gather=(pat == "broadcast_gather"))
        else:
            raise ValueError(f"unknown pattern {pat!r}")

    def run(self) -> RunResult:
        if self._lanes > 1:
            raise RuntimeError("this engine was built with stack_seeds; "
                               "use run_stacked()")
        self._setup()
        self._drain_all()
        return self._finalize()

    # -- work sharing (+ feedback) --------------------------------------------
    def _setup_work(self, feedback: bool) -> None:
        spec, p, inv = self.spec, self.p, self.inv
        nP, nC = spec.n_producers, spec.n_consumers
        per_producer = spec.total_messages // nP
        size = spec.workload.payload_bytes
        flush = self.arch.client_flush_s()
        W = max(2, min(p.confirm_window, p.window_bytes // size))

        # declare order matches the heap engine: work queues first (homes
        # round-robin from 0; per-tenant vhost queues in tenant order),
        # then per-producer reply queues
        nq, q_consumers, prod_queues, q_pubs = self._work_topology()
        q_home = np.arange(nq) % inv.n_dsn
        reply_home = (nq + np.arange(nP)) % inv.n_dsn

        pr_node = np.arange(nP) % inv.n_producer_nodes
        pr_bnode = np.arange(nP) % inv.n_dsn
        c_node = np.arange(nC) % inv.n_consumer_nodes
        c_bnode = (np.arange(nC) + 1) % inv.n_dsn

        i_idx = np.broadcast_to(np.arange(per_producer), (nP, per_producer))
        pr_idx = np.broadcast_to(np.arange(nP)[:, None], (nP, per_producer))
        # producer pr round-robins over its own queue list (all queues
        # when shared; its tenant's vhost queues when isolated)
        msg_q = np.empty((nP, per_producer), dtype=int)
        for pr in range(nP):
            ql = np.asarray(prod_queues[pr])
            msg_q[pr] = ql[(pr + np.arange(per_producer)) % ql.size]

        lanes = () if self._lanes == 1 else (self._lanes,)
        confirms = np.zeros((nP, per_producer) + lanes)
        pub_start = np.zeros((nP, per_producer) + lanes)
        consume_t = np.full((nP * per_producer,) + lanes, np.nan)
        rtts = (np.full((nP * per_producer,) + lanes, np.nan)
                if feedback else None)
        recv_req = self._recv_latency(size)
        reply_size = max(1, int(size * p.reply_factor))
        recv_rep = self._recv_latency(reply_size)

        # queue states: work queues see all nP producers' credit, reply
        # queues are exempt from credit flow (the heap engine never
        # withholds reply confirms) but share the byte cap
        cap = (p.queue_max_bytes // size if p.queue_max_bytes else None)
        rcap = (p.queue_max_bytes // reply_size if p.queue_max_bytes
                else None)
        work_q = [self._queue_state(("work", qi), q_consumers[qi], size,
                                    credit=FLOW_CREDIT * q_pubs[qi],
                                    cap_msgs=cap)
                  for qi in range(nq)]
        if feedback:
            for pr in range(nP):
                self._queue_state(("reply", pr), [nC + pr], reply_size,
                                  cap_msgs=rcap)

        R = max(1, min(W, self._round))
        # flow-control events reachable (byte cap below the per-queue
        # volume, or a publish surplus that can pile backlog past the
        # credit threshold): per-message rounds reproduce the heap
        # engine's burst-and-retry dynamics at the blocking boundary
        if self.p.vec_round is None and self.flow_events_possible():
            R = 1
        n_rounds = -(-per_producer // R)
        # per-producer resolved-confirm prefixes: round r may launch once
        # every confirm its send gates read (indices < hi - W) is
        # resolved.  Message-granular like the heap engine's confirm
        # window, so a credit-flow deferral stalls exactly the sends it
        # gates — the producers still land W more messages first.
        conf_ok = np.zeros((nP, per_producer), dtype=bool)
        prefix = np.zeros(nP, dtype=np.int64)
        state = {"next_launch": 0}

        def mark_confirmed(pr_arr: np.ndarray,
                           i_arr: np.ndarray) -> None:
            conf_ok[pr_arr, i_arr] = True
            for pr in np.unique(pr_arr):
                j = int(prefix[pr])
                while j < per_producer and conf_ok[pr, j]:
                    j += 1
                prefix[pr] = j
            advance_pubs()

        def advance_pubs() -> None:
            while state["next_launch"] < n_rounds:
                r = state["next_launch"]
                need = min((r + 1) * R, per_producer) - W
                if need > 0 and int(prefix.min()) < need:
                    return
                state["next_launch"] += 1
                launch_pub(r)

        # tenant-aware hop graphs: combos carry the client's tenant as a
        # trailing column (the path constructors' 4th argument)
        tcols = self._tenant_cols
        ppt, cpt = self._ppt, self._cpt

        def _tenant_col(base: np.ndarray, tenant: np.ndarray) -> np.ndarray:
            if not tcols:
                return base
            return np.concatenate([base, tenant[:, None]], axis=1)

        combos_del_by_q = {qi: (lambda mem, cons, qi=qi:
                                _tenant_col(
                                    np.stack([c_bnode[cons],
                                              np.full(cons.size, q_home[qi]),
                                              c_node[cons]], axis=1),
                                    cons // cpt))
                           for qi in range(nq)}

        def on_seen_del(mem: np.ndarray, t_done: np.ndarray,
                        cons: np.ndarray) -> None:
            consume_t[mem] = t_done
            if feedback:
                launch_reply(mem, t_done, cons)

        def launch_pub(r: int) -> None:
            lo, hi = r * R, min((r + 1) * R, per_producer)
            i_blk = np.arange(lo, hi)
            gate = np.zeros((nP, i_blk.size) + lanes)
            m_g = i_blk >= W
            gate[:, m_g] = confirms[:, i_blk[m_g] - W]
            s_blk = gate + flush
            pub_start[:, i_blk] = s_blk
            flat_pr = pr_idx[:, i_blk].ravel()
            flat_i = i_idx[:, i_blk].ravel()
            flat_q = msg_q[:, i_blk].ravel()

            def combos_of(mem: np.ndarray) -> np.ndarray:
                return _tenant_col(
                    np.stack([pr_node[flat_pr[mem]],
                              pr_bnode[flat_pr[mem]],
                              q_home[flat_q[mem]]], axis=1),
                    flat_pr[mem] // ppt)

            def groups_of(mem: np.ndarray) -> Iterator[tuple]:
                qs = flat_q[mem]
                for qi in np.unique(qs):
                    yield (int(qi), [work_q[int(qi)]],
                           np.nonzero(qs == qi)[0])

            def set_conf(mem: np.ndarray, t_conf: np.ndarray) -> None:
                confirms[flat_pr[mem], flat_i[mem]] = t_conf

            def mark(mem: np.ndarray) -> None:
                mark_confirmed(flat_pr[mem], flat_i[mem])

            def deliver(qi: int, mem: np.ndarray,
                        t_enq: np.ndarray) -> None:
                self._deliver_queue(
                    ("work", qi), q_consumers[qi], t_enq,
                    flat_pr[mem] * per_producer + flat_i[mem],
                    combos_del_by_q[qi], size, "delivery_path",
                    consumer=True, recv=recv_req, on_seen=on_seen_del)

            self._publish_with_retry(
                np.arange(flat_pr.size),
                s_blk.reshape((nP * i_blk.size,) + lanes),
                flow="publish_path", size=size, combos_of=combos_of,
                groups_of=groups_of, deliver=deliver,
                set_confirms=set_conf, mark_confirmed=mark)

        def launch_reply(members: np.ndarray, t_done: np.ndarray,
                         cons: np.ndarray) -> None:
            # members are global message indices; producer = index // n
            mem_arr, cns_arr = members, cons

            def combos_of(pos: np.ndarray) -> np.ndarray:
                return _tenant_col(
                    np.stack([c_node[cns_arr[pos]],
                              c_bnode[cns_arr[pos]],
                              reply_home[mem_arr[pos] // per_producer]],
                             axis=1),
                    cns_arr[pos] // cpt)

            def groups_of(pos: np.ndarray) -> Iterator[tuple]:
                prs = mem_arr[pos] // per_producer
                for pr in np.unique(prs):
                    yield (int(pr), [self._queues[("reply", int(pr))]],
                           np.nonzero(prs == pr)[0])

            def deliver(pr: int, pos_sel: np.ndarray,
                        t_renq: np.ndarray) -> None:
                def combos_fn(sub_mem: np.ndarray, _cons: np.ndarray,
                              pr: int = pr) -> np.ndarray:
                    row = [reply_home[pr], pr_bnode[pr], pr_node[pr]]
                    if tcols:
                        row.append(pr // ppt)
                    return np.broadcast_to(row, (sub_mem.size, len(row)))

                def on_seen(sub_mem: np.ndarray, t_seen: np.ndarray,
                            _cons: np.ndarray) -> None:
                    flat_pub = pub_start.reshape(
                        (nP * per_producer,) + lanes)
                    rtts[sub_mem] = t_seen - flat_pub[sub_mem]

                self._deliver_queue(
                    ("reply", pr), [nC + pr], t_renq, mem_arr[pos_sel],
                    combos_fn, reply_size, "reply_delivery_path",
                    consumer=False, recv=recv_rep, on_seen=on_seen)

            self._publish_with_retry(
                np.arange(mem_arr.size), t_done,
                flow="reply_publish_path", size=reply_size,
                combos_of=combos_of, groups_of=groups_of, deliver=deliver)

        advance_pubs()
        self._fin_consume, self._fin_rtts = consume_t, rtts
        self._fin_pub = pub_start
        self._fin_confirms = confirms

    # -- broadcast (+ gather) --------------------------------------------------
    def _setup_broadcast(self, gather: bool) -> None:
        spec, p, inv = self.spec, self.p, self.inv
        nC = spec.n_consumers
        assert spec.n_producers == 1, "broadcast patterns use one producer"
        per_producer = spec.total_messages  # // nP with nP == 1
        size = spec.workload.payload_bytes
        flush = self.arch.client_flush_s()
        W = max(2, min(p.confirm_window, p.window_bytes // size))

        bq_home = np.arange(nC) % inv.n_dsn        # bq:c declared in order
        gather_home = nC % inv.n_dsn               # declared after the bqs
        pnode, pbnode = 0 % inv.n_producer_nodes, 0
        c_node = np.arange(nC) % inv.n_consumer_nodes
        c_bnode = (np.arange(nC) + 1) % inv.n_dsn

        lanes = () if self._lanes == 1 else (self._lanes,)
        confirms = np.zeros((per_producer,) + lanes)
        pub_start = np.zeros((per_producer,) + lanes)
        consume_t = np.full((per_producer * nC,) + lanes, np.nan)
        rtts = (np.full((per_producer * nC,) + lanes, np.nan)
                if gather else None)
        recv_req = self._recv_latency(size)
        reply_size = max(1, int(size * p.reply_factor))
        recv_rep = self._recv_latency(reply_size)

        # fanout targets: reject-publish is atomic across all of them, and
        # the first flow-blocked target withholds the confirm (heap broker)
        cap = (p.queue_max_bytes // size if p.queue_max_bytes else None)
        rcap = (p.queue_max_bytes // reply_size if p.queue_max_bytes
                else None)
        bqs = [self._queue_state(("bq", c), [c], size,
                                 credit=FLOW_CREDIT, cap_msgs=cap)
               for c in range(nC)]
        if gather:
            self._queue_state(("gather",), [nC], reply_size, cap_msgs=rcap)

        R = max(1, min(W, self._round))
        # flow-control events reachable on the fanout targets: see
        # _setup_work
        if self.p.vec_round is None and self.flow_events_possible():
            R = 1
        n_rounds = -(-per_producer // R)
        # resolved-confirm prefix of the single producer (see _run_work)
        conf_ok = np.zeros(per_producer, dtype=bool)
        state = {"next_launch": 0, "prefix": 0}

        def mark_confirmed(i_arr: np.ndarray) -> None:
            conf_ok[i_arr] = True
            j = state["prefix"]
            while j < per_producer and conf_ok[j]:
                j += 1
            state["prefix"] = j
            advance_pubs()

        def advance_pubs() -> None:
            while state["next_launch"] < n_rounds:
                r = state["next_launch"]
                need = min((r + 1) * R, per_producer) - W
                if need > 0 and state["prefix"] < need:
                    return
                state["next_launch"] += 1
                launch_pub(r)

        def launch_pub(r: int) -> None:
            lo, hi = r * R, min((r + 1) * R, per_producer)
            i_blk = np.arange(lo, hi)
            gate = np.zeros((i_blk.size,) + lanes)
            m_g = i_blk >= W          # rounds can straddle the window edge
            gate[m_g] = confirms[i_blk[m_g] - W]
            s_blk = gate + flush
            pub_start[i_blk] = s_blk

            def combos_of(mem: np.ndarray) -> np.ndarray:
                # a fanout publish transits once, to the exchange's home
                return np.broadcast_to([pnode, pbnode, 0], (mem.size, 3))

            def groups_of(mem: np.ndarray) -> Iterator[tuple]:
                # one admission group: reject-publish and credit flow are
                # atomic across every fanout target (heap broker)
                yield None, bqs, np.arange(mem.size)

            def set_conf(mem: np.ndarray, t_conf: np.ndarray) -> None:
                confirms[i_blk[mem]] = t_conf

            def mark(mem: np.ndarray) -> None:
                mark_confirmed(i_blk[mem])

            def deliver(_g: object, mem: np.ndarray,
                        t_enq: np.ndarray) -> None:
                launch_del(i_blk[mem], t_enq)

            self._publish_with_retry(
                np.arange(i_blk.size), s_blk, flow="publish_path",
                size=size, combos_of=combos_of, groups_of=groups_of,
                deliver=deliver, set_confirms=set_conf,
                mark_confirmed=mark)

        def launch_del(i_part: np.ndarray, t_enq: np.ndarray) -> None:
            # replicate to every per-consumer queue; deliver each copy
            for c in range(nC):
                gidx_c = c * per_producer + i_part

                def combos_fn(members: np.ndarray, cons: np.ndarray,
                              c: int = c) -> np.ndarray:
                    return np.broadcast_to(
                        [c_bnode[c], bq_home[c], c_node[c]],
                        (members.size, 3))

                def on_seen(members: np.ndarray, t_done: np.ndarray,
                            cons: np.ndarray, c: int = c) -> None:
                    consume_t[members] = t_done
                    if gather:
                        launch_reply(members, t_done, c)

                self._deliver_queue(
                    ("bq", c), [c], t_enq, gidx_c, combos_fn, size,
                    "delivery_path", consumer=True, recv=recv_req,
                    on_seen=on_seen)

        def launch_reply(members: np.ndarray, t_done: np.ndarray,
                         c: int) -> None:
            # members are global copy indices (c * per_producer + i)
            mem_arr = members

            def combos_of(pos: np.ndarray) -> np.ndarray:
                return np.broadcast_to(
                    [c_node[c], c_bnode[c], gather_home], (pos.size, 3))

            def groups_of(pos: np.ndarray) -> Iterator[tuple]:
                yield None, [self._queues[("gather",)]], np.arange(pos.size)

            def deliver(_g: object, pos_sel: np.ndarray,
                        t_renq: np.ndarray) -> None:
                def combos_fn(sub_members: np.ndarray,
                              _cons: np.ndarray) -> np.ndarray:
                    return np.broadcast_to(
                        [gather_home, pbnode, pnode],
                        (sub_members.size, 3))

                def on_seen(sub_members: np.ndarray, t_seen: np.ndarray,
                            _cons: np.ndarray) -> None:
                    rtts[sub_members] = (
                        t_seen - pub_start[sub_members % per_producer])

                self._deliver_queue(
                    ("gather",), [nC], t_renq, mem_arr[pos_sel],
                    combos_fn, reply_size, "reply_delivery_path",
                    consumer=False, recv=recv_rep, on_seen=on_seen)

            self._publish_with_retry(
                np.arange(mem_arr.size), t_done,
                flow="reply_publish_path", size=reply_size,
                combos_of=combos_of, groups_of=groups_of, deliver=deliver)

        advance_pubs()
        self._fin_consume, self._fin_rtts = consume_t, rtts
        self._fin_pub = pub_start
        self._fin_confirms = confirms

    # -- shared result assembly ------------------------------------------------
    def _finalize(self) -> RunResult:
        """Assemble the RunResult from the state ``_setup_*`` recorded
        (split from :meth:`run` so the stacked path can drain before
        finalizing each lane)."""
        return self._result(self.spec, self._fin_consume, self._fin_rtts,
                            self._fin_pub.reshape(-1))

    def _finalize_stacked(self) -> list:
        """Per-lane results of a stacked run: lane ``s`` is the cell run
        with ``stack_seeds[s]``.  Flow-control counters (rejected /
        blocked confirms) are lane-resolved — each lane's own admission
        decisions against its own credit backlog and depart cursor; only
        the event count is a scheduling-level quantity shared by all
        lanes (the pilot's cohorts)."""
        import dataclasses
        pub = self._fin_pub.reshape(-1, self._lanes)
        out = []
        for s, seed in enumerate(self.stack_seeds):
            spec_s = dataclasses.replace(
                self.spec, params=dataclasses.replace(self.p, seed=seed))
            out.append(self._result(
                spec_s, self._fin_consume[:, s],
                None if self._fin_rtts is None else self._fin_rtts[:, s],
                pub[:, s], lane=s))
        return out

    def run_stacked(self) -> list:
        """Run all ``stack_seeds`` lanes in one batched event loop and
        return their per-lane results (in ``stack_seeds`` order).

        The pilot lane (``stack_seeds[0]``) is bit-identical to a solo
        :meth:`run` of the same spec — it drives every scheduling
        decision with its own clock, including every broker admission
        decision (reject-publish, credit blocking).  The other lanes run
        the *same schedule* (cohort splits, delivery assignment, chunk
        boundaries) with their own jitter streams, resources, FIFO
        carries, **and their own flow-control accounting**: per-lane
        credit backlogs, depart cursors, reject-retry cadences and
        deferred-confirm clocks (see :meth:`_publish_with_retry`), so
        per-lane rejected/blocked counters are lane-resolved even in the
        overflow regime.  Non-overflow lanes deviate from a solo run by
        the same ordering-slack class of approximation as
        ``vec_horizon_s`` (well under 1% on aggregate summaries, see
        tests/test_campaign.py); overflow-regime lanes stay within 5% of
        their solo heap runs (tests/test_engine_parity.py)."""
        if self._lanes == 1:
            return [self.run()]
        self._setup()
        self._drain_all()
        return self._finalize_stacked()

    def _result(self, spec: ExperimentSpec, consume_t: np.ndarray,
                rtts: Optional[np.ndarray],
                pub_start: np.ndarray, lane: int = 0) -> RunResult:
        # arrays are indexed pr*per_producer + i (work patterns) or
        # c*per_producer + i (broadcast), so producer attribution falls
        # out of the finite-entry indices
        fin_c = np.isfinite(consume_t)
        consume_t = consume_t[fin_c]
        fin_r = np.isfinite(rtts) if rtts is not None else None
        r = rtts[fin_r] if rtts is not None else np.zeros(0)
        per_producer = max(1, spec.total_messages // spec.n_producers)
        if spec.pattern.startswith("broadcast"):
            cp = np.zeros(consume_t.size, dtype=np.int64)
            rp = np.zeros(r.size, dtype=np.int64)
        else:
            cp = np.flatnonzero(fin_c) // per_producer
            rp = (np.flatnonzero(fin_r) // per_producer
                  if fin_r is not None else np.zeros(0, dtype=np.int64))
        top = float(consume_t.max()) if consume_t.size else 0.0
        if r.size:
            top = max(top, float(r.max()))
        return RunResult(
            spec=spec, feasible=True,
            consume_times=consume_t,
            rtts=r,
            publish_starts=np.sort(pub_start),
            rejected_publishes=int(self.rejected[lane]),
            blocked_confirms=int(self.blocked[lane]),
            redelivered=0,
            sim_time=top, n_events=self.n_events,
            consume_producers=cp, rtt_producers=rp)


ENGINES["vectorized"] = VectorizedStreamSim


# ---------------------------------------------------------------------------
# Stacked multi-run execution (the campaign layer's batched entry point)
# ---------------------------------------------------------------------------


def _stack_key(spec: ExperimentSpec) -> tuple:
    """Cells that differ only in ``params.seed`` stack into one run."""
    import dataclasses
    return (spec.pattern, spec.arch, spec.workload, spec.n_producers,
            spec.n_consumers, spec.total_messages,
            getattr(spec, "tenants", 1),
            getattr(spec, "tenant_isolation", "shared"),
            repr(sorted(dataclasses.replace(
                spec.params, seed=0).__dict__.items())))


#: stacked lanes per run are chunked to bound the array working set
STACK_MAX_LANES = 16


def run_many(specs: Sequence[ExperimentSpec],
             inventory: Optional[ClusterInventory] = None
             ) -> list[RunResult]:
    """Run several experiments, stacking structurally-identical cells.

    The campaign layer's batched entry point: cells that differ only in
    their seed (the paper's 3-run averaging, or wider seed sweeps) are
    grouped and pushed through one :meth:`VectorizedStreamSim.run_stacked`
    event loop as stacked cohort lanes — the batched run costs barely
    more than a single solo run, instead of ``n_seeds`` times as much.
    This includes overflow-regime cells (explicit ``queue_max_bytes``
    caps, credit-flow-reachable publish surpluses): flow control is
    lane-resolved, so each lane carries its own reject/block counters
    and admission clocks.  Only heterogeneous cells (different
    pattern/arch/consumer-count/knobs) and heap-engine cells fall back
    to per-cell solo execution.

    ``engine="jax"`` cells stack the same way (the JAX engine shares the
    stacked-lane contract); cells the JAX engine cannot take — JAX not
    importable, or an unsupported cell shape — fall back to the
    vectorized engine, recorded per cell in the result's
    ``spec.params.engine`` (campaign summaries surface it as
    ``Summary.engine``).

    Infeasible specs come back as ``feasible=False`` results, like
    :func:`~repro.core.simulator.run_experiment`.  Returns one
    :class:`RunResult` per spec, in input order."""
    import dataclasses

    from repro.core.simulator import get_engine, run_experiment
    specs = list(specs)
    results: list = [None] * len(specs)
    deferred: list = []
    for i, spec in enumerate(specs):
        if spec.params.engine == "jax":
            from repro.core import jax_engine
            ok, _why = jax_engine.jax_supported(spec)
            if not ok:
                specs[i] = dataclasses.replace(
                    spec, params=dataclasses.replace(
                        spec.params, engine="vectorized"))
    groups: dict = {}
    for i, spec in enumerate(specs):
        if spec.params.engine in ("vectorized", "jax"):
            # engine is part of params, so the key never mixes engines
            groups.setdefault(_stack_key(spec), []).append(i)
        else:
            groups[("solo", i)] = [i]
    for idxs in groups.values():
        stack = len(idxs) > 1
        if not stack:
            for i in idxs:
                results[i] = run_experiment(specs[i], inventory)
            continue
        cls = get_engine(specs[idxs[0]].params.engine)
        # one probe per group: feasibility is structural, identical
        # across the seeds
        try:
            cls(specs[idxs[0]], inventory)
        except InfeasibleConfiguration as e:
            for i in idxs:
                results[i] = RunResult(spec=specs[i], feasible=False,
                                       infeasible_reason=str(e))
            continue
        max_lanes = getattr(cls, "STACK_MAX_LANES", STACK_MAX_LANES)
        for lo in range(0, len(idxs), max_lanes):
            chunk = idxs[lo:lo + max_lanes]
            if len(chunk) == 1:
                results[chunk[0]] = run_experiment(specs[chunk[0]],
                                                   inventory)
                continue
            seeds = [specs[i].params.seed for i in chunk]
            sim = cls(specs[chunk[0]], inventory, stack_seeds=seeds)
            if getattr(sim, "_use_device_loop", lambda: False)():
                # whole-run device programs batch across *cells* too
                # (vmap-over-cells; see repro.core.jax_device_loop) —
                # defer so structurally identical grids fuse
                deferred.append((chunk, sim))
                continue
            for i, r in zip(chunk, sim.run_stacked()):
                results[i] = r
    if deferred:
        from repro.core import jax_device_loop
        lane_results = jax_device_loop.run_wave_cells(
            [sim for _, sim in deferred])
        for (chunk, _sim), rs in zip(deferred, lane_results):
            for i, r in zip(chunk, rs):
                results[i] = r
    return results


