"""The paper's primary contribution: cross-facility data streaming
architectures (DTS / PRS / MSS), the DS2HPC + SciStream + S3M deployment
machinery, a RabbitMQ-semantics broker, and the discrete-event StreamSim
evaluation engine (paper §2-§5)."""

from repro.core.architectures import (
    ALL_ARCHITECTURES, Architecture, Calibration, DirectStreaming,
    ManagedServiceStreaming, ProxiedStreaming, make_architecture)
from repro.core.broker import BrokerCluster, ClassicQueue, Message
from repro.core.campaign import (
    CampaignResult, CampaignSpec, CellSpec, cell_key, run_campaign)
from repro.core.ds2hpc import ClusterInventory, RabbitMQRelease
from repro.core.metrics import (
    jain_fairness, overhead_table, overhead_vs_baseline, rtt_cdf,
    summarize, tenant_median_rtts, tenant_throughputs,
    throughput_msgs_per_s)
from repro.core.patterns import (
    CONSUMER_SWEEP, DEPLOYMENT_ARCHS, TENANT_SWEEP, FeasibilityStudy,
    TenantPoint, crossover_point, deployment_feasibility, multi_tenant,
    overflow_stress, run_pattern, sweep)
from repro.core.s3m import ResourceSettings, S3MService
from repro.core.scistream import (
    S2CS, S2UC, establish_prs_session, provision_tenant_tunnels)
from repro.core.simulator import (
    ENGINES, Engine, ExperimentSpec, RunResult, SimConfig, SimParams,
    StreamSim, get_engine, run_experiment)
from repro.core.vectorized import VectorizedStreamSim, run_many
from repro.core.workloads import (
    DSTREAM, GENERIC, LSTREAM, WORKLOADS, Workload, get_workload)

__all__ = [
    "ALL_ARCHITECTURES", "Architecture", "BrokerCluster", "CONSUMER_SWEEP",
    "Calibration", "CampaignResult", "CampaignSpec", "CellSpec",
    "ClassicQueue", "ClusterInventory", "DEPLOYMENT_ARCHS", "DSTREAM",
    "DirectStreaming", "ENGINES", "Engine", "ExperimentSpec",
    "FeasibilityStudy", "GENERIC", "LSTREAM",
    "ManagedServiceStreaming", "Message", "ProxiedStreaming",
    "RabbitMQRelease", "ResourceSettings", "RunResult", "S2CS", "S2UC",
    "S3MService", "SimConfig", "SimParams", "StreamSim", "TENANT_SWEEP",
    "TenantPoint", "VectorizedStreamSim", "WORKLOADS", "Workload",
    "cell_key", "crossover_point", "deployment_feasibility",
    "establish_prs_session", "get_engine", "get_workload",
    "provision_tenant_tunnels",
    "jain_fairness", "make_architecture", "multi_tenant",
    "overflow_stress", "overhead_table", "overhead_vs_baseline",
    "rtt_cdf", "run_campaign", "run_experiment", "run_many",
    "run_pattern", "summarize", "sweep", "tenant_median_rtts",
    "tenant_throughputs", "throughput_msgs_per_s",
]
