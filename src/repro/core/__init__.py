"""The paper's primary contribution: cross-facility data streaming
architectures (DTS / PRS / MSS), the DS2HPC + SciStream + S3M deployment
machinery, a RabbitMQ-semantics broker, and the discrete-event StreamSim
evaluation engine (paper §2-§5)."""

from repro.core.architectures import (
    ALL_ARCHITECTURES, Architecture, Calibration, DirectStreaming,
    ManagedServiceStreaming, ProxiedStreaming, make_architecture)
from repro.core.broker import BrokerCluster, ClassicQueue, Message
from repro.core.ds2hpc import ClusterInventory, RabbitMQRelease
from repro.core.metrics import (
    overhead_table, overhead_vs_baseline, rtt_cdf, summarize,
    throughput_msgs_per_s)
from repro.core.patterns import (
    CONSUMER_SWEEP, overflow_stress, run_pattern, sweep)
from repro.core.s3m import ResourceSettings, S3MService
from repro.core.scistream import S2CS, S2UC, establish_prs_session
from repro.core.simulator import (
    ENGINES, Engine, ExperimentSpec, RunResult, SimConfig, SimParams,
    StreamSim, get_engine, run_experiment)
from repro.core.vectorized import VectorizedStreamSim
from repro.core.workloads import (
    DSTREAM, GENERIC, LSTREAM, WORKLOADS, Workload, get_workload)

__all__ = [
    "ALL_ARCHITECTURES", "Architecture", "BrokerCluster", "CONSUMER_SWEEP",
    "Calibration", "ClassicQueue", "ClusterInventory", "DSTREAM",
    "DirectStreaming", "ENGINES", "Engine", "ExperimentSpec", "GENERIC",
    "LSTREAM", "ManagedServiceStreaming", "Message", "ProxiedStreaming",
    "RabbitMQRelease", "ResourceSettings", "RunResult", "S2CS", "S2UC",
    "S3MService", "SimConfig", "SimParams", "StreamSim",
    "VectorizedStreamSim", "WORKLOADS", "Workload",
    "establish_prs_session", "get_engine", "get_workload",
    "make_architecture", "overflow_stress", "overhead_table",
    "overhead_vs_baseline", "rtt_cdf", "run_experiment", "run_pattern",
    "summarize", "sweep", "throughput_msgs_per_s",
]
