"""Cross-facility streaming architecture models: DTS, PRS, MSS (paper §2, §4).

Each architecture is an explicit *hop graph*: an ordered list of path
elements a message traverses from a producer into the streaming service
(publish path) and from the service out to a consumer (delivery path).
Elements reference *shared resources* (links, CPU pools, tunnels, ingress
workers) by key, so contention between flows is modeled where the paper's
deployments actually share hardware:

* **DTS** (§2.1/§4.3): producer —TLS/AMQPS→ NodePort on a DSN RabbitMQ node.
  Minimal-hop; per-byte TLS cost on the client links. Clients connect to a
  broker node round-robin; messages for queues homed elsewhere take an
  intra-cluster hop on the OpenShift SDN (internal network, separate from
  the NodePort-facing NICs).
* **PRS** (§2.2/§4.4, SciStream): producer —AMQP→ producer-side S2DS proxy
  —mTLS overlay tunnel→ consumer-side S2DS proxy —SDN→ RabbitMQ. Tunnel
  realizations: Stunnel (single serialized TLS flow, hard 16-connection cap
  as in the paper's deployment) or HAProxy (load-balanced, higher capacity,
  mild degradation as flow count grows). Consumers are inside the facility
  and reach the broker directly (plain AMQP — the tunnel already encrypts);
  feedback replies to external producers re-traverse the tunnel.
* **MSS** (§2.3/§4.5): producer —TLS:443→ hardware load balancer → OpenShift
  ingress (per-connection HTTP/TLS-terminating workers + shared pipe) →
  RabbitMQ; deliveries traverse the ingress in the opposite direction.

Structural facts (who shares which link, which hop carries TLS, connection
caps, which legs ride the internal SDN) are fixed from the paper's
deployment description; numeric constants that are *fit* to the paper's
measured figures live in :class:`Calibration` with provenance notes.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Optional

from repro.core.ds2hpc import ClusterInventory
from repro.core.workloads import GBIT

if TYPE_CHECKING:
    from repro.core.s3m import ManagedCluster
    from repro.core.scistream import StreamingSession


# --------------------------------------------------------------------------
# Path / resource primitives consumed by the simulator
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResourceSpec:
    """A shared contention point. kind:
    - "pipe":   FIFO byte pipe; hold = service_s + size/rate_Bps
    - "pool":   k-server pool;  hold = service_s + size*per_byte_s
    """

    key: str
    kind: str
    rate_Bps: float = 0.0
    servers: int = 1
    service_s: float = 0.0
    per_byte_s: float = 0.0
    conn_limit: Optional[int] = None   # max distinct client connections


@dataclasses.dataclass(frozen=True)
class PathElement:
    """One traversal step: occupy ``resource`` (if any), then add
    ``latency_s`` of pure propagation/processing delay."""

    resource: Optional[str]
    latency_s: float = 0.0
    # multiplier on message size at this element (TLS record + framing)
    byte_factor: float = 1.0
    extra_bytes: int = 0


@dataclasses.dataclass
class Calibration:
    """Fit parameters. Values reproduce the paper's headline measurements;
    see EXPERIMENTS.md §Paper-validation for the fit table."""

    # Client (Andes) 1 Gbps NICs: ~88% effective TCP goodput (fit: DTS/PRS
    # 1P1C Dstream in the paper's 4.4-6.3K msgs/s band).
    client_link_eff: float = 0.88
    # DSN NodePort effective bandwidth (fit: a ~5.6 Gbps aggregate DTS
    # egress cap explains both the Dstream 39K msgs/s and the Lstream
    # 685 msgs/s peaks).
    dsn_link_gbps: float = 1.87
    # OpenShift SDN internal (pod-to-pod) network between DSNs.
    dsn_internal_gbps: float = 10.0
    # Per-message wire overhead (TCP/IP + AMQP framing).
    frame_bytes: int = 1400
    # TLS per-byte inflation + per-message CPU at each TLS endpoint.
    tls_byte_factor: float = 1.02
    tls_msg_cpu_s: float = 18e-6
    # RabbitMQ per-message CPU (publish / deliver), 12-core pods -> pool.
    broker_publish_cpu_s: float = 22e-6
    broker_deliver_cpu_s: float = 18e-6
    broker_cpu_workers: int = 12
    broker_per_byte_s: float = 1.0 / (2.2e9)   # ~memcpy-bound per node
    # Client-library batching/flush delay per direction.
    client_flush_s: float = 0.4e-3
    # Small-message receive latency (Nagle / delayed-ACK / client event
    # loop) — fit: the paper's ~20 ms (DTS) / ~17 ms (PRS) Dstream RTT
    # floors. Applied on the receive side for messages < 64 KiB.
    small_msg_latency_s: float = 8.0e-3
    small_msg_threshold: int = 64 * 1024
    # Intra-cluster (SDN) hop latency when crossing broker nodes.
    intercluster_hop_s: float = 0.25e-3
    # --- PRS (SciStream) ---
    proxy_msg_cpu_s: float = 20e-6          # S2DS per-message forward cost
    proxy_latency_s: float = 0.35e-3
    # HAProxy tunnel: its event loop serializes a per-message cost on the
    # shared pipe, which makes the effective cap message-size dependent
    # (fit: Dstream PRS peak ~19K msgs/s AND Lstream plateau ~580 msgs/s
    # from one parameter pair).
    tunnel_gbps_haproxy: float = 5.0
    tunnel_msg_service_s: float = 26.5e-6
    tunnel_gbps_stunnel: float = 0.95
    stunnel_service_s: float = 25e-6        # single serialized TLS flow
    stunnel_conn_limit: int = 16            # hard cap from the paper
    # single-process HAProxy per-message cost grows mildly with flow count
    # (fit: Dstream PRS throughput stagnates/declines beyond 8 consumers)
    haproxy_flow_penalty: float = 0.010
    haproxy_penalty_after: int = 8
    # PRS pipelines TLS on a persistent tunnel => smaller client flush.
    prs_client_flush_s: float = 0.3e-3
    # --- MSS ---
    lb_latency_s: float = 0.6e-3
    # Ingress is asymmetric: inbound TLS termination + routing is expensive;
    # outbound delivery is mostly zero-copy writes. Fit: inbound 2.05 Gbps
    # (MSS Dstream 14K / Lstream ~250 publish-side caps), outbound 3.6 Gbps
    # + 29 us/msg (MSS generic broadcast ~105 copies/s, paper ~110).
    ingress_gbps: float = 2.05              # inbound
    ingress_out_gbps: float = 3.9           # outbound
    ingress_out_msg_service_s: float = 29e-6
    ingress_msg_cpu_s: float = 50e-6
    ingress_worker_MBps: float = 110.0      # per-connection worker rate
    ingress_workers: int = 8
    mss_extra_latency_s: float = 1.2e-3     # route controller / FQDN path
    # PRS keeps tunnel streams warm => slightly lower receive latency
    prs_small_msg_latency_s: float = 6.5e-3
    # --- multi-tenant DTS (per-tenant S2DS tunnels, §6 deployment study) ---
    # With several independent users, DTS stops being "one NodePort per
    # client": each tenant gets its own minimal-hop S2DS control/data
    # path (a dedicated per-tenant tunnel pair), and every tenant's
    # tunnel terminates on the facility's edge gateway (DTN) — so
    # contention moves from the broker to the shared facility ingress.
    dts_tenant_tunnel_gbps: float = 10.0    # dedicated per-tenant pair
    dts_tenant_tunnel_service_s: float = 15e-6
    # the DTN's dual-homed NIC pair (2x the DSN NodePort effective rate)
    dts_gw_gbps: float = 3.74
    dts_gw_service_s: float = 6e-6          # per-message gateway forward
    # every per-tenant tunnel is its own process on the gateway host:
    # TLS-session/context-switch pressure inflates the *per-message*
    # gateway + endpoint service as the tenant count grows past the
    # knee — the mechanism that hands the high-tenant regime to MSS
    dts_tenant_gw_penalty: float = 0.15
    dts_tenant_gw_after: int = 4


DEFAULT_CALIBRATION = Calibration()

# PRS proxy placement (paper §4.4: producer/consumer S2CS pods on two
# separate DSNs).
PPROXY_NODE = 0
CPROXY_NODE = 1


# --------------------------------------------------------------------------
# Architecture base
# --------------------------------------------------------------------------


class Architecture:
    """Base: owns resource specs + path constructors for the simulator.

    Multi-tenant deployments (paper §6): :meth:`configure` receives the
    experiment's tenant count; an architecture whose hop graph differs
    *per tenant* (DTS's dedicated per-tenant tunnels) sets
    :attr:`tenant_paths` and reads the ``tenant`` argument of the path
    constructors — both engines pass the publishing/consuming client's
    tenant.  Architectures whose tenants share one fabric (PRS's single
    proxy pair, MSS's LB+ingress) leave it False and ignore ``tenant``.
    """

    name: str = "base"
    deployment_feasibility: str = ""
    #: True when the hop graph depends on the ``tenant`` path argument
    #: (set by :meth:`configure` on tenant-aware architectures)
    tenant_paths: bool = False

    def __init__(self, inventory: Optional[ClusterInventory] = None,
                 cal: Optional[Calibration] = None) -> None:
        self.inv = inventory or ClusterInventory()
        self.cal = cal or DEFAULT_CALIBRATION
        self._specs: dict[str, ResourceSpec] = {}
        self._build_common()
        self._build()

    # -- shared infra ---------------------------------------------------------
    def _build_common(self) -> None:
        c, inv = self.cal, self.inv
        client_Bps = inv.client_link_gbps * GBIT / 8.0 * c.client_link_eff
        # client NICs are full duplex: TX and RX are separate resources
        # (plink = producer TX, plink_rx = producer RX for reply deliveries;
        #  clink = consumer RX for deliveries, clink_tx = consumer TX for
        #  reply publishes)
        for i in range(inv.n_producer_nodes):
            self._add(ResourceSpec(f"plink:{i}", "pipe", rate_Bps=client_Bps))
            self._add(ResourceSpec(f"plink_rx:{i}", "pipe", rate_Bps=client_Bps))
        for i in range(inv.n_consumer_nodes):
            self._add(ResourceSpec(f"clink:{i}", "pipe", rate_Bps=client_Bps))
            self._add(ResourceSpec(f"clink_tx:{i}", "pipe", rate_Bps=client_Bps))
        dsn_Bps = c.dsn_link_gbps * GBIT / 8.0
        int_Bps = c.dsn_internal_gbps * GBIT / 8.0
        for i in range(inv.n_dsn):
            self._add(ResourceSpec(f"dsn_in:{i}", "pipe", rate_Bps=dsn_Bps))
            self._add(ResourceSpec(f"dsn_out:{i}", "pipe", rate_Bps=dsn_Bps))
            self._add(ResourceSpec(f"dsn_int:{i}", "pipe", rate_Bps=int_Bps))
            self._add(ResourceSpec(
                f"bcpu:{i}", "pool", servers=c.broker_cpu_workers,
                per_byte_s=c.broker_per_byte_s))

    def _build(self) -> None:  # per-arch extra resources
        pass

    def configure(self, n_producers: int, n_consumers: int,
                  tenants: int = 1) -> None:
        """Experiment-size-dependent adjustments (idempotent).

        ``tenants``: how many independent workflows this deployment
        hosts (1 = the single-user figures).  Tenant-aware
        architectures build per-tenant resources here."""
        pass

    def _add(self, spec: ResourceSpec) -> None:
        self._specs[spec.key] = spec

    @property
    def resources(self) -> dict[str, ResourceSpec]:
        return dict(self._specs)

    # -- TLS bookkeeping --------------------------------------------------------
    def _tls(self, el: PathElement) -> PathElement:
        return dataclasses.replace(
            el, byte_factor=el.byte_factor * self.cal.tls_byte_factor,
            latency_s=el.latency_s + self.cal.tls_msg_cpu_s)

    # -- broker-internal legs -----------------------------------------------------
    def _broker_ingest(self, connected_node: int, home_node: int) -> list[PathElement]:
        """From the node a client is connected to, to the queue's home."""
        c = self.cal
        els = [PathElement(f"bcpu:{connected_node}",
                           latency_s=c.broker_publish_cpu_s)]
        if home_node != connected_node:
            els.append(PathElement(f"dsn_int:{connected_node}",
                                   latency_s=c.intercluster_hop_s))
            els.append(PathElement(f"bcpu:{home_node}",
                                   latency_s=c.broker_publish_cpu_s * 0.5))
        return els

    def _broker_egress(self, home_node: int, connected_node: int) -> list[PathElement]:
        """From the queue's home to the node the consumer is connected to."""
        c = self.cal
        els = [PathElement(f"bcpu:{home_node}",
                           latency_s=c.broker_deliver_cpu_s)]
        if home_node != connected_node:
            els.append(PathElement(f"dsn_int:{home_node}",
                                   latency_s=c.intercluster_hop_s))
            els.append(PathElement(f"bcpu:{connected_node}",
                                   latency_s=c.broker_deliver_cpu_s * 0.5))
        return els

    # -- paths (override) ---------------------------------------------------------
    def publish_path(self, producer_node: int, broker_node: int,
                     home_node: int, tenant: int = 0) -> list[PathElement]:
        """producer client -> enqueued at the queue's home node.
        ``tenant`` is the publishing client's tenant index; only
        :attr:`tenant_paths` architectures read it."""
        raise NotImplementedError

    def delivery_path(self, broker_node: int, home_node: int,
                      consumer_node: int, tenant: int = 0) -> list[PathElement]:
        """queue home -> consumer client, exiting via ``broker_node`` (the
        node the consumer's AMQP connection terminates on)."""
        raise NotImplementedError

    # -- feedback-pattern reverse paths ----------------------------------------
    @staticmethod
    def _swap_prefix(els: list[PathElement], frm: str, to: str) -> list[PathElement]:
        out = []
        for el in els:
            r = el.resource
            if r is not None and r.startswith(frm):
                r = to + r[len(frm):]
            out.append(dataclasses.replace(el, resource=r))
        return out

    def reply_publish_path(self, consumer_node: int, broker_node: int,
                           home_node: int, tenant: int = 0) -> list[PathElement]:
        """Consumer -> broker for replies: mirrors the producer publish path
        but from a consumer node (overridden where asymmetric).
        ``tenant`` is the *replying consumer's* tenant."""
        return self._swap_prefix(
            self.publish_path(consumer_node, broker_node, home_node,
                              tenant=tenant),
            "plink:", "clink_tx:")

    def reply_delivery_path(self, home_node: int, broker_node: int,
                            producer_node: int, tenant: int = 0) -> list[PathElement]:
        """Broker -> producer for replies: mirrors the delivery path.
        ``tenant`` is the *receiving producer's* tenant."""
        return self._swap_prefix(
            self.delivery_path(broker_node, home_node, producer_node,
                               tenant=tenant),
            "clink:", "plink_rx:")

    def control_latency_s(self) -> float:
        """One-way latency for small control frames (acks/confirms)."""
        return 0.2e-3

    def producer_conn_limit(self) -> Optional[int]:
        return None

    def client_flush_s(self) -> float:
        return self.cal.client_flush_s

    def recv_latency_s(self, size: int) -> float:
        """Receive-side client latency: flush + small-message penalty."""
        extra = (self.cal.small_msg_latency_s
                 if size < self.cal.small_msg_threshold else 0.0)
        return self.client_flush_s() + extra


# --------------------------------------------------------------------------
# DTS
# --------------------------------------------------------------------------


class DirectStreaming(Architecture):
    """§2.1/§4.3 — NodePort-exposed brokers, AMQPS end-to-end.

    **Multi-tenant mode** (``configure(tenants=T)`` with ``T > 1`` —
    the §6 deployment-feasibility study): DTS cannot hand every user a
    NodePort + firewall rule, so each tenant instead gets a dedicated
    minimal-hop S2DS control/data path — its own tunnel pair
    (``ttun:{t}``, provisioned per tenant, see
    :func:`repro.core.scistream.provision_tenant_tunnels`) terminating
    on the facility's edge gateway.  The gateway NIC (``dts_gw_in`` /
    ``dts_gw_out``) is the one link every tenant's tunnel shares, so
    multi-tenant contention appears at the facility ingress rather
    than inside the broker; per-tenant tunnel endpoints also share the
    gateway host's CPU, inflating their per-message service as the
    tenant (process) count grows (``dts_tenant_gw_penalty``)."""

    name = "dts"
    deployment_feasibility = (
        "requires firewall/iptables rules, NodePort + DNS admin; viable only "
        "within unified administrative domains")

    def configure(self, n_producers: int, n_consumers: int,
                  tenants: int = 1) -> None:
        c = self.cal
        self._tenants = tenants
        self.tenant_paths = tenants > 1
        if tenants <= 1:
            return
        over = max(0, tenants - c.dts_tenant_gw_after)
        infl = 1.0 + c.dts_tenant_gw_penalty * over
        self._add(ResourceSpec(
            "dts_gw_in", "pipe", rate_Bps=c.dts_gw_gbps * GBIT / 8.0,
            service_s=c.dts_gw_service_s * infl))
        self._add(ResourceSpec(
            "dts_gw_out", "pipe", rate_Bps=c.dts_gw_gbps * GBIT / 8.0,
            service_s=c.dts_gw_service_s * infl))
        svc = c.dts_tenant_tunnel_service_s * infl
        for t in range(tenants):
            # servers=2: the tenant's producer-side + consumer-side
            # S2DS endpoints, a dedicated (not load-balanced) pair
            self._add(ResourceSpec(
                f"ttun:{t}", "pool", servers=2, service_s=svc,
                per_byte_s=8.0 / (c.dts_tenant_tunnel_gbps * GBIT)))

    def publish_path(self, producer_node: int, broker_node: int,
                     home_node: int, tenant: int = 0) -> list[PathElement]:
        c = self.cal
        if self.tenant_paths:
            els = [
                self._tls(PathElement(f"plink:{producer_node}",
                                      extra_bytes=c.frame_bytes)),
                PathElement(f"ttun:{tenant}", latency_s=c.proxy_latency_s),
                self._tls(PathElement("dts_gw_in")),
                PathElement(f"dsn_int:{broker_node}"),
            ]
            els += self._broker_ingest(broker_node, home_node)
            return els
        els = [
            self._tls(PathElement(f"plink:{producer_node}",
                                  extra_bytes=c.frame_bytes)),
            self._tls(PathElement(f"dsn_in:{broker_node}")),
        ]
        els += self._broker_ingest(broker_node, home_node)
        return els

    def delivery_path(self, broker_node: int, home_node: int,
                      consumer_node: int, tenant: int = 0) -> list[PathElement]:
        c = self.cal
        els = self._broker_egress(home_node, broker_node)
        if self.tenant_paths:
            els += [
                PathElement(f"dsn_int:{broker_node}"),
                self._tls(PathElement("dts_gw_out",
                                      extra_bytes=c.frame_bytes)),
                PathElement(f"ttun:{tenant}", latency_s=c.proxy_latency_s),
                self._tls(PathElement(f"clink:{consumer_node}")),
            ]
            return els
        els += [
            self._tls(PathElement(f"dsn_out:{broker_node}",
                                  extra_bytes=c.frame_bytes)),
            self._tls(PathElement(f"clink:{consumer_node}")),
        ]
        return els


# --------------------------------------------------------------------------
# PRS (SciStream)
# --------------------------------------------------------------------------


class ProxiedStreaming(Architecture):
    """§2.2/§4.4 — S2DS proxies + overlay tunnel (Stunnel or HAProxy).

    **Multi-tenant mode**: PRS sits between DTS and MSS in the §6
    deployment study — every tenant multiplexes the *one* shared proxy
    pair + overlay tunnel (no per-tenant hop-graph difference, so
    ``tenant_paths`` stays False) ahead of per-tenant vhost queues.
    Contention appears at the shared tunnel: the single-process proxy's
    per-message cost grows with the number of multiplexed flows
    (``haproxy_flow_penalty``), and Stunnel's hard connection cap makes
    large tenant counts outright infeasible (the paper's missing data
    points)."""

    name = "prs"
    deployment_feasibility = (
        "moderate: proxies on pre-authorized gateway nodes (DTNs/DSNs); "
        "overcomes NAT/firewalls with centralized rules")

    def __init__(self, inventory: Optional[ClusterInventory] = None,
                 cal: Optional[Calibration] = None,
                 tunnel: str = "haproxy", num_conns: int = 1,
                 session: Optional["StreamingSession"] = None) -> None:
        if tunnel not in ("haproxy", "stunnel"):
            raise ValueError(f"unknown tunnel {tunnel!r}")
        self.tunnel = tunnel
        self.num_conns = num_conns
        self.session = session      # optional scistream.StreamingSession
        super().__init__(inventory, cal)
        self.name = f"prs-{tunnel}" + (f"-c{num_conns}" if num_conns > 1 else "")

    def _build(self) -> None:
        c = self.cal
        if self.tunnel == "stunnel":
            # One long-lived TLS flow: a single-server pool serializes all
            # messages (no load balancing) + hard connection limit.
            self._add(ResourceSpec(
                "tunnel", "pool", servers=1,
                service_s=c.stunnel_service_s,
                per_byte_s=8.0 / (c.tunnel_gbps_stunnel * GBIT),
                conn_limit=c.stunnel_conn_limit))
        else:
            self._add(ResourceSpec(
                "tunnel", "pipe",
                rate_Bps=c.tunnel_gbps_haproxy * GBIT / 8.0,
                service_s=c.tunnel_msg_service_s))
        self._add(ResourceSpec("pproxy", "pool", servers=4,
                               service_s=c.proxy_msg_cpu_s))
        self._add(ResourceSpec("cproxy", "pool", servers=4,
                               service_s=c.proxy_msg_cpu_s))

    def configure(self, n_producers: int, n_consumers: int,
                  tenants: int = 1) -> None:
        self._tenants = tenants
        if self.tunnel != "haproxy":
            return
        c = self.cal
        # the single-process proxy's event loop serializes every
        # multiplexed flow; with tenants > 1 each tenant's producers are
        # distinct flows, so the penalty already scales with the total
        over = max(0, n_producers - c.haproxy_penalty_after)
        svc = c.tunnel_msg_service_s * (1.0 + c.haproxy_flow_penalty * over)
        self._add(dataclasses.replace(self._specs["tunnel"], service_s=svc))

    def producer_conn_limit(self) -> Optional[int]:
        return self.cal.stunnel_conn_limit if self.tunnel == "stunnel" else None

    def client_flush_s(self) -> float:
        return self.cal.prs_client_flush_s

    def recv_latency_s(self, size: int) -> float:
        extra = (self.cal.prs_small_msg_latency_s
                 if size < self.cal.small_msg_threshold else 0.0)
        return self.client_flush_s() + extra

    def _tunnel_leg(self) -> list[PathElement]:
        return [self._tls(PathElement("tunnel"))]

    def publish_path(self, producer_node: int, broker_node: int,
                     home_node: int, tenant: int = 0) -> list[PathElement]:
        c = self.cal
        els = [
            # producer -> producer-side S2DS: plain AMQP inside facility
            PathElement(f"plink:{producer_node}", extra_bytes=c.frame_bytes),
            PathElement("pproxy", latency_s=c.proxy_latency_s),
        ]
        els += self._tunnel_leg()
        els += [
            PathElement("cproxy", latency_s=c.proxy_latency_s),
            # consumer-side proxy -> broker over the internal SDN
            PathElement(f"dsn_int:{CPROXY_NODE}"),
            PathElement(f"bcpu:{home_node}",
                        latency_s=c.broker_publish_cpu_s),
        ]
        return els

    def delivery_path(self, broker_node: int, home_node: int,
                      consumer_node: int, tenant: int = 0) -> list[PathElement]:
        # consumers are inside the facility: direct AMQP, no tunnel
        els = self._broker_egress(home_node, broker_node)
        els += [
            PathElement(f"dsn_out:{broker_node}", extra_bytes=self.cal.frame_bytes),
            PathElement(f"clink:{consumer_node}"),
        ]
        return els

    def reply_publish_path(self, consumer_node: int, broker_node: int,
                           home_node: int, tenant: int = 0
                           ) -> list[PathElement]:
        # consumer -> broker directly (plain AMQP inside the facility)
        els = [
            PathElement(f"clink_tx:{consumer_node}",
                        extra_bytes=self.cal.frame_bytes),
            PathElement(f"dsn_in:{broker_node}"),
        ]
        els += self._broker_ingest(broker_node, home_node)
        return els

    def reply_delivery_path(self, home_node: int, broker_node: int,
                            producer_node: int, tenant: int = 0
                            ) -> list[PathElement]:
        """Replies back to external producers re-traverse the tunnel."""
        c = self.cal
        els = [
            PathElement(f"bcpu:{home_node}", latency_s=c.broker_deliver_cpu_s),
            PathElement(f"dsn_int:{home_node}"),
            PathElement("cproxy", latency_s=c.proxy_latency_s),
        ]
        els += self._tunnel_leg()
        els += [
            PathElement("pproxy", latency_s=c.proxy_latency_s),
            PathElement(f"plink_rx:{producer_node}", extra_bytes=c.frame_bytes),
        ]
        return els


# --------------------------------------------------------------------------
# MSS
# --------------------------------------------------------------------------


class ManagedServiceStreaming(Architecture):
    """§2.3/§4.5 — FQDN:443 via hardware LB + OpenShift ingress, provisioned
    through the S3M API. Producers *and* consumers traverse LB+ingress."""

    name = "mss"
    deployment_feasibility = (
        "highest: user needs only outbound 443; facility manages routing, "
        "DNS, TLS, provisioning (S3M API)")

    def __init__(self, inventory: Optional[ClusterInventory] = None,
                 cal: Optional[Calibration] = None,
                 managed_cluster: Optional["ManagedCluster"] = None) -> None:
        self.managed_cluster = managed_cluster   # from s3m.provision_cluster
        super().__init__(inventory, cal)

    def _build(self) -> None:
        c = self.cal
        self._add(ResourceSpec("lb", "pool", servers=16, service_s=15e-6))
        self._add(ResourceSpec(
            "ingress_in", "pipe", rate_Bps=c.ingress_gbps * GBIT / 8.0))
        self._add(ResourceSpec(
            "ingress_out", "pipe",
            rate_Bps=c.ingress_out_gbps * GBIT / 8.0,
            service_s=c.ingress_out_msg_service_s))
        # per-connection HTTP/TLS-terminating workers: one connection pins
        # to one worker (single-threaded termination)
        for d in ("in", "out"):
            for w in range(c.ingress_workers):
                self._add(ResourceSpec(
                    f"ingw_{d}:{w}", "pool", servers=1,
                    service_s=c.ingress_msg_cpu_s,
                    per_byte_s=1.0 / (c.ingress_worker_MBps * 1e6)))

    def _worker(self, node: int) -> int:
        return node % self.cal.ingress_workers

    def publish_path(self, producer_node: int, broker_node: int,
                     home_node: int, tenant: int = 0) -> list[PathElement]:
        c = self.cal
        els = [
            self._tls(PathElement(f"plink:{producer_node}",
                                  extra_bytes=c.frame_bytes)),
            PathElement("lb", latency_s=c.lb_latency_s),
            self._tls(PathElement(f"ingw_in:{self._worker(producer_node)}")),
            PathElement("ingress_in", latency_s=c.mss_extra_latency_s,
                        byte_factor=c.tls_byte_factor,
                        extra_bytes=c.frame_bytes),
            PathElement(f"dsn_int:{home_node}"),
            PathElement(f"bcpu:{home_node}", latency_s=c.broker_publish_cpu_s),
        ]
        return els

    def delivery_path(self, broker_node: int, home_node: int,
                      consumer_node: int, tenant: int = 0) -> list[PathElement]:
        c = self.cal
        els = [
            PathElement(f"bcpu:{home_node}", latency_s=c.broker_deliver_cpu_s),
            PathElement(f"dsn_int:{home_node}"),
            PathElement("ingress_out", latency_s=c.mss_extra_latency_s,
                        byte_factor=c.tls_byte_factor,
                        extra_bytes=c.frame_bytes),
            self._tls(PathElement(f"ingw_out:{self._worker(consumer_node)}")),
            PathElement("lb", latency_s=c.lb_latency_s),
            self._tls(PathElement(f"clink:{consumer_node}",
                                  extra_bytes=c.frame_bytes)),
        ]
        return els

    def control_latency_s(self) -> float:
        return 0.2e-3 + self.cal.lb_latency_s + self.cal.mss_extra_latency_s


# --------------------------------------------------------------------------
# Factory
# --------------------------------------------------------------------------


def make_architecture(name: str, inventory: Optional[ClusterInventory] = None,
                      cal: Optional[Calibration] = None,
                      **kw: Any) -> Architecture:
    """``name``: dts | prs-stunnel | prs-haproxy | prs-haproxy-c4 | mss."""
    if name == "dts":
        return DirectStreaming(inventory, cal)
    if name == "mss":
        return ManagedServiceStreaming(inventory, cal, **kw)
    if name.startswith("prs"):
        parts = name.split("-")
        tunnel = parts[1] if len(parts) > 1 else "haproxy"
        num_conns = 1
        for p in parts[2:]:
            if p.startswith("c"):
                num_conns = int(p[1:])
        return ProxiedStreaming(inventory, cal, tunnel=tunnel,
                                num_conns=num_conns, **kw)
    raise ValueError(f"unknown architecture {name!r}")


ALL_ARCHITECTURES = ("dts", "prs-stunnel", "prs-haproxy", "prs-haproxy-c4", "mss")
