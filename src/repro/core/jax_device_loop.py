"""Whole-run JAX device programs for the StreamSim *wave* regime.

PR 6 put the vectorized engine's hot kernels on JAX devices but kept the
cohort event loop in Python, so end-to-end the ``jax`` engine dispatched
thousands of tiny device calls and lost to NumPy (ROADMAP item 1).  This
module inverts that control flow for the regime every deployment-grid
cell lives in: it compiles the **entire run** — admission gating by
publisher-confirm windows, hop-graph resource FIFO serving, the windowed
broker pump with prefetch gates and batched ack-multiples, feedback
replies — into one ``lax.scan`` over *generations* of messages, with
stacked seed-lanes vmapped exactly like the kernel layer and whole cells
batched by :func:`run_wave_cells` (a ``vmap``-over-cells driver in the
spirit of ``fifo_scan_cells``).

**The wave contract.**  The device program is *not* the event loop — it
is a wave-synchronous re-formulation that is exact where the regime
makes exactness cheap and banded where it does not:

* Messages advance in per-producer *generations* of ``G`` messages
  (``G <= min(confirm_window, prefetch // 2)``, shrunk until no consumer
  can see more than ``prefetch // 2`` deliveries per generation).  A
  generation's sends are gated by the confirm ring exactly like the
  engines' confirm window (message ``i`` waits on confirm ``i - W``).
* Every shared resource keeps per-chain FIFO carries across generations
  (pipes: one chain; pools: ``k`` interleaved chains with per-serve
  earliest-free ordering — the vectorized engine's pool semantics), so
  capacity/work conservation is exact and throughput parity holds at
  the vectorized engine's own band.
* Cross-phase service *order* inside one generation is
  publish -> deliver -> reply rather than globally time-sorted, so
  latency-sensitive metrics (RTT) on **saturated** cells carry a wider
  tolerance than the cohort engines (see ``repro.core.parity``
  ``device_loop.*`` bands and docs/engines.md).
* Acks flush at every ``ack_batch`` boundary *and* at generation end
  (the engines flush on prefetch pressure instead); jitter draws are
  re-realized per lane from the same per-seed streams (identical
  distribution, different realization than the cohort engines).

**Backends.**  The whole program is written once against a tiny ``ops``
namespace with two implementations: ``jax`` (``lax.scan`` +
``associative_scan`` segmented FIFO closed forms, jitted under the
scoped-x64 contract of :mod:`repro.core.jax_engine`) and ``numpy`` (a
plain Python generation loop over the *same* step function).  The NumPy
backend is the step-for-step oracle: ``tests/test_flow_control_props.py``
property-tests that both backends produce the same per-generation trace.

**Pallas.**  The hottest fused step — the pump window assignment
(round-robin consumer pick + prefetch-ring gate + depart clamp) — has a
Pallas TPU kernel (:func:`_pump_assign_pallas`) behind a
:func:`pallas_enabled` capability gate with the XLA closed form as
fallback; on CPU hosts the kernel is exercised in interpreter mode by
the test suite (``REPRO_PALLAS=interpret``).

Pad-and-mask: member axes pad to the next power of two with invalid
members carrying ``+inf`` clocks, zero holds and dummy carry chains —
inert by the same contract as the kernel layer (property-tested).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable, Optional

import numpy as np

from repro.core.simulator import ExperimentSpec
from repro.core.vectorized import VectorizedStreamSim, _align_paths

Array = Any

_INF = np.inf
#: integer sentinel for "no next ack boundary" (never survives: the last
#: valid member of every consumer segment is always a boundary)
_IBIG = np.int64(2) ** 40


# ---------------------------------------------------------------------------
# Capability gates
# ---------------------------------------------------------------------------


def pallas_enabled() -> str:
    """Capability gate for the Pallas pump-assignment kernel.

    Returns ``"compiled"`` on a TPU backend, ``"interpret"`` when forced
    via ``REPRO_PALLAS=interpret`` (CPU CI exercises the kernel this
    way), and ``""`` (use the XLA fallback) otherwise."""
    mode = os.environ.get("REPRO_PALLAS", "")
    try:
        import jax
        from jax.experimental import pallas as pl  # noqa: F401
    except Exception:
        return ""
    if mode == "interpret":
        return "interpret"
    try:
        if jax.default_backend() == "tpu":
            return "compiled"
    except Exception:
        return ""
    return ""


def device_loop_supported(spec: ExperimentSpec) -> tuple[bool, str]:
    """Can the whole-run device program take this cell?  ``(ok, why)``.

    Requires JAX, a work-sharing/feedback pattern, a statically
    flow-event-free regime (no credit blocking / reject-publish
    reachable — the wave program carries no retry machinery), and a
    generation size that keeps every consumer under half its prefetch
    per generation.  Unsupported cells silently use the ordinary
    per-cohort jax path; this is a *request*, not a demand."""
    from repro.core.jax_engine import jax_available
    if not jax_available():
        return False, "jax is not importable in this environment"
    if spec.pattern not in ("work_sharing", "feedback"):
        return False, f"pattern {spec.pattern!r} is not wave-formulated"
    sim = VectorizedStreamSim(spec)
    return _device_loop_ok(sim)


#: calibration knobs for the parity harness (tests never set these):
#: force the reply-lag / egress-lag generation offsets instead of the
#: static estimate in build_static
_FORCE_DELAY: Optional[int] = None
_FORCE_DEGR: Optional[int] = None


def _device_loop_ok(sim: VectorizedStreamSim) -> tuple[bool, str]:
    spec, p = sim.spec, sim.p
    if spec.pattern not in ("work_sharing", "feedback"):
        return False, f"pattern {spec.pattern!r} is not wave-formulated"
    if spec.total_messages // max(1, spec.n_producers) < 1:
        return False, "fewer messages than producers"
    if sim.flow_events_possible():
        return False, ("flow-control events (credit blocking / overflow) "
                       "are reachable; the wave program models neither")
    G = _pick_generation(sim)
    if G is None:
        return False, ("no generation size keeps every consumer under "
                       "prefetch/2 deliveries per generation")
    # Universal run-length clause (any pattern): the wave schedule's
    # lockstep generation barriers accumulate against the cohort
    # loop's continuous pipelining, so throughput deviation grows with
    # msgs/producer regardless of the confirm window or jitter
    # (measured on work_sharing dts c8: 0.4% at 128, 3.9% at 256,
    # 6.8% at 512, 8.1% at 1024 msgs/producer — crossing the 6% band
    # between 256 and 512).  Every validated cell (bench e2e rows,
    # parity suites, the calibration grid) sits at <= 256.
    if spec.total_messages // max(1, spec.n_producers) > 256:
        return False, (f"run length {spec.total_messages // max(1, spec.n_producers)}"
                       " msgs/producer > 256: generation-barrier drift "
                       "accumulates over long runs (throughput deviation "
                       "grows with nGen past the parity band)")
    if spec.pattern == "feedback":
        # The wave formulation carries feedback replies through a static
        # delay-line pipeline (a fixed reply lag in units of
        # generations).  That approximation was calibrated against the
        # cohort engines across the deployment grid and holds only in a
        # specific regime; outside it the static schedule under-tracks
        # the cohort loop's continuous pipelining by far more than any
        # parity band, so those cells stay on the per-cohort path:
        #
        # * coarse generations (G >= 4) — at G < 4 the per-generation
        #   reply-lag discretization error dominates the schedule (no
        #   constant lag fits; measured 28-57%% throughput deviation);
        # * a window that binds but does not saturate, on a run not
        #   much longer than the window (2 * G < W < M <= 2 * W with
        #   M = msgs/producer) — at W <= 2G the run is a hard window
        #   stall the cadence floor only approximates, at W >= M the
        #   window never binds (burst-then-drain, no generation
        #   cadence at all), and at M > 2W the constant reply lag
        #   drifts over the run (RTT deviation grows with nGen);
        # * not the single-broker ``mss`` arch, whose feedback cells
        #   keep structural residuals across the whole (G, W) plane.
        M = spec.total_messages // max(1, spec.n_producers)
        size = spec.workload.payload_bytes
        W = max(2, min(p.confirm_window, p.window_bytes // size))
        if spec.arch == "mss":
            return False, ("feedback on the single-broker mss arch is "
                           "outside the wave model's validated regime")
        if G < 4:
            return False, (f"feedback generations too fine (G={G} < 4): "
                           "the static reply-lag pipeline cannot track "
                           "the cohort loop at this granularity")
        if W <= 2 * G:
            return False, (f"confirm window W={W} <= 2G={2 * G}: "
                           "hard window-stall regime, outside the wave "
                           "model's validated feedback corridor")
        if W >= M:
            return False, (f"confirm window W={W} >= msgs/producer {M}: "
                           "the window never binds (burst regime), "
                           "outside the wave model's validated corridor")
        if M > 2 * W:
            return False, (f"run length {M} msgs/producer > 2W={2 * W}: "
                           "the static reply lag drifts over runs much "
                           "longer than the confirm window (measured "
                           "RTT deviation grows with nGen)")
    return True, ""


# ---------------------------------------------------------------------------
# Static build: topology -> arrays
# ---------------------------------------------------------------------------


def _pick_generation(sim: VectorizedStreamSim) -> Optional[int]:
    """Largest workable generation size G.

    Upper bounds: the cohort engines' publish *round* (the wave's
    phase interleaving granularity — publishes of one generation serve
    before deliveries of the previous at shared resources, exactly the
    convoy the vectorized engine exhibits per round, so matching its
    round keeps the distortion inside the vectorized engine's own
    bands); the confirm window; and a per-consumer load of at most
    prefetch//2 per generation, so prefetch gates always resolve
    against *earlier* generations' ack rings."""
    spec, p = sim.spec, sim.p
    nP = spec.n_producers
    size = spec.workload.payload_bytes
    W = max(2, min(p.confirm_window, p.window_bytes // size))
    nq, q_consumers, prod_queues, _ = sim._work_topology()
    budget = max(1, p.prefetch // 2)
    rnd = max(1, int(getattr(sim, "_round", 8)))
    for G in range(min(W, budget, rnd), 0, -1):
        # per-queue arrivals per generation: every producer publishing
        # into the queue lands at most ceil(G * |its queues touching q|)
        # ... message routing is round-robin, so producer pr sends at
        # most ceil(G / len(prod_queues[pr])) of a generation to q
        load_ok = True
        for qi in range(nq):
            arrivals = sum(-(-G // len(prod_queues[pr]))
                           for pr in range(nP) if qi in prod_queues[pr])
            per_consumer = -(-arrivals // max(1, len(q_consumers[qi])))
            if per_consumer > budget:
                load_ok = False
                break
        if load_ok and G <= budget:
            return G
    return None


def _path_slots(paths: dict, res_index: dict, kinds: dict,
                size: int) -> tuple[dict, int]:
    """Resolve + align a {combo_key: [PathElement]} map into per-combo
    per-slot static tuples ``(kind, rid, hold_base, lat)`` where kind is
    0 latency-only / 1 pipe / 2 pool."""
    aligned, n_slots = _align_paths(paths)
    out = {}
    for key, els in aligned.items():
        rows = []
        for el in els:
            if el is None or el.resource is None:
                rows.append((0, 0, 0.0,
                             0.0 if el is None else el.latency_s))
                continue
            spec = res_index[el.resource]
            nbytes = size * el.byte_factor + el.extra_bytes
            if spec.kind == "pipe":
                hold = spec.service_s + (
                    nbytes / spec.rate_Bps if spec.rate_Bps else 0.0)
                rows.append((1, kinds[el.resource], hold, el.latency_s))
            else:
                hold = spec.service_s + nbytes * spec.per_byte_s
                rows.append((2, kinds[el.resource], hold, el.latency_s))
        out[key] = rows
    return out, n_slots


@dataclasses.dataclass
class WaveStatic:
    """Everything the device program needs, as NumPy arrays + a
    hashable ``signature`` (the compile/vmap-batching bucket)."""

    meta: dict                 # hashable ints/flags/pool layout
    xs: dict                   # per-generation arrays, leading axis nGen
    inv: dict                  # loop-invariant arrays (tables, scalars)
    sizes: dict                # python ints used by the host wrapper

    def signature(self) -> tuple:
        return (tuple(sorted(self.meta.items())),
                tuple(sorted((k, v.shape, str(v.dtype))
                             for k, v in self.xs.items())),
                tuple(sorted((k, v.shape, str(v.dtype))
                             for k, v in self.inv.items())))


def build_static(sim: VectorizedStreamSim) -> WaveStatic:
    """Extract the wave program's static schedule from a constructed
    (but not yet run) engine instance."""
    spec, p, inv = sim.spec, sim.p, sim.inv
    arch = sim.arch
    nP, nC = spec.n_producers, spec.n_consumers
    M = spec.total_messages // nP
    size = spec.workload.payload_bytes
    reply_size = max(1, int(size * p.reply_factor))
    feedback = spec.pattern == "feedback"
    W = max(2, min(p.confirm_window, p.window_bytes // size))
    G = _pick_generation(sim)
    assert G is not None, "call _device_loop_ok first"
    G = min(G, M)
    nGen = -(-M // G)
    L = sim._lanes

    nq, q_consumers, prod_queues, _ = sim._work_topology()
    q_home = np.arange(nq) % inv.n_dsn
    reply_home = (nq + np.arange(nP)) % inv.n_dsn
    pr_node = np.arange(nP) % inv.n_producer_nodes
    pr_bnode = np.arange(nP) % inv.n_dsn
    c_node = np.arange(nC) % inv.n_consumer_nodes
    c_bnode = (np.arange(nC) + 1) % inv.n_dsn
    tcols = sim._tenant_cols
    ppt, cpt = sim._ppt, sim._cpt

    # resource registry: flat chain ids (pipes 1 chain, pools k chains)
    res_keys = sorted(sim.arch.resources)
    res_index = {k: sim.arch.resources[k] for k in res_keys}
    rid_of = {k: i for i, k in enumerate(res_keys)}
    NR = len(res_keys)
    k_arr = np.ones(NR, dtype=np.int64)
    chain_base = np.zeros(NR, dtype=np.int64)
    pools = []
    base = 0
    for k in res_keys:
        s = res_index[k]
        kk = max(1, s.servers) if s.kind == "pool" else 1
        chain_base[rid_of[k]] = base
        k_arr[rid_of[k]] = kk
        if s.kind == "pool":
            pools.append((base, kk))
        base += kk
    NCH = base                         # +1 dummy row appended by backends

    def tkey(t: int) -> tuple:
        return (t,) if tcols else ()

    # -- publish paths: one combo per (pr, q), aligned together ----------
    pub_paths = {}
    for pr in range(nP):
        for qi in prod_queues[pr]:
            pub_paths[(pr, qi)] = arch.publish_path(
                int(pr_node[pr]), int(pr_bnode[pr]), int(q_home[qi]),
                *tkey(pr // ppt))
    pub_slots, S_pub = _path_slots(pub_paths, res_index, rid_of, size)
    pub_keys = sorted(pub_slots)
    pub_idx_of = {k: i for i, k in enumerate(pub_keys)}
    pub_tab = np.zeros((len(pub_keys), S_pub, 4))
    for k, rows in pub_slots.items():
        pub_tab[pub_idx_of[k]] = rows

    # -- delivery paths: aligned per queue (like _deliver_queue batches),
    #    padded to the max slot count with inert latency-only slots -----
    del_aligned = {}
    S_del = 0
    for qi in range(nq):
        dp = {int(c): arch.delivery_path(
            int(c_bnode[c]), int(q_home[qi]), int(c_node[c]),
            *tkey(int(c) // cpt)) for c in q_consumers[qi]}
        slots, ns = _path_slots(dp, res_index, rid_of, size)
        del_aligned[qi] = slots
        S_del = max(S_del, ns)
    kq = np.array([len(q_consumers[qi]) for qi in range(nq)],
                  dtype=np.int64)
    kq_max = int(kq.max())
    q_cons_tab = np.zeros((nq, kq_max), dtype=np.int64)
    del_tab = np.zeros((nq, kq_max, S_del, 4))
    for qi in range(nq):
        for j, c in enumerate(q_consumers[qi]):
            q_cons_tab[qi, j] = int(c)
            rows = del_aligned[qi][int(c)]
            del_tab[qi, j, :len(rows)] = rows

    # -- reply paths (feedback) -----------------------------------------
    if feedback:
        rp_paths = {(int(c), pr): arch.reply_publish_path(
            int(c_node[c]), int(c_bnode[c]), int(reply_home[pr]),
            *tkey(int(c) // cpt))
            for pr in range(nP)
            for c in sorted({int(x) for qi in prod_queues[pr]
                             for x in q_consumers[qi]})}
        rp_slots, S_rp = _path_slots(rp_paths, res_index, rid_of,
                                     reply_size)
        rp_tab = np.zeros((nC, nP, S_rp, 4))
        for (c, pr), rows in rp_slots.items():
            rp_tab[c, pr] = rows
        rd_aligned = {}
        S_rd = 0
        for pr in range(nP):
            slots, ns = _path_slots(
                {0: arch.reply_delivery_path(
                    int(reply_home[pr]), int(pr_bnode[pr]),
                    int(pr_node[pr]), *tkey(pr // ppt))},
                res_index, rid_of, reply_size)
            rd_aligned[pr] = slots[0]
            S_rd = max(S_rd, ns)
        rd_tab = np.zeros((nP, S_rd, 4))
        for pr in range(nP):
            rows = rd_aligned[pr]
            rd_tab[pr, :len(rows)] = rows
    else:
        S_rp = S_rd = 0
        rp_tab = np.zeros((nC, nP, 0, 4))
        rd_tab = np.zeros((nP, 0, 4))

    # combined-serve slot axis: all legs pad to one width so each
    # step's transits run as a SINGLE serve over the concatenated
    # member axis (shared resources then see competing flows in true
    # arrival order); the extra slots are kind-0 inert pass-throughs
    S_max = max(S_pub, S_del, S_rp, S_rd)

    def pad_slots(tab: np.ndarray) -> np.ndarray:
        pad = ([(0, 0)] * (tab.ndim - 2)
               + [(0, S_max - tab.shape[-2]), (0, 0)])
        return np.pad(tab, pad)

    pub_tab, del_tab = pad_slots(pub_tab), pad_slots(del_tab)
    rp_tab, rd_tab = pad_slots(rp_tab), pad_slots(rd_tab)

    # -- per-generation member arrays ------------------------------------
    N = nP * G
    Np = 1 << max(0, N - 1).bit_length()       # pow2 pad-and-mask bucket
    pr_m = np.tile(np.repeat(np.arange(nP), G), (nGen, 1))
    loc = np.tile(np.arange(G), nP)
    valid = np.zeros((nGen, Np), dtype=bool)
    i_glob = np.zeros((nGen, Np), dtype=np.int64)
    q_m = np.zeros((nGen, Np), dtype=np.int64)
    pub_ci = np.zeros((nGen, Np), dtype=np.int64)
    mem_id = np.zeros((nGen, Np), dtype=np.int64)
    for g in range(nGen):
        ii = g * G + loc                        # per-producer msg index
        ok = ii < M
        valid[g, :N] = ok
        i_glob[g, :N] = np.minimum(ii, M - 1)
        for pr in range(nP):
            ql = np.asarray(prod_queues[pr])
            sl = slice(pr * G, (pr + 1) * G)
            qs = ql[(pr + ii[sl]) % ql.size]
            q_m[g, sl] = qs
            pub_ci[g, sl] = [pub_idx_of[(pr, int(q))] for q in qs]
        mem_id[g, :N] = pr_m[g] * M + np.minimum(ii, M - 1)
    pr_mat = np.zeros((nGen, Np), dtype=np.int64)
    pr_mat[:, :N] = pr_m
    has_gate = valid & (i_glob >= W)
    # invalid pad members write confirm slot W (a scratch column past
    # the ring) so masked writes can never collide with live slots
    conf_slot = np.where(valid, i_glob % W, W)

    # static round-robin bases: per-generation queue/consumer/producer
    # arrival counts are order-independent, so the RR cursors are
    # precomputed instead of carried
    cnt_q = np.zeros((nGen, nq), dtype=np.int64)
    cnt_c = np.zeros((nGen, nC), dtype=np.int64)
    cq = np.zeros(nq, dtype=np.int64)
    cc = np.zeros(nC, dtype=np.int64)
    for g in range(nGen):
        cnt_q[g], cnt_c[g] = cq.copy(), cc.copy()
        counts = np.bincount(q_m[g][valid[g]], minlength=nq)
        for qi in range(nq):
            n, k = int(counts[qi]), int(kq[qi])
            for pp in range(n):
                cc[q_cons_tab[qi, (cq[qi] + pp) % k]] += 1
            cq[qi] += n
    # producer reply counts: pr receives exactly its own valid msgs;
    # padded with a scratch column for the dummy reply chain
    per_gen_p = np.stack([np.bincount(pr_mat[g][valid[g]], minlength=nP)
                          for g in range(nGen)])
    cnt_p = np.concatenate([np.zeros((1, nP), dtype=np.int64),
                            np.cumsum(per_gen_p, axis=0)[:-1]])
    cnt_p = np.concatenate(
        [cnt_p, np.zeros((nGen, 1), dtype=np.int64)], axis=1)

    # software-pipelined scan inputs: step g publishes generation g and
    # delivers generation g-1; the reply legs trail by an *adaptive*
    # lag — replies for generation g re-enter the shared ingress
    # resources roughly a delivery-path-plus-receive latency after the
    # publishes, during which the confirm window lets publishes run up
    # to W/G generations ahead.  Serving reply-publish at step
    # g+1+DELAY (and reply-delivery one step later) keeps each step's
    # combined serve populated with flows whose *arrival clocks*
    # actually coexist, which is what makes arrival-order service at
    # shared chains match the engines.  Every leg's static arrays are
    # shifted by its offset, with all-False validity masks filling the
    # prologue/drain steps.
    # The reply lag DELAY (in generations) is physical, not a window
    # artifact: rp(g) enqueues one publish+delivery+receive+process
    # path-latency after pub(g), during which publishes advance one
    # generation per tau — the per-generation cadence, itself the max
    # of the busiest chain's per-generation work and the confirm-
    # window stall cadence (when W binds, a generation can only clear
    # admission every conf-roundtrip/(W/G)).  Serving reply-publish at
    # step g+1+DELAY (and reply-delivery one step later) keeps each
    # step's combined serve populated with flows whose *arrival
    # clocks* actually coexist, which is what makes arrival-order
    # service at shared chains match the engines.  Every leg's static
    # arrays are shifted by its offset, with all-False validity masks
    # filling the prologue/drain steps.
    if feedback:
        work = np.zeros((2, NR))
        for m_i in range(N):
            if not valid[0, m_i]:
                continue
            pr_i, q_i = int(pr_m[0][m_i]), int(q_m[0, m_i])
            legs = [(0, pub_tab[pub_ci[0, m_i]]), (1, del_tab[q_i, 0]),
                    (0, rp_tab[int(q_cons_tab[q_i, 0]), pr_i]),
                    (1, rd_tab[pr_i])]
            for sd, rows in legs:
                for kk_, r_, h_, _l in rows:
                    if kk_ > 0:
                        work[sd, int(r_)] += (
                            h_ / max(1, int(k_arr[int(r_)])))
        tau = float(work.max())

        def combo_sum(tab: np.ndarray) -> float:
            t = tab.reshape(-1, tab.shape[-2], 4)
            live = (t[:, :, 0] > 0).any(axis=1)
            tot = (t[:, :, 2] + t[:, :, 3]).sum(axis=1)
            return float(tot[live].mean()) if live.any() else 0.0

        lag_pub = combo_sum(pub_tab)
        # window-bound cadence floor: with at most W unconfirmed, a
        # generation clears admission every pub-confirm-roundtrip per
        # W/G outstanding generations
        tau_gen = max(tau, lag_pub / max(1.0, W / G))
        # pub enqueue -> reply-publish enqueue path latency
        lag_rp = (lag_pub + combo_sum(del_tab)
                  + sim._recv_latency(size) + sim._proc_s)
        delay = (int(np.clip(round(lag_rp / tau_gen), 1, nGen))
                 if tau_gen > 0 else 1)
        if _FORCE_DELAY is not None:       # debug/calibration knob
            delay = int(np.clip(_FORCE_DELAY, 1, nGen))
        # egress alignment: reply-deliveries re-enter the egress
        # resources a delivery + receive + reply-publish lag after the
        # corresponding deliveries, so rd(g) genuinely contends with
        # del(g + De) there.  The delivery leg is delayed by
        # dlag = delay - De so the two flows meet in the same step's
        # combined serve.  Cross-direction step offsets are free:
        # pub/rp and del/rd live on different chain copies.
        lag_e = (combo_sum(del_tab) + sim._recv_latency(size)
                 + combo_sum(rp_tab))
        d_egr = (int(np.clip(round(lag_e / tau_gen), 1,
                             max(1, delay - 1)))
                 if tau_gen > 0 else 1)
        if _FORCE_DEGR is not None:        # debug/calibration knob
            d_egr = int(np.clip(_FORCE_DEGR, 1, max(1, delay - 1)))
        dlag = delay - d_egr
    else:
        delay, d_egr, dlag = 1, 1, 0
    depth = (2 + delay) if feedback else 1
    nSteps = nGen + depth

    def shift(a: np.ndarray, by: int) -> np.ndarray:
        out = np.zeros((nSteps,) + a.shape[1:], dtype=a.dtype)
        out[by:by + nGen] = a
        return out

    meta = dict(
        Np=Np, L=L, S_pub=S_pub, S_del=S_del, S_rp=S_rp, S_rd=S_rd,
        S_max=S_max, feedback=feedback, NR=NR, NCH=NCH, nq=nq, nC=nC,
        nP=nP, kq_max=kq_max, P=int(p.prefetch), B=int(p.ack_batch),
        W=W, G=G, nGen=nGen, nSteps=nSteps, delay=delay, dlag=dlag,
        ring=d_egr, pools=tuple(pools))
    xs = dict(
        pub_valid=shift(valid, 0), pub_pr=shift(pr_mat, 0),
        pub_ci=shift(pub_ci, 0), pub_has_gate=shift(has_gate, 0),
        pub_conf_slot=shift(np.where(valid, conf_slot, W), 0),
        del_valid=shift(valid, 1 + dlag), del_q=shift(q_m, 1 + dlag),
        del_cnt_q=shift(cnt_q, 1 + dlag),
        del_cnt_c=shift(cnt_c, 1 + dlag),
        dly=np.arange(nSteps) % d_egr,
        dlyp=np.arange(nSteps) % (1 + dlag))
    xs["pub_conf_slot"][nGen:] = W      # drain steps hit the scratch slot
    if feedback:
        xs.update(rp_valid=shift(valid, 1 + delay),
                  rp_pr=shift(pr_mat, 1 + delay),
                  rp_cnt_p=shift(cnt_p, 1 + delay),
                  rd_valid=shift(valid, 2 + delay),
                  rd_pr=shift(pr_mat, 2 + delay))
    inv_arrays = dict(
        pub_tab=pub_tab, del_tab=del_tab, rp_tab=rp_tab, rd_tab=rd_tab,
        q_cons_tab=q_cons_tab, kq=kq, k_arr=k_arr, chain_base=chain_base,
        scal=np.array([arch.client_flush_s(),
                       arch.control_latency_s(),
                       sim._recv_latency(size),
                       sim._recv_latency(reply_size),
                       sim._proc_s]))
    sizes = dict(nP=nP, nC=nC, M=M, G=G, nGen=nGen, N=N, Np=Np, L=L,
                 n_jit=(4 if feedback else 2) * S_max + 1,
                 mem_id=mem_id, valid=valid)
    return WaveStatic(meta=meta, xs=xs, inv=inv_arrays, sizes=sizes)


def draw_jitter(sim: VectorizedStreamSim, ws: WaveStatic) -> dict:
    """Per-lane jitter draws for every (generation, slot, member), from
    the engine's per-seed streams.  One flat draw per lane in a fixed
    layout keeps each lane's realization independent of how many other
    lanes are stacked (lane-addition inertness by construction).
    Returned pre-shifted per pipeline leg, ready to merge into ``xs``."""
    s, m = ws.sizes, ws.meta
    j = sim.p.jitter
    raw = np.zeros((s["nGen"], s["n_jit"], s["Np"], s["L"]))
    if j:
        for lane, rng in enumerate(sim._rngs):
            raw[..., lane] = rng.uniform(
                -j, j, size=(s["nGen"], s["n_jit"], s["Np"]))
    nSteps = m["nSteps"]

    def shift(a: np.ndarray, by: int) -> np.ndarray:
        out = np.zeros((nSteps,) + a.shape[1:])
        out[by:by + s["nGen"]] = a
        return out

    S = m["S_max"]
    jit = dict(pub_jit=shift(raw[:, :S], 0),
               del_jit=shift(raw[:, S:2 * S], 1 + m["dlag"]),
               proc_jit=shift(raw[:, 2 * S], 1 + m["dlag"]))
    if m["feedback"]:
        jit["rp_jit"] = shift(raw[:, 2 * S + 1:3 * S + 1],
                              1 + m["delay"])
        jit["rd_jit"] = shift(raw[:, 3 * S + 1:], 2 + m["delay"])
    return jit


# ---------------------------------------------------------------------------
# Backend ops
# ---------------------------------------------------------------------------


class _NumpyOps:
    """Reference backend: the same step function run as a plain Python
    loop — the step-for-step oracle for the device program."""

    xp = np

    @staticmethod
    def lexsort(keys: tuple) -> np.ndarray:
        return np.lexsort(keys)

    @staticmethod
    def cummax(x: np.ndarray) -> np.ndarray:
        return np.maximum.accumulate(x, axis=0)

    @staticmethod
    def seg_cummax(x: np.ndarray, start: np.ndarray) -> np.ndarray:
        out = x.copy()
        for i in range(1, x.shape[0]):
            if not start[i]:
                out[i] = np.maximum(out[i - 1], x[i])
        return out

    @staticmethod
    def at_set(arr, idx, vals):
        out = arr.copy()
        out[idx] = vals
        return out

    @staticmethod
    def at_max(arr, idx, vals):
        out = arr.copy()
        np.maximum.at(out, idx, vals)
        return out

    @staticmethod
    def scan(step: Callable, carry: Any, xs: dict, n: int
             ) -> tuple[Any, dict]:
        ys_all: dict = {}
        for g in range(n):
            carry, ys = step(carry, {k: v[g] for k, v in xs.items()})
            for k, v in ys.items():
                ys_all.setdefault(k, []).append(v)
        return carry, {k: np.stack(v) for k, v in ys_all.items()}


def _jax_ops() -> Any:
    import jax
    import jax.numpy as jnp
    from jax import lax

    class _JaxOps:
        xp = jnp

        @staticmethod
        def lexsort(keys: tuple):
            return jnp.lexsort(keys)

        @staticmethod
        def cummax(x):
            return lax.cummax(x, axis=0)

        @staticmethod
        def seg_cummax(x, start):
            s = start.reshape(start.shape + (1,) * (x.ndim - 1))

            def comb(l, r):
                xl, sl = l
                xr, sr = r
                return (jnp.where(sr, xr, jnp.maximum(xl, xr)), sl | sr)
            y, _ = lax.associative_scan(
                comb, (x, jnp.broadcast_to(s, x.shape)))
            return y

        @staticmethod
        def at_set(arr, idx, vals):
            return arr.at[idx].set(vals)

        @staticmethod
        def at_max(arr, idx, vals):
            return arr.at[idx].max(vals)

        @staticmethod
        def scan(step, carry, xs, n):
            return lax.scan(lambda c, x: step(c, x), carry, xs, length=n)

    return _JaxOps


# ---------------------------------------------------------------------------
# The wave program (backend-generic)
# ---------------------------------------------------------------------------


def _serve_leg(ops: Any, free: Array, a: Array, hold: Array,
               kind: Array, rid: Array, lat: Array, valid: Array,
               side: Array, meta: dict, chain_base: Array,
               k_arr: Array) -> tuple:
    """FIFO-serve one aligned path slot for all members: segmented
    closed-form scans over (resource chain)-grouped members, with
    earliest-free pool server interleaving and cross-generation carries.

    ``free``: ``(2*NCH+1, L)`` per-chain busy-until carries (last row
    is the dummy chain absorbing latency-only/invalid members).  Each
    resource has TWO chain copies, one per traffic *direction*
    (``side`` 0: ingress-bound publish/reply-publish, 1: egress-bound
    delivery/reply-delivery).  Same-direction flows genuinely contend
    at the saturated gateway pipes and have comparable clock lags, so
    they share a FIFO chain; cross-direction sharing only happens at
    many-server fabric internals whose real contention is negligible —
    and a shared busy-until carry there would *invent* contention,
    because it cannot represent the idle gap between the two
    directions' disjoint usage windows.  Returns ``(free', t_out)``."""
    xp = ops.xp
    NCH = meta["NCH"]
    dummy = 2 * NCH
    idx = xp.arange(a.shape[0])      # combined (multi-leg) member axis
    is_res = (kind > 0) & valid
    pilot = xp.where(is_res, a[:, 0], _INF)
    # latency-only / invalid members get unique singleton chains past
    # the resource id space so the segmented scan leaves them alone
    rid_key = xp.where(is_res, rid + side * meta["NR"],
                       2 * meta["NR"] + idx)
    # pool-carry ordering: vectorized serves each pool with its carries
    # sorted by the pilot lane ascending (earliest-free server first)
    for (b, kk) in meta["pools"]:
        for off in (0, NCH):
            sub = free[b + off:b + off + kk]
            order = ops.lexsort((xp.arange(kk), sub[:, 0]))
            free = ops.at_set(free, xp.arange(b + off, b + off + kk),
                              sub[order])
    # stage 1: group by (resource, direction), pilot-arrival order
    # within the group
    o1 = ops.lexsort((idx, pilot, rid_key))
    rk1, a1 = rid_key[o1], a[o1]
    start1 = xp.concatenate([xp.ones(1, dtype=bool), rk1[1:] != rk1[:-1]])
    segfirst = ops.cummax(xp.where(start1, idx, -1))
    pos = idx - segfirst
    k1 = k_arr[xp.clip(rid[o1], 0, meta["NR"] - 1)]
    server = xp.where(is_res[o1], pos % k1, 0)
    chain = xp.where(is_res[o1],
                     chain_base[xp.clip(rid[o1], 0, meta["NR"] - 1)]
                     + server + side[o1] * NCH, dummy)
    chain_key = xp.where(is_res[o1], chain, dummy + 1 + idx)
    # stage 2: make each chain contiguous, preserving pilot order
    o2 = ops.lexsort((idx, chain_key))
    a2, chain2, chkey2 = a1[o2], chain[o2], chain_key[o2]
    h2 = hold[o1][o2]
    res2 = is_res[o1][o2]
    start2 = xp.concatenate([xp.ones(1, dtype=bool),
                             chkey2[1:] != chkey2[:-1]])
    carry = free[chain2]
    a_eff = xp.where(res2[:, None], xp.maximum(a2, carry), a2)
    # segmented FIFO closed form: e = H + segcummax(a - (H - h))
    c = xp.cumsum(h2, axis=0)
    basefill = ops.cummax(xp.where(start2[:, None], c - h2, -_INF))
    Hs = c - basefill
    e2 = Hs + ops.seg_cummax(a_eff - (Hs - h2), start2)
    free = ops.at_max(free, xp.where(res2, chain2, dummy), e2)
    perm = o1[o2]
    t_out = ops.at_set(xp.zeros_like(a), perm, e2 + lat[perm][:, None])
    return free, t_out


def _transit(ops: Any, free: Array, t: Array, slots: Array, jit: Array,
             valid: Array, side: Array, meta: dict, chain_base: Array,
             k_arr: Array) -> tuple:
    """Walk members through an aligned path: ``slots`` is
    ``(S, Np, 4)`` rows of (kind, rid, hold_base, lat)."""
    xp = ops.xp
    S = slots.shape[0]
    for s in range(S):
        kind = slots[s, :, 0].astype(xp.int64)
        rid = slots[s, :, 1].astype(xp.int64)
        hold = xp.where((kind > 0) & valid,
                        slots[s, :, 2], 0.0)[:, None] * (1.0 + jit[s])
        free, t = _serve_leg(ops, free, t, hold, kind, rid,
                             slots[s, :, 3], valid, side, meta,
                             chain_base, k_arr)
    return free, t


def _next_boundary(ops: Any, boundary: Array, start: Array,
                   Np: int) -> Array:
    """Index of the nearest boundary at or after each position within
    its segment (exists: segment ends are always boundaries)."""
    xp = ops.xp
    idx = xp.arange(Np)
    end = xp.concatenate([start[1:], xp.ones(1, dtype=bool)])
    r = xp.where(boundary, idx, _IBIG)[::-1]
    nb_rev = -ops.seg_cummax(-r, end[::-1])
    return nb_rev[::-1]


def _pump_assign_xla(ops: Any, ring: Array, t_ready: Array, gid: Array,
                     base_cnt: Array, idx_on: Array, valid: Array,
                     meta: dict) -> Array:
    """XLA fallback for the pump window assignment: gate each message on
    the prefetch ring of its assigned consumer and clamp the depart.
    ``gid``: consumer id per member; ``idx_on``: the message's index in
    its consumer's total delivery order."""
    xp = ops.xp
    P = meta["P"]
    gate = ring[gid, idx_on % P]
    gate = xp.where((idx_on >= P)[:, None] & valid[:, None], gate, 0.0)
    return xp.maximum(t_ready, gate)


def _pump_assign_pallas(ring: Array, t_ready: Array, gid: Array,
                        idx_on: Array, valid: Array, P: int,
                        interpret: bool) -> Array:
    """Pallas port of the pump window assignment (single-block kernel,
    in-kernel ``fori_loop`` over members, VMEM-resident prefetch ring).
    Semantically identical to :func:`_pump_assign_xla`."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    Np, L = t_ready.shape

    def kernel(ring_ref, t_ref, gid_ref, idx_ref, valid_ref, out_ref):
        def body(m, _):
            gidm = gid_ref[m]
            idxm = idx_ref[m]
            gate = ring_ref[gidm, idxm % P]
            use = (idxm >= P) & valid_ref[m]
            gate = jnp.where(use, gate, jnp.zeros_like(gate))
            out_ref[m, :] = jnp.maximum(t_ref[m, :], gate)
            return 0
        jax.lax.fori_loop(0, Np, body, 0)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((Np, L), t_ready.dtype),
        interpret=interpret,
    )(ring, t_ready, gid, idx_on, valid)


def _seg_pos(ops: Any, key_sorted: Array, Np: int) -> tuple:
    """(segment-start flags, position within segment) for a sorted
    integer key array."""
    xp = ops.xp
    idx = xp.arange(Np)
    start = xp.concatenate([xp.ones(1, dtype=bool),
                            key_sorted[1:] != key_sorted[:-1]])
    pos = idx - ops.cummax(xp.where(start, idx, -1))
    return start, pos


def _wave_step(ops: Any, meta: dict, inv: dict, carry: dict, x: dict,
               pump: Callable) -> tuple[dict, dict]:
    """One *pipelined* scan step.

    Step ``g`` publishes generation ``g``, delivers ``g-1``,
    reply-publishes ``g-2`` and reply-delivers ``g-3`` — mirroring the
    engines' steady state, where all four flows are concurrently in
    flight with exactly these generation offsets.  Every leg's arrivals
    are known at step entry (hand-off rides the ``pend_*`` carries), so
    all four transits run as ONE combined serve over a concatenated
    member axis: shared resources see the competing flows in true
    arrival order, not phase-convoy order — the property that keeps
    throughput and RTT inside the parity bands.  Scan length is
    ``nGen`` plus the pipeline depth, shifted validity masks draining
    the tail."""
    xp = ops.xp
    Np, L, P, B = meta["Np"], meta["L"], meta["P"], meta["B"]
    nC, nP, fb = meta["nC"], meta["nP"], meta["feedback"]
    flush, ctrl, recv_req, recv_rep, proc_s = (inv["scal"][i]
                                               for i in range(5))
    idx = xp.arange(Np)

    # ---- per-leg arrivals (all independent at step entry) -------------
    # publish(g): confirm-window admission gates + client flush
    v_pub, pr = x["pub_valid"], x["pub_pr"]
    gate = carry["conf"][pr, x["pub_conf_slot"]]
    gate = xp.where(x["pub_has_gate"][:, None], gate, 0.0)
    pub_start = xp.where(v_pub[:, None], gate + flush, _INF)

    # delivery(g-1): pump window assignment — per-queue arrival-order
    # round robin with prefetch-ring gates (the Pallas-ported step)
    v_del, q = x["del_valid"], x["del_q"]
    dlyp = x["dlyp"]
    t_enq_prev = carry["pend_pub"]["t_enq"][dlyp]
    pub_start_prev = carry["pend_pub"]["pub_start"][dlyp]
    oq = ops.lexsort((idx, xp.where(v_del, t_enq_prev[:, 0], _INF),
                      xp.where(v_del, q, meta["nq"])))
    q_s = q[oq]
    _, posq = _seg_pos(ops, xp.where(v_del[oq], q_s, meta["nq"]), Np)
    kqv = inv["kq"][xp.clip(q_s, 0, meta["nq"] - 1)]
    slot_c = (x["del_cnt_q"][xp.clip(q_s, 0, meta["nq"] - 1)]
              + posq) % kqv
    cons_s = inv["q_cons_tab"][xp.clip(q_s, 0, meta["nq"] - 1), slot_c]
    idx_on_c = x["del_cnt_c"][cons_s] + posq // kqv
    depart_s = pump(carry["ack"], t_enq_prev[oq], cons_s, idx_on_c,
                    v_del[oq], meta)
    cons = ops.at_set(xp.zeros(Np, dtype=cons_s.dtype), oq, cons_s)
    idxc = ops.at_set(xp.zeros(Np, dtype=idx_on_c.dtype), oq, idx_on_c)
    slotc = ops.at_set(xp.zeros(Np, dtype=slot_c.dtype), oq, slot_c)
    depart = ops.at_set(xp.zeros_like(t_enq_prev), oq, depart_s)
    depart = xp.where(v_del[:, None], depart, _INF)

    # ---- combined transit: all legs, one serve per aligned slot -------
    blocks = [
        (pub_start, v_pub,
         xp.swapaxes(inv["pub_tab"][x["pub_ci"]], 0, 1), x["pub_jit"]),
        (depart, v_del,
         xp.swapaxes(inv["del_tab"][q, slotc], 0, 1), x["del_jit"]),
    ]
    if fb:
        # the delivery->reply delay line: slot ``dly`` holds the entry
        # written ``delay`` steps ago (generation g-1-delay), which is
        # exactly the generation this step reply-publishes
        dly = x["dly"]
        pend_b = {k: v[dly] for k, v in carry["pend_del"].items()}
        pend_c = carry["pend_rep"]
        v_rp, rp_pr = x["rp_valid"], x["rp_pr"]
        v_rd, rd_pr = x["rd_valid"], x["rd_pr"]
        blocks.append(
            (pend_b["seen"], v_rp,
             xp.swapaxes(inv["rp_tab"][xp.clip(pend_b["cons"], 0,
                                               nC - 1), rp_pr], 0, 1),
             x["rp_jit"]))
        blocks.append(
            (pend_c["rdep"], v_rd,
             xp.swapaxes(inv["rd_tab"][rd_pr], 0, 1), x["rd_jit"]))
    a_c = xp.concatenate([b[0] for b in blocks], axis=0)
    v_c = xp.concatenate([b[1] for b in blocks], axis=0)
    slots_c = xp.concatenate([b[2] for b in blocks], axis=1)
    jit_c = xp.concatenate([b[3] for b in blocks], axis=1)
    # direction per block: publish/reply-publish are ingress-bound (0),
    # delivery/reply-delivery egress-bound (1)
    side_c = xp.concatenate(
        [xp.full(Np, s, dtype=xp.int64)
         for s in ((0, 1, 0, 1) if fb else (0, 1))])
    free, t_c = _transit(ops, carry["free"], a_c, slots_c, jit_c, v_c,
                         side_c, meta, inv["chain_base"], inv["k_arr"])
    t_enq = t_c[:Np]
    t_land = t_c[Np:2 * Np]

    # ---- publish(g) epilogue: confirms feed the admission ring --------
    confirms = t_enq + ctrl
    conf = ops.at_set(carry["conf"], (pr, x["pub_conf_slot"]), confirms)

    # ---- delivery(g-1) epilogue: consumer processing + batched acks ---
    a = t_land + recv_req
    h = (xp.where(v_del, proc_s, 0.0)[:, None] * (1.0 + x["proc_jit"]))
    ch = xp.where(v_del, cons, nC)
    oc = ops.lexsort((idx, xp.where(v_del, a[:, 0], _INF), ch))
    ch_s = ch[oc]
    start_c, posc = _seg_pos(ops, ch_s, Np)
    carry_pf = carry["proc"][ch_s]
    a_eff = xp.where((ch_s < nC)[:, None],
                     xp.maximum(a[oc], carry_pf), a[oc])
    h_s = h[oc]
    c = xp.cumsum(h_s, axis=0)
    basefill = ops.cummax(xp.where(start_c[:, None], c - h_s, -_INF))
    Hs = c - basefill
    seen_s = Hs + ops.seg_cummax(a_eff - (Hs - h_s), start_c)
    proc = ops.at_max(carry["proc"], ch_s, seen_s)
    seen = ops.at_set(xp.zeros_like(a), oc, seen_s)
    seen = xp.where(v_del[:, None], seen, _INF)
    # acks: batch every B in processing order, force-flush at
    # generation end; invalid members route to the dummy ring row nC
    # (valid slots within a generation are distinct: load < P)
    boundary = ((((posc + 1) % B) == 0)
                | xp.concatenate([start_c[1:], xp.ones(1, dtype=bool)]))
    nb = _next_boundary(ops, boundary | (ch_s >= nC), start_c, Np)
    ack = ops.at_set(carry["ack"], (ch_s, idxc[oc] % P),
                     seen_s[nb] + ctrl)

    ys = dict(pub_start=pub_start, confirms=confirms, depart=depart,
              seen=seen)
    carry = dict(
        carry, free=free, conf=conf, proc=proc, ack=ack,
        pend_pub=dict(
            t_enq=ops.at_set(carry["pend_pub"]["t_enq"], dlyp, t_enq),
            pub_start=ops.at_set(carry["pend_pub"]["pub_start"], dlyp,
                                 pub_start)))
    if not fb:
        ys["rtt"] = xp.full_like(seen, _INF)
        return carry, ys

    # ---- reply-publish(g-2) epilogue: per-producer reply pump ---------
    t_renq = t_c[2 * Np:3 * Np]
    pch = xp.where(v_rp, rp_pr, nP)
    opr = ops.lexsort((idx, xp.where(v_rp, t_renq[:, 0], _INF), pch))
    pr_s = pch[opr]
    _, posp = _seg_pos(ops, pr_s, Np)
    idx_on_p = x["rp_cnt_p"][pr_s] + posp
    rdep_s = pump(carry["prep"], t_renq[opr], pr_s, idx_on_p,
                  v_rp[opr], meta)
    rdep = ops.at_set(xp.zeros_like(t_renq), opr, rdep_s)
    rdep = xp.where(v_rp[:, None], rdep, _INF)
    idxp = ops.at_set(xp.zeros(Np, dtype=idx_on_p.dtype), opr, idx_on_p)

    # ---- reply-delivery(g-3) epilogue: RTTs + producer ack batching ---
    t_seen = t_c[3 * Np:] + recv_rep
    rtt = xp.where(v_rd[:, None], t_seen - pend_c["pub_start"], _INF)
    pch_d = xp.where(v_rd, rd_pr, nP)
    opd = ops.lexsort((idx, xp.where(v_rd, t_seen[:, 0], _INF), pch_d))
    pd_s = pch_d[opd]
    start_p, posd = _seg_pos(ops, pd_s, Np)
    boundary = ((((posd + 1) % B) == 0)
                | xp.concatenate([start_p[1:], xp.ones(1, dtype=bool)]))
    nb = _next_boundary(ops, boundary | (pd_s >= nP), start_p, Np)
    prep = ops.at_set(carry["prep"],
                      (pd_s, pend_c["idx_on_p"][opd] % P),
                      t_seen[opd][nb] + ctrl)

    ys["rtt"] = rtt
    new_b = dict(seen=seen, cons=cons, pub_start=pub_start_prev)
    carry = dict(
        carry, prep=prep,
        pend_del={k: ops.at_set(carry["pend_del"][k], dly, new_b[k])
                  for k in new_b},
        pend_rep=dict(rdep=rdep, idx_on_p=idxp,
                      pub_start=pend_b["pub_start"]))
    return carry, ys


def _init_carry(xp: Any, meta: dict) -> dict:
    # trailing dummy rows/slots absorb the masked writes of invalid
    # pad members: conf slot W, ack row nC, proc row nC, prep row nP
    L, Np = meta["L"], meta["Np"]
    return dict(
        free=xp.zeros((2 * meta["NCH"] + 1, L)),
        conf=xp.zeros((meta["nP"], meta["W"] + 1, L)),
        ack=xp.zeros((meta["nC"] + 1, meta["P"], L)),
        proc=xp.zeros((meta["nC"] + 1, L)),
        prep=xp.zeros((meta["nP"] + 1, meta["P"], L)),
        # delay-line rings: publish->delivery trails by 1+dlag steps,
        # delivery->reply-publish by ``ring`` steps; slot = step % len
        pend_pub=dict(t_enq=xp.zeros((1 + meta["dlag"], Np, L)),
                      pub_start=xp.zeros((1 + meta["dlag"], Np, L))),
        pend_del=dict(seen=xp.zeros((meta["ring"], Np, L)),
                      cons=xp.zeros((meta["ring"], Np),
                                    dtype=xp.int64),
                      pub_start=xp.zeros((meta["ring"], Np, L))),
        pend_rep=dict(rdep=xp.zeros((Np, L)),
                      idx_on_p=xp.zeros(Np, dtype=xp.int64),
                      pub_start=xp.zeros((Np, L))))


def run_wave_trace(ws: WaveStatic, jitter: dict,
                   backend: str = "jax") -> dict:
    """Run the wave program, returning the full per-step trace
    ``{pub_start, confirms, depart, seen, rtt}`` with leading axis
    ``nSteps`` — the step-for-step comparison surface for the property
    tests.  ``backend="numpy"`` runs the same step as a Python loop."""
    meta = dict(ws.meta)
    if backend == "numpy":
        ops: Any = _NumpyOps
        inv = ws.inv
        xs = dict(ws.xs, **jitter)

        def pump(ring, t, gid, idxo, v, m):
            return _pump_assign_xla(ops, ring, t, gid, None, idxo, v, m)
        _, ys = ops.scan(
            lambda c, x: _wave_step(ops, meta, inv, c, x, pump),
            _init_carry(np, meta), xs, meta["nSteps"])
        return ys
    return _run_jax(ws, jitter)


@functools.lru_cache(maxsize=64)
def _compiled_program(sig: tuple, meta_items: tuple, n_cells: bool
                      ) -> Callable:
    """Jit (once per static signature / shape bucket) the whole-run
    program; ``n_cells`` selects the vmap-over-cells variant."""
    import jax
    from jax.experimental import enable_x64

    ops = _jax_ops()
    meta = dict(meta_items)
    meta["pools"] = tuple(meta["pools"])
    mode = pallas_enabled()

    def pump(ring, t, gid, idxo, v, m):
        if mode:
            return _pump_assign_pallas(ring, t, gid, idxo, v, m["P"],
                                       interpret=(mode == "interpret"))
        return _pump_assign_xla(ops, ring, t, gid, None, idxo, v, m)

    def program(xs: dict, inv: dict, jitter: dict) -> dict:
        xs = dict(xs, **jitter)
        _, ys = ops.scan(
            lambda c, x: _wave_step(ops, meta, inv, c, x, pump),
            _init_carry(ops.xp, meta), xs, meta["nSteps"])
        return ys

    fn = jax.vmap(program) if n_cells else program
    jfn = jax.jit(fn)

    def call(*args: Any) -> Any:
        with enable_x64():
            return jfn(*args)
    return call


def _run_jax(ws: WaveStatic, jitter: dict) -> dict:
    fn = _compiled_program(ws.signature(), _meta_key(ws.meta), False)
    out = fn(ws.xs, ws.inv, jitter)
    return {k: np.asarray(v) for k, v in out.items()}


def _meta_key(meta: dict) -> tuple:
    return tuple(sorted((k, v if not isinstance(v, tuple) else v)
                        for k, v in meta.items()))


# ---------------------------------------------------------------------------
# Result assembly + engine/campaign entry points
# ---------------------------------------------------------------------------


def _assemble(sim: VectorizedStreamSim, ws: WaveStatic,
              ys: dict) -> list:
    """Per-lane RunResults from the generation trace, through the
    engine's own ``_result`` contract (attribution, counters, sort)."""
    s = ws.sizes
    nP, M, L, nGen = s["nP"], s["M"], s["L"], s["nGen"]
    mem = s["mem_id"].ravel()
    valid = s["valid"].ravel()
    lanes = () if L == 1 else (L,)
    consume_t = np.full((nP * M,) + lanes, np.nan)
    rtts = (np.full((nP * M,) + lanes, np.nan)
            if ws.meta["feedback"] else None)
    pub = np.zeros((nP * M,) + lanes)
    # de-stagger the pipelined trace: step g carries publish(g),
    # delivery(g-1), reply-publish(g-2), reply-delivery(g-3)
    a0 = 1 + ws.meta["dlag"]
    seen = ys["seen"][a0:nGen + a0].reshape(-1, L)[valid]
    consume_t[mem[valid]] = (seen if lanes else seen[:, 0])
    ps = ys["pub_start"][:nGen].reshape(-1, L)[valid]
    pub[mem[valid]] = (ps if lanes else ps[:, 0])
    if rtts is not None:
        d = 2 + ws.meta["delay"]
        rv = ys["rtt"][d:nGen + d].reshape(-1, L)[valid]
        rtts[mem[valid]] = (rv if lanes else rv[:, 0])
    sim.n_events = int(valid.sum()) * max(
        1, ws.meta["S_pub"] + ws.meta["S_del"]
        + ws.meta["S_rp"] + ws.meta["S_rd"])
    results = []
    for lane, seed in enumerate(sim.stack_seeds):
        lane_spec = dataclasses.replace(
            sim.spec, params=dataclasses.replace(sim.p, seed=seed))
        sel = (slice(None),) if L == 1 else (slice(None), lane)
        results.append(sim._result(
            lane_spec, consume_t[sel],
            rtts[sel] if rtts is not None else None,
            pub[sel], lane=lane))
    return results


def run_wave_results(sim: VectorizedStreamSim) -> list:
    """Whole-run device execution for one (possibly lane-stacked)
    engine instance; one RunResult per stacked seed-lane."""
    ws = build_static(sim)
    ys = _run_jax(ws, draw_jitter(sim, ws))
    return _assemble(sim, ws, ys)


def run_wave_cells(sims: list) -> list:
    """vmap-over-cells driver: batch structurally identical cells
    (same :meth:`WaveStatic.signature`) into one device program, pow2
    pad-and-mask on the cell axis (pads replicate cell 0 and are
    dropped — inertness is property-tested).  Returns, per sim, the
    per-lane RunResult list."""
    import jax.numpy as jnp  # noqa: F401  (jax required here)
    built = [(sim, build_static(sim)) for sim in sims]
    out: list = [None] * len(sims)
    groups: dict = {}
    for i, (sim, ws) in enumerate(built):
        groups.setdefault(ws.signature(), []).append(i)
    for sig, idxs in groups.items():
        if len(idxs) == 1:
            i = idxs[0]
            sim, ws = built[i]
            out[i] = _assemble(sim, ws, _run_jax(ws, draw_jitter(sim, ws)))
            continue
        C = len(idxs)
        Cp = 1 << max(0, C - 1).bit_length()
        pad = [idxs[0]] * (Cp - C)
        cells = idxs + pad
        ws0 = built[idxs[0]][1]
        xs = {k: np.stack([built[i][1].xs[k] for i in cells])
              for k in ws0.xs}
        inv = {k: np.stack([built[i][1].inv[k] for i in cells])
               for k in ws0.inv}
        draws = [draw_jitter(built[i][0], built[i][1]) for i in cells]
        jit = {k: np.stack([d[k] for d in draws]) for k in draws[0]}
        fn = _compiled_program(sig, _meta_key(ws0.meta), True)
        ys = fn(xs, inv, jit)
        ys = {k: np.asarray(v) for k, v in ys.items()}
        for c, i in enumerate(idxs):
            sim, ws = built[i]
            out[i] = _assemble(sim, ws, {k: v[c] for k, v in ys.items()})
    return out
