"""Discrete-event streaming simulator (paper §5.2 — "StreamSim").

The paper's evaluation drives a Golang simulator whose producers, consumers
and coordinator exchange real messages through the deployed architectures.
Here the same experiment logic runs against the *modeled* architectures of
:mod:`repro.core.architectures` under a deterministic virtual clock, so the
whole 1..64-consumer sweep of Figs 4-8 runs in seconds and is bit-stable
across runs (seeded jitter only).

Engine design: every message steps hop-by-hop through its architecture's
path elements; each shared resource (client NIC, DSN NIC, broker CPU pool,
overlay tunnel, ingress pipe/worker) is a FIFO server or server-pool whose
busy intervals are tracked analytically — one heap event per hop, so a full
128K-message run is a few million events.

Flow control matches the paper's RabbitMQ configuration (§5.2):
publisher-confirm windows, consumer prefetch (basic.qos), batch
acknowledgements, reject-publish overflow with producer re-publish.

Three engines implement the same experiment contract (the
:class:`Engine` protocol): this module's heap engine (one event per hop
— the reference), the batched array engine in
:mod:`repro.core.vectorized` that computes whole message cohorts with
prefix-scan FIFO math, and the JAX port of its hot kernels in
:mod:`repro.core.jax_engine` (``jax.jit`` device programs, vmapped over
stacked seed-lanes).  The vectorized engine is the default; select via
``SimParams(engine="vectorized"|"heap"|"jax")`` (alias
:data:`SimConfig`).  All model the full flow-control stack, including
credit-flow confirm withholding and reject-publish overflow with
producer re-publish.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Optional, Protocol

import numpy as np

from repro.core.architectures import (
    Architecture, PathElement, ResourceSpec, make_architecture)
from repro.core.broker import BrokerCluster, Delivery, Message
from repro.core.ds2hpc import ClusterInventory
from repro.core.workloads import WORKLOADS, Workload

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

#: per-workload consumer processing time (seconds/message); kept as an
#: alias of the Table-1 values, which now live on the Workload itself.
CONSUMER_PROC_S = {name: w.proc_time_s() for name, w in WORKLOADS.items()}

#: registered engine names -> constructor, filled at the bottom of this
#: module (heap) and by repro.core.vectorized on import (vectorized).
ENGINES: dict[str, type["Engine"]] = {}


@dataclasses.dataclass
class SimParams:
    confirm_window: int = 128       # unconfirmed publishes per producer
    window_bytes: int = 48 * 1024 * 1024   # in-flight byte cap per producer
    prefetch: int = 64              # basic.qos per consumer
    ack_batch: int = 8              # ack-multiple every N deliveries
    n_work_queues: int = 2          # paper: two shared work queues
    reply_factor: float = 1.0       # reply size = factor * request size
    publish_retry_s: float = 10e-3  # backoff after reject-publish
    jitter: float = 0.03            # +/- service-time jitter (CDF spread)
    seed: int = 0
    max_events: int = 30_000_000
    max_sim_time: float = 36_000.0
    consumer_proc_s: Optional[float] = None   # override per-workload default
    #: per-data-queue byte cap (None = the broker's RAM-budget default).
    #: Small caps push the run into the reject-publish overflow regime.
    queue_max_bytes: Optional[int] = None
    engine: str = "vectorized"  # "vectorized" (default) | "heap" | "jax"
    #: vectorized engine: per-producer messages per cohort round; must be a
    #: sub-multiple of the confirm window.  Smaller rounds interleave
    #: cross-flow traffic more finely (closer to the heap engine's event
    #: order) at the cost of more python-level rounds.  None (default)
    #: auto-tunes: 8, shrunk when a shared DSN-NIC/tunnel pipe is
    #: estimated saturated and few flows are in play (see
    #: :mod:`repro.core.vectorized`).
    vec_round: Optional[int] = None
    #: vectorized engine: how far (seconds) past the next event's key a
    #: cohort may be served in one batch; 0 enforces strict global time
    #: ordering at every shared resource, larger values trade fidelity
    #: for fewer, bigger array operations.  None auto-scales with client
    #: count (aggregate metrics become insensitive to ordering slack as
    #: the number of concurrent flows grows) and shrinks alongside
    #: ``vec_round`` under detected saturation.
    vec_horizon_s: Optional[float] = None
    #: jax engine: *request* the whole-run device program (one
    #: ``lax.scan`` over message generations instead of the Python
    #: cohort loop; see :mod:`repro.core.jax_device_loop`).  True uses
    #: it when the cell is wave-formulated (work_sharing/feedback, no
    #: flow-control events reachable) and silently keeps the ordinary
    #: per-cohort jax path otherwise; None/False (default) never uses
    #: it.  Device-loop results match the cohort engines at the
    #: ``device_loop.*`` parity bands rather than bit-for-bit.
    jax_device_loop: Optional[bool] = None

    def __post_init__(self) -> None:
        # resolve the engine name early so a typo fails at construction,
        # not deep inside a sweep
        get_engine(self.engine)
        if self.confirm_window < 2:
            raise ValueError(
                f"confirm_window must be >= 2, got {self.confirm_window}")
        for name in ("prefetch", "ack_batch", "n_work_queues"):
            if getattr(self, name) < 1:
                raise ValueError(
                    f"{name} must be >= 1, got {getattr(self, name)}")
        if self.queue_max_bytes is not None and self.queue_max_bytes <= 0:
            raise ValueError(
                f"queue_max_bytes must be positive, got {self.queue_max_bytes}")
        if self.vec_round is not None:
            if self.vec_round < 1:
                raise ValueError(
                    f"vec_round must be >= 1 (got {self.vec_round}); use "
                    f"None for auto-tuning")
            if self.vec_round > self.confirm_window:
                raise ValueError(
                    f"vec_round={self.vec_round} exceeds the confirm window "
                    f"({self.confirm_window}): publish rounds could never "
                    f"be gated by confirms")
            if self.confirm_window % self.vec_round != 0:
                raise ValueError(
                    f"vec_round={self.vec_round} must be a sub-multiple of "
                    f"confirm_window={self.confirm_window} so every round "
                    f"is gated by whole earlier rounds")
        if self.vec_horizon_s is not None and self.vec_horizon_s < 0:
            raise ValueError(
                f"vec_horizon_s must be >= 0, got {self.vec_horizon_s}")


#: the user-facing name for selecting an engine: SimConfig(engine=...)
SimConfig = SimParams


@dataclasses.dataclass
class ExperimentSpec:
    pattern: str                    # work_sharing | feedback | broadcast_gather
    workload: Workload
    arch: str                       # architecture name for make_architecture
    n_producers: int
    n_consumers: int
    total_messages: int
    params: SimParams = dataclasses.field(default_factory=SimParams)
    #: multi-tenant mode (paper §6's MSS multi-user claim): partition the
    #: producers/consumers into this many independent workflows sharing
    #: one broker deployment.  Tenant of producer/consumer ``k`` is
    #: ``k // (count // tenants)`` (contiguous blocks).
    tenants: int = 1
    #: how tenant workflows share the broker: ``"shared"`` — all tenants
    #: publish into the same work queues (messages mix; any consumer may
    #: process any tenant's message); ``"vhost"`` — per-tenant queues in
    #: per-tenant vhosts (RabbitMQ-style namespacing; only the tenant's
    #: own consumers see its messages).  Work-sharing/feedback only.
    tenant_isolation: str = "shared"

    def __post_init__(self) -> None:
        if self.tenants < 1:
            raise ValueError(f"tenants must be >= 1, got {self.tenants}")
        if self.tenant_isolation not in ("shared", "vhost"):
            raise ValueError(
                f"tenant_isolation must be 'shared' or 'vhost', got "
                f"{self.tenant_isolation!r}")
        if self.tenants > 1:
            if self.pattern not in ("work_sharing", "feedback"):
                raise ValueError(
                    "multi-tenant mode supports the work_sharing/feedback "
                    f"patterns, not {self.pattern!r}")
            if (self.n_producers % self.tenants
                    or self.n_consumers % self.tenants):
                raise ValueError(
                    f"tenants={self.tenants} must evenly divide producers "
                    f"({self.n_producers}) and consumers "
                    f"({self.n_consumers})")


@dataclasses.dataclass
class RunResult:
    spec: ExperimentSpec
    feasible: bool
    infeasible_reason: str = ""
    consume_times: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    rtts: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros(0))
    publish_starts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    #: basic.reject events observed by producers (reject-publish
    #: overflow).  In a stacked multi-seed vectorized run this is the
    #: *lane's own* count — each lane runs its own admission sequence
    #: against its own credit backlog and depart cursor.
    rejected_publishes: int = 0
    #: confirms withheld by credit-flow; lane-resolved like
    #: ``rejected_publishes`` in stacked runs
    blocked_confirms: int = 0
    redelivered: int = 0
    sim_time: float = 0.0
    n_events: int = 0
    #: producer index of each ``consume_times`` / ``rtts`` entry (same
    #: order), for per-producer / per-tenant attribution.  Empty when an
    #: engine predates the attribution contract or the run is infeasible.
    consume_producers: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))
    rtt_producers: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))

    @property
    def n_consumed(self) -> int:
        return int(self.consume_times.size)

    def tenant_of_producer(self, producer_idx: np.ndarray) -> np.ndarray:
        """Map producer indices to tenant indices (contiguous blocks)."""
        per = max(1, self.spec.n_producers // max(1, self.spec.tenants))
        return np.asarray(producer_idx, dtype=np.int64) // per


class InfeasibleConfiguration(RuntimeError):
    pass


class Engine(Protocol):
    """What an engine must provide: construct from (spec, inventory, arch)
    — raising :class:`InfeasibleConfiguration` for configs the deployment
    cannot host — then produce a :class:`RunResult` from :meth:`run`."""

    def __init__(self, spec: ExperimentSpec,
                 inventory: Optional[ClusterInventory] = None,
                 arch: Optional[Architecture] = None) -> None: ...

    def run(self) -> RunResult: ...


def check_feasibility(arch: Architecture, spec: ExperimentSpec) -> None:
    """Deployment gates shared by every engine (e.g. Stunnel's hard
    16-connection cap, the paper's missing PRS data points)."""
    limit = arch.producer_conn_limit()
    if limit is not None and spec.n_producers > limit:
        raise InfeasibleConfiguration(
            f"{arch.name}: {spec.n_producers} producer "
            f"connections exceed tunnel connection limit {limit}")
    qcap = spec.params.queue_max_bytes
    if qcap is not None:
        need = spec.workload.payload_bytes
        if spec.pattern in ("feedback", "broadcast_gather"):
            need = max(need, max(1, int(need * spec.params.reply_factor)))
        if qcap < need:
            # a queue that cannot hold one message would reject every
            # publish forever (producers retry until max_sim_time)
            raise InfeasibleConfiguration(
                f"queue_max_bytes={qcap} cannot hold a single "
                f"{need}-byte message; every publish would be rejected")


# ---------------------------------------------------------------------------
# Virtual-time resources
# ---------------------------------------------------------------------------


class _Resource:
    __slots__ = ("spec", "_free_pipe", "_free_pool")

    def __init__(self, spec: ResourceSpec) -> None:
        self.spec = spec
        self._free_pipe = 0.0
        self._free_pool: list[float] = [0.0] * max(1, spec.servers)
        heapq.heapify(self._free_pool)

    def hold_time(self, nbytes: float) -> float:
        s = self.spec
        if s.kind == "pipe":
            return s.service_s + (nbytes / s.rate_Bps if s.rate_Bps else 0.0)
        return s.service_s + nbytes * s.per_byte_s

    def acquire(self, t: float, nbytes: float, jitter: float) -> float:
        hold = self.hold_time(nbytes) * (1.0 + jitter)
        if self.spec.kind == "pipe":
            start = t if t > self._free_pipe else self._free_pipe
            end = start + hold
            self._free_pipe = end
            return end
        free = heapq.heappop(self._free_pool)
        start = t if t > free else free
        end = start + hold
        heapq.heappush(self._free_pool, end)
        return end


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class StreamSim:
    """One experiment run. Deterministic given (spec, inventory, cal)."""

    def __init__(self, spec: ExperimentSpec,
                 inventory: Optional[ClusterInventory] = None,
                 arch: Optional[Architecture] = None) -> None:
        self.spec = spec
        self.p = spec.params
        self.inv = inventory or ClusterInventory()
        self.arch = arch or make_architecture(spec.arch, self.inv)
        self.arch.configure(spec.n_producers, spec.n_consumers,
                            tenants=spec.tenants)
        # tenant of producer/consumer k is k // per-tenant-count
        # (contiguous blocks); tenant-aware architectures route each
        # client through its own tenant's resources (e.g. DTS tunnels)
        self._ppt = max(1, spec.n_producers // spec.tenants)
        self._cpt = max(1, spec.n_consumers // spec.tenants)
        self.rng = np.random.default_rng(self.p.seed)
        self.now = 0.0
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._eseq = itertools.count()
        self.n_events = 0
        self.resources = {k: _Resource(s)
                          for k, s in self.arch.resources.items()}
        self.broker = BrokerCluster(n_nodes=self.inv.n_dsn,
                                    default_prefetch=self.p.prefetch)
        # metrics
        self.consume_times: list[float] = []
        self.rtts: list[float] = []
        self.publish_starts: list[float] = []
        self.consume_producers: list[int] = []
        self.rtt_producers: list[int] = []
        self._reply_q: dict[int, str] = {}
        self.rejected = 0
        self.blocked = 0
        # flow state
        self._blocked_confirms: dict[str, list[Callable[[], None]]] = {}
        self._done = False
        self._replies_expected = 0
        self._replies_received = 0
        self._consumed = 0
        self._expected_consumed = 0
        self._proc_s = (self.p.consumer_proc_s
                        if self.p.consumer_proc_s is not None
                        else spec.workload.proc_time_s())
        self._check_feasibility()
        self._setup_pattern()

    # -- scheduling -------------------------------------------------------------
    def _at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (t, next(self._eseq), fn))

    def _after(self, dt: float, fn: Callable[[], None]) -> None:
        self._at(self.now + dt, fn)

    def _jit(self) -> float:
        j = self.p.jitter
        return float(self.rng.uniform(-j, j)) if j else 0.0

    # -- transit: step a message through path elements ----------------------------
    def _transit(self, t0: float, elements: list[PathElement], size: int,
                 done: Callable[[float], None]) -> None:
        def step(i: int, t: float) -> None:
            while i < len(elements) and elements[i].resource is None:
                t += elements[i].latency_s
                i += 1
            if i >= len(elements):
                done(t)
                return
            el = elements[i]
            res = self.resources[el.resource]
            nbytes = size * el.byte_factor + el.extra_bytes
            t_end = res.acquire(t, nbytes, self._jit()) + el.latency_s
            self._at(t_end, lambda: step(i + 1, t_end))
        self._at(t0, lambda: step(0, t0))

    # -- feasibility (e.g. Stunnel's 16-connection cap) ----------------------------
    def _check_feasibility(self) -> None:
        check_feasibility(self.arch, self.spec)

    # -- topology per pattern --------------------------------------------------------
    def _setup_pattern(self) -> None:
        spec, p = self.spec, self.p
        nP, nC = spec.n_producers, spec.n_consumers
        per_producer = spec.total_messages // nP
        self._expected_consumed = per_producer * nP
        pat = spec.pattern
        qcap = p.queue_max_bytes          # None = broker RAM-budget default
        if pat in ("work_sharing", "feedback"):
            T = spec.tenants
            vhosted = T > 1 and spec.tenant_isolation == "vhost"
            if vhosted:
                # per-tenant vhost queues: tenant t's producers publish
                # only into t's queues, consumed only by t's consumers
                ppt, cpt = nP // T, nC // T
                nq_t = min(p.n_work_queues, cpt)
                self._work_queues = []
                for t in range(T):
                    for i in range(nq_t):
                        q = self.broker.declare_queue(
                            f"work:{i}", vhost=f"t{t}", max_bytes=qcap)
                        self._work_queues.append(q.name)
                for c in range(nC):
                    t, cl = c // cpt, c % cpt
                    qn = self._work_queues[t * nq_t + cl % nq_t]
                    self.broker.register_consumer(
                        f"c{c}", qn, prefetch=p.prefetch,
                        connected_node=(c + 1) % self.inv.n_dsn)
            else:
                nq = min(p.n_work_queues, nC)
                self._work_queues = [f"work:{i}" for i in range(nq)]
                for q in self._work_queues:
                    self.broker.declare_queue(q, max_bytes=qcap)
                for c in range(nC):
                    q = self._work_queues[c % nq]
                    self.broker.register_consumer(
                        f"c{c}", q, prefetch=p.prefetch,
                        connected_node=(c + 1) % self.inv.n_dsn)
            if pat == "feedback":
                self._replies_expected = self._expected_consumed
                for pr in range(nP):
                    vh = f"t{pr // (nP // T)}" if vhosted else None
                    rq = self.broker.declare_queue(
                        f"reply:{pr}", vhost=vh, control=False,
                        max_bytes=qcap)
                    self._reply_q[pr] = rq.name
                    self.broker.register_consumer(
                        f"p{pr}", rq.name, prefetch=p.prefetch,
                        connected_node=pr % self.inv.n_dsn)
            for pr in range(nP):
                if vhosted:
                    t = pr // ppt
                    qs = self._work_queues[t * nq_t:(t + 1) * nq_t]
                else:
                    qs = self._work_queues
                self._start_producer(pr, per_producer,
                                     queue_of=self._ws_queue_of(pr, qs))
        elif pat in ("broadcast", "broadcast_gather"):
            assert nP == 1, "broadcast patterns use a single producer"
            self._expected_consumed = per_producer * nC
            qs = []
            for c in range(nC):
                qn = f"bq:{c}"
                self.broker.declare_queue(qn, max_bytes=qcap)
                self.broker.register_consumer(
                    f"c{c}", qn, prefetch=p.prefetch,
                    connected_node=(c + 1) % self.inv.n_dsn)
                qs.append(qn)
            self.broker.declare_fanout("bcast", qs)
            if pat == "broadcast_gather":
                self._replies_expected = per_producer * nC
                self.broker.declare_queue("gather", max_bytes=qcap)
                self.broker.register_consumer("p0", "gather",
                                              prefetch=p.prefetch,
                                              connected_node=0)
            self._start_producer(0, per_producer,
                                 queue_of=lambda i: "fanout:bcast")
        else:
            raise ValueError(f"unknown pattern {pat!r}")

    def _ws_queue_of(self, pr: int, qs: list) -> Callable[[int], str]:
        return lambda i: qs[(pr + i) % len(qs)]

    # -- producers ---------------------------------------------------------------
    def _start_producer(self, pr: int, n_msgs: int,
                        queue_of: Callable[[int], str]) -> None:
        spec, p = self.spec, self.p
        pnode = self.inv.producer_node_of(pr)
        bnode = pr % self.inv.n_dsn
        tnt = pr // self._ppt
        state = {"sent": 0, "inflight": 0}
        size = spec.workload.payload_bytes
        flush = self.arch.client_flush_s()
        # effective publisher window: message-count cap AND byte cap
        window = max(2, min(p.confirm_window, p.window_bytes // size))

        def maybe_send() -> None:
            while (state["sent"] < n_msgs
                   and state["inflight"] < window):
                i = state["sent"]
                state["sent"] += 1
                state["inflight"] += 1
                rk = queue_of(i)
                msg = Message(routing_key=rk, size=size,
                              producer_id=f"p{pr}",
                              reply_to=(self._reply_q.get(pr, f"reply:{pr}")
                                        if spec.pattern == "feedback" else
                                        ("gather" if spec.pattern ==
                                         "broadcast_gather" else None)))
                t_start = self.now + flush
                msg.publish_time = t_start
                self.publish_starts.append(t_start)
                home = self._home_of(rk)
                path = self.arch.publish_path(pnode, bnode, home,
                                              tenant=tnt)
                self._transit(t_start, path, size,
                              lambda t, m=msg: arrive(t, m))

        def arrive(t: float, msg: Message) -> None:
            ok, queued = self.broker.publish(msg)
            if not ok:
                self.rejected += 1
                self._at(t + p.publish_retry_s,
                         lambda: retry(msg))
                return
            for qn in queued:
                self._pump(qn, t)
            # credit-based flow control (RabbitMQ): if any target queue's
            # backlog exceeds its credit, the channel is blocked — withhold
            # the publisher confirm until the queue drains.
            blocked_on = next(
                (qn for qn in queued if self.broker.queues[qn].flow_blocked),
                None)
            if blocked_on is not None:
                self.blocked += 1
                self._blocked_confirms.setdefault(blocked_on, []).append(confirm)
            else:
                self._at(t + self.arch.control_latency_s(), confirm)

        def retry(msg: Message) -> None:
            home = self._home_of(msg.routing_key)
            path = self.arch.publish_path(pnode, bnode, home, tenant=tnt)
            self._transit(self.now, path, size,
                          lambda t, m=msg: arrive(t, m))

        def confirm() -> None:
            state["inflight"] -= 1
            maybe_send()

        self._at(0.0, maybe_send)

    def _home_of(self, routing_key: str) -> int:
        if routing_key.startswith("fanout:"):
            return 0
        return self.broker.queues[routing_key].home_node

    # -- delivery pump --------------------------------------------------------------
    def _pump(self, queue_name: str, t: float) -> None:
        while True:
            d = self.broker.next_delivery(queue_name)
            if d is None:
                break
            self._dispatch_delivery(d, t)
        # release flow-blocked publishers once the queue has drained
        blocked = self._blocked_confirms.get(queue_name)
        if blocked and self.broker.queues[queue_name].flow_resume:
            self._blocked_confirms[queue_name] = []
            dt = self.arch.control_latency_s()
            for confirm in blocked:
                self._after(dt, confirm)

    def _dispatch_delivery(self, d: Delivery, t: float) -> None:
        cid = d.consumer_id
        if cid.startswith("p"):          # producer consuming replies
            self._deliver_to_producer(d, t)
        else:
            self._deliver_to_consumer(d, t)

    # -- consumers --------------------------------------------------------------------
    def _consumer_state(self, cid: str) -> dict:
        if not hasattr(self, "_cstates"):
            self._cstates: dict[str, dict] = {}
        st = self._cstates.get(cid)
        if st is None:
            st = {"free_at": 0.0, "since_ack": 0, "last_tag": 0}
            self._cstates[cid] = st
        return st

    def _deliver_to_consumer(self, d: Delivery, t: float) -> None:
        cidx = int(d.consumer_id[1:])
        cnode = self.inv.consumer_node_of(cidx)
        home = self.broker.queues[d.queue].home_node
        bnode = (cidx + 1) % self.inv.n_dsn   # node this consumer connects to
        path = self.arch.delivery_path(bnode, home, cnode,
                                       tenant=cidx // self._cpt)
        size = d.message.size

        def landed(t_arr: float) -> None:
            st = self._consumer_state(d.consumer_id)
            start = max(t_arr + self.arch.recv_latency_s(size), st["free_at"])
            t_done = start + self._proc_s * (1.0 + self._jit())
            st["free_at"] = t_done
            self._at(t_done, lambda: consumed(t_done))

        def consumed(t_done: float) -> None:
            self.consume_times.append(t_done)
            pid = d.message.producer_id
            self.consume_producers.append(
                int(pid[1:]) if pid and pid[1:].isdigit() else 0)
            self._consumed += 1
            self._ack(d, t_done)
            if d.message.reply_to is not None:
                self._send_reply(d, cidx, cnode, t_done)
            self._check_done()

        self._transit(t, path, size, landed)

    def _ack(self, d: Delivery, t: float) -> None:
        """Batch acks: flush every ack_batch deliveries (ack-multiple)."""
        st = self._consumer_state(d.consumer_id)
        st["since_ack"] += 1
        st["last_tag"] = max(st["last_tag"], d.delivery_tag)
        pending_all = len(self.broker.channels[d.consumer_id].unacked)
        if st["since_ack"] >= self.spec.params.ack_batch or \
                pending_all >= self.spec.params.prefetch or \
                self._consumed >= self._expected_consumed:
            tag = st["last_tag"]
            st["since_ack"] = 0
            cid = d.consumer_id
            qn = d.queue

            def ack_arrives() -> None:
                self.broker.ack(cid, tag, multiple=True)
                self._pump(qn, self.now)
            self._at(t + self.arch.control_latency_s(), ack_arrives)

    def _send_reply(self, d: Delivery, cidx: int, cnode: int,
                    t: float) -> None:
        spec = self.spec
        size = int(spec.workload.payload_bytes * spec.params.reply_factor)
        reply = Message(routing_key=d.message.reply_to, size=size,
                        producer_id=f"c{cidx}",
                        correlation_id=d.message.msg_id,
                        headers={"req_publish": d.message.publish_time})
        bnode = (cidx + 1) % self.inv.n_dsn
        home = self._home_of(reply.routing_key)
        path = self.arch.reply_publish_path(cnode, bnode, home,
                                            tenant=cidx // self._cpt)

        def arrive(t_arr: float) -> None:
            ok, queued = self.broker.publish(reply)
            if not ok:
                self.rejected += 1
                self._at(t_arr + spec.params.publish_retry_s,
                         lambda: self._transit(
                             self.now, path, size, arrive))
                return
            for qn in queued:
                self._pump(qn, t_arr)

        self._transit(t, path, size, arrive)

    # -- producers consuming replies ----------------------------------------------------
    def _deliver_to_producer(self, d: Delivery, t: float) -> None:
        pidx = int(d.consumer_id[1:])
        pnode = self.inv.producer_node_of(pidx)
        home = self.broker.queues[d.queue].home_node
        bnode = pidx % self.inv.n_dsn
        path = self.arch.reply_delivery_path(home, bnode, pnode,
                                             tenant=pidx // self._ppt)
        size = d.message.size

        def landed(t_arr: float) -> None:
            t_seen = t_arr + self.arch.recv_latency_s(size)
            req_t = d.message.headers.get("req_publish")
            if req_t is not None:
                self.rtts.append(t_seen - req_t)
                self.rtt_producers.append(pidx)
            self._replies_received += 1
            self._ack(d, t_seen)
            self._check_done()

        self._transit(t, path, size, landed)

    # -- termination ----------------------------------------------------------------------
    def _check_done(self) -> None:
        if self._consumed >= self._expected_consumed and \
                self._replies_received >= self._replies_expected:
            self._done = True

    # -- main loop -------------------------------------------------------------------------
    def run(self) -> RunResult:
        p = self.p
        while self._heap and not self._done:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            self.n_events += 1
            if self.n_events > p.max_events or t > p.max_sim_time:
                break
            fn()
        redeliv = sum(q.stats.redelivered for q in self.broker.queues.values())
        return RunResult(
            spec=self.spec, feasible=True,
            consume_times=np.asarray(self.consume_times),
            rtts=np.asarray(self.rtts),
            publish_starts=np.asarray(self.publish_starts),
            rejected_publishes=self.rejected,
            blocked_confirms=self.blocked,
            redelivered=redeliv,
            sim_time=self.now, n_events=self.n_events,
            consume_producers=np.asarray(self.consume_producers,
                                         dtype=np.int64),
            rtt_producers=np.asarray(self.rtt_producers, dtype=np.int64))


ENGINES["heap"] = StreamSim


def get_engine(name: str) -> type["Engine"]:
    """Resolve an engine name to its class, importing lazily."""
    if name not in ENGINES and name == "vectorized":
        import repro.core.vectorized  # noqa: F401  (registers itself)
    if name not in ENGINES and name == "jax":
        # the module imports (and registers) without jax installed;
        # only constructing the engine needs jax
        import repro.core.jax_engine  # noqa: F401  (registers itself)
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; options: {sorted(ENGINES)}") from None


def run_experiment(spec: ExperimentSpec,
                   inventory: Optional[ClusterInventory] = None,
                   arch: Optional[Architecture] = None) -> RunResult:
    """Run one experiment on the engine named by ``spec.params.engine``;
    infeasible configs return a RunResult with feasible=False (matching the
    paper's missing Stunnel data points)."""
    engine_cls = get_engine(spec.params.engine)
    try:
        sim = engine_cls(spec, inventory, arch)
    except InfeasibleConfiguration as e:
        return RunResult(spec=spec, feasible=False, infeasible_reason=str(e))
    return sim.run()
