"""Experiment drivers for the paper's three messaging patterns (§5.1).

* **work sharing** — embarrassingly parallel fan-out (hyperparameter
  searches, Monte-Carlo ensembles): producers push to shared work queues,
  messages round-robin across consumers. Metric: aggregate throughput.
* **work sharing with feedback** — distribute-with-reply (TF-PS/MXNet-style
  data-parallel DL, master-worker task farms): requests via the work-queue
  model, replies via per-producer direct reply queues. Metric: RTT.
* **broadcast & gather** — DDP motif (NCCL/Gloo: weight fan-out +
  gradient reduce): one producer fans out via pub-sub to every consumer and
  gathers all replies from a single gather queue. Metrics: broadcast
  throughput + gather RTT.

Each driver returns (RunResult, Summary) pairs across a consumer sweep, and
is consumed both by benchmarks/ (paper figures) and tests/.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.architectures import Calibration
from repro.core.ds2hpc import ClusterInventory
from repro.core.metrics import (
    Summary, jain_fairness, summarize, tenant_median_rtts,
    tenant_throughputs)
from repro.core.simulator import (
    ExperimentSpec, RunResult, SimParams, run_experiment)
from repro.core.workloads import Workload, get_workload

#: the paper's consumer sweep (Figs 4-8)
CONSUMER_SWEEP = (1, 2, 4, 8, 16, 32, 64)

#: broadcast&gather replies are aggregation/metric payloads, much smaller
#: than the 4 MiB broadcast body (paper §5.1: "all workers send back metrics
#: to be reduced at the initiator"): 4 MiB / 256 = 16 KiB replies. The sharp
#: RTT increase beyond 4 consumers (Fig 7b) then emerges from broker-egress
#: saturation on the broadcast leg plus the single producer gathering and
#: broadcasting concurrently.
GATHER_REPLY_FACTOR = 1.0 / 256.0


def _params(seed: int, **overrides) -> SimParams:
    # construct in one shot so SimParams.__post_init__ validates the
    # overrides (engine name, vec_round sub-multiple, positive knobs)
    return SimParams(seed=seed, **overrides)


#: Overflow-regime stress scenario: a regime the paper's configurations
#: never trigger, exercisable at scale on the vectorized engine.  A small
#: confirm window, slow consumers and a tight per-queue byte cap push the
#: work queues through repeated credit-flow blocking episodes
#: (publisher confirms withheld above ``FLOW_CREDIT x producers`` backlog)
#: into reject-publish overflow (producers observe rejects and re-publish
#: after the backoff).  ``queue_cap_msgs`` sits just above the credit
#: threshold so *both* mechanisms fire: the queue blocks at the threshold,
#: and the in-flight window landing on top of it overflows the cap.
#: the stress scenario's SimParams overrides (exported so benchmark cache
#: fingerprints can cover exactly what the runs used)
OVERFLOW_STRESS_DEFAULTS = dict(confirm_window=64, prefetch=16,
                                ack_batch=4, consumer_proc_s=2e-3)


def overflow_stress(arch: str, n_consumers: int, *,
                    workload: str | Workload = "dstream",
                    total_messages: Optional[int] = None,
                    queue_cap_msgs: Optional[int] = None,
                    n_runs: int = 1, seed: int = 0,
                    engine: Optional[str] = None,
                    **param_overrides) -> list[RunResult]:
    """Run the overflow-regime stress cell (feedback pattern, equal
    producers/consumers, up to 1024 consumers on the vectorized engine).

    ``queue_cap_msgs`` defaults to ~6% above the credit threshold
    (``FLOW_CREDIT x producers``) so both mechanisms fire; pass a small
    explicit cap for large consumer counts to get a pure reject-publish
    regime at affordable message volumes (the credit threshold itself
    scales with producers).  Returns the per-seed :class:`RunResult`
    list; results report nonzero ``rejected_publishes`` (and, in the
    default both-mechanisms regime, ``blocked_confirms``)."""
    from repro.core.broker import ClassicQueue
    wl = get_workload(workload) if isinstance(workload, str) else workload
    if queue_cap_msgs is None:
        queue_cap_msgs = int(ClassicQueue.FLOW_CREDIT * n_consumers * 1.06)
    if total_messages is None:
        # enough volume for repeated blocking/overflow episodes per queue
        total_messages = max(8192, 4 * queue_cap_msgs)
    for k, v in OVERFLOW_STRESS_DEFAULTS.items():
        param_overrides.setdefault(k, v)
    param_overrides.setdefault("queue_max_bytes",
                               queue_cap_msgs * wl.payload_bytes)
    return run_pattern("feedback", arch, wl, n_consumers,
                       total_messages=total_messages, n_runs=n_runs,
                       seed=seed, engine=engine, **param_overrides)


#: the multi-tenant sweep (paper §6's MSS multi-user scalability claim,
#: made quantitative): number of independent workflows on one broker
TENANT_SWEEP = (1, 2, 4, 8, 16, 32, 64)


@dataclasses.dataclass
class TenantPoint:
    """One point of the multi-tenant contention curve: ``tenants``
    independent workflows sharing one managed-broker deployment."""

    tenants: int
    isolation: str                   # "shared" | "vhost"
    arch: str
    workload: str
    feasible: bool
    #: mean per-tenant consumed-message rate (msgs/s per tenant)
    tenant_throughput_msgs_s: float = float("nan")
    #: mean of the per-tenant median request->reply RTTs (s)
    tenant_median_rtt_s: float = float("nan")
    #: Jain fairness index over the per-tenant throughputs (1.0 = even)
    fairness: float = float("nan")
    #: worst-off tenant's share of the best-off tenant's rate
    min_max_ratio: float = float("nan")
    #: per-tenant throughput relative to the sweep's first point
    #: (1.0 = no degradation as tenants are added)
    degradation: float = float("nan")
    rejected: float = 0.0
    blocked: float = 0.0
    n_runs: int = 0


def multi_tenant(arch: str = "mss",
                 tenant_counts: Sequence[int] = TENANT_SWEEP, *,
                 isolation: str = "vhost",
                 producers_per_tenant: int = 1,
                 consumers_per_tenant: int = 1,
                 workload: str | Workload = "dstream",
                 messages_per_tenant: int = 256,
                 n_runs: int = 3, seed: int = 0,
                 engine: Optional[str] = None,
                 inventory: Optional[ClusterInventory] = None,
                 **param_overrides) -> list[TenantPoint]:
    """Multi-tenant contention sweep: N independent feedback workflows
    (1 producer + 1 consumer each by default) share one broker
    deployment, as tenant count grows ``1 -> 64``.

    This is the quantitative version of the paper's §6 claim that MSS
    "provides greater deployment feasibility and scalability across
    multiple users": every tenant still funnels through the same
    LB + ingress + broker fabric, so per-tenant throughput degrades and
    RTT inflates as tenants are added — the sweep measures how much,
    and how *fairly* the shared fabric splits capacity (Jain index +
    worst/best tenant ratio).  ``isolation`` picks the broker layout:
    ``"vhost"`` gives each tenant its own queues in its own vhost
    (RabbitMQ namespacing — the S3M provisioning model's per-project
    isolation), ``"shared"`` drops every tenant into the same work
    queues (messages mix across tenants).

    Offered load scales with the tenant count (``messages_per_tenant``
    each), so a flat curve means perfect scaling.  Returns one
    :class:`TenantPoint` per entry of ``tenant_counts``, with
    ``degradation`` relative to the first point."""
    wl = get_workload(workload) if isinstance(workload, str) else workload
    if engine is not None:
        param_overrides.setdefault("engine", engine)
    points: list[TenantPoint] = []
    base: Optional[float] = None
    for T in tenant_counts:
        nP, nC = T * producers_per_tenant, T * consumers_per_tenant
        specs = [ExperimentSpec(
                    pattern="feedback", workload=wl, arch=arch,
                    n_producers=nP, n_consumers=nC,
                    total_messages=T * messages_per_tenant,
                    params=_params(seed + 1000 * r, **param_overrides),
                    tenants=T, tenant_isolation=isolation)
                 for r in range(n_runs)]
        if specs[0].params.engine == "vectorized":
            from repro.core.vectorized import run_many
            results = run_many(specs, inventory)
        else:
            results = [run_experiment(s, inventory) for s in specs]
        feas = [r for r in results if r.feasible]
        if not feas:
            points.append(TenantPoint(T, isolation, arch, wl.name, False))
            continue
        import numpy as np
        thr = np.stack([tenant_throughputs(r) for r in feas])
        rtt = np.stack([tenant_median_rtts(r) for r in feas])
        per_thr = float(np.nanmean(thr))
        ratios = [float(row.min() / row.max())
                  for row in thr if np.isfinite(row).all() and row.max() > 0]
        pt = TenantPoint(
            tenants=T, isolation=isolation, arch=arch, workload=wl.name,
            feasible=True,
            tenant_throughput_msgs_s=per_thr,
            tenant_median_rtt_s=float(np.nanmean(rtt)),
            fairness=float(np.nanmean([jain_fairness(row)
                                       for row in thr])),
            min_max_ratio=(float(np.mean(ratios)) if ratios
                           else float("nan")),
            rejected=float(np.mean([r.rejected_publishes for r in feas])),
            blocked=float(np.mean([r.blocked_confirms for r in feas])),
            n_runs=len(feas))
        if base is None:
            base = per_thr
        pt.degradation = (per_thr / base if base else float("nan"))
        points.append(pt)
    return points


def run_pattern(pattern: str, arch: str, workload: str | Workload,
                n_consumers: int, *,
                total_messages: int = 8192,
                n_runs: int = 3,
                seed: int = 0,
                engine: Optional[str] = None,
                inventory: Optional[ClusterInventory] = None,
                cal: Optional[Calibration] = None,
                **param_overrides) -> list[RunResult]:
    """Run one (pattern, architecture, workload, consumer-count) cell.

    The paper averages three runs per data point; we run ``n_runs`` seeds.
    Work-sharing patterns use equal producer/consumer counts; broadcast
    patterns use a single producer (paper §5.2).  ``engine`` selects the
    simulator backend: ``"vectorized"`` (the default — batched array
    engine, orders of magnitude faster at high consumer counts; see
    :mod:`repro.core.vectorized`) or ``"heap"`` (the exact one-event-per-
    message-hop reference).  ``None`` uses ``SimParams.engine``'s default.
    """
    wl = get_workload(workload) if isinstance(workload, str) else workload
    if engine is not None:
        param_overrides.setdefault("engine", engine)
    n_producers = 1 if pattern.startswith("broadcast") else n_consumers
    if pattern == "broadcast_gather" and "reply_factor" not in param_overrides:
        param_overrides["reply_factor"] = GATHER_REPLY_FACTOR
    results = []
    for r in range(n_runs):
        spec = ExperimentSpec(
            pattern=pattern, workload=wl, arch=arch,
            n_producers=n_producers, n_consumers=n_consumers,
            total_messages=total_messages,
            params=_params(seed + 1000 * r, **param_overrides))
        if cal is not None or inventory is not None:
            from repro.core.architectures import make_architecture
            inv = inventory or ClusterInventory()
            a = make_architecture(arch, inv, cal)
            results.append(run_experiment(spec, inv, a))
        else:
            results.append(run_experiment(spec))
    return results


def sweep(pattern: str, archs: Sequence[str], workload: str,
          consumers: Sequence[int] = CONSUMER_SWEEP, *,
          total_messages: int = 8192, n_runs: int = 3, seed: int = 0,
          engine: Optional[str] = None,
          inventory: Optional[ClusterInventory] = None,
          cal: Optional[Calibration] = None,
          **param_overrides) -> list[Summary]:
    """Full paper-style sweep; returns averaged summaries per cell."""
    out: list[Summary] = []
    for arch in archs:
        for nc in consumers:
            rs = run_pattern(pattern, arch, workload, nc,
                             total_messages=total_messages, n_runs=n_runs,
                             seed=seed, engine=engine,
                             inventory=inventory, cal=cal,
                             **param_overrides)
            out.append(average_summaries([summarize(r) for r in rs]))
    return out


def average_summaries(ss: Sequence[Summary]) -> Summary:
    """Average the metric fields over repeated runs (paper: 3-run mean).

    Averages over the *feasible subset* and records how many runs went
    into the mean in ``Summary.n_runs`` — a mixed-feasibility cell (some
    seeds infeasible) must not silently report a single seed's full
    metrics as a multi-run mean.  With no feasible run at all, the cell
    is reported infeasible with ``n_runs=0``."""
    import numpy as np
    feas = [s for s in ss if s.feasible]
    if not feas:
        out = Summary(**{**ss[0].__dict__})
        out.feasible = False
        out.n_runs = 0
        return out
    out = Summary(**{**feas[0].__dict__})
    out.n_runs = len(feas)
    for f in ("throughput_msgs_s", "median_rtt_s", "p95_rtt_s",
              "min_rtt_s", "goodput_gbps"):
        vals = [getattr(s, f) for s in feas]
        vals = [v for v in vals if np.isfinite(v)]
        setattr(out, f, float(np.mean(vals)) if vals else float("nan"))
    # float means: int(np.mean(...)) floored rare-overflow cells (e.g. a
    # mean of 0.33 rejects across seeds) to an invisible 0
    out.rejected = float(np.mean([s.rejected for s in feas]))
    out.blocked = float(np.mean([s.blocked for s in feas]))
    out.n_messages = int(np.mean([s.n_messages for s in feas]))
    return out
