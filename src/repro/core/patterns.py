"""Experiment drivers for the paper's three messaging patterns (§5.1).

* **work sharing** — embarrassingly parallel fan-out (hyperparameter
  searches, Monte-Carlo ensembles): producers push to shared work queues,
  messages round-robin across consumers. Metric: aggregate throughput.
* **work sharing with feedback** — distribute-with-reply (TF-PS/MXNet-style
  data-parallel DL, master-worker task farms): requests via the work-queue
  model, replies via per-producer direct reply queues. Metric: RTT.
* **broadcast & gather** — DDP motif (NCCL/Gloo: weight fan-out +
  gradient reduce): one producer fans out via pub-sub to every consumer and
  gathers all replies from a single gather queue. Metrics: broadcast
  throughput + gather RTT.

Each driver returns (RunResult, Summary) pairs across a consumer sweep, and
is consumed both by benchmarks/ (paper figures) and tests/.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.core.architectures import Calibration
from repro.core.ds2hpc import ClusterInventory
from repro.core.metrics import Summary, summarize
from repro.core.simulator import (
    ExperimentSpec, RunResult, SimParams, run_experiment)
from repro.core.workloads import Workload, get_workload

#: the paper's consumer sweep (Figs 4-8)
CONSUMER_SWEEP = (1, 2, 4, 8, 16, 32, 64)

#: broadcast&gather replies are aggregation/metric payloads, much smaller
#: than the 4 MiB broadcast body (paper §5.1: "all workers send back metrics
#: to be reduced at the initiator"): 4 MiB / 256 = 16 KiB replies. The sharp
#: RTT increase beyond 4 consumers (Fig 7b) then emerges from broker-egress
#: saturation on the broadcast leg plus the single producer gathering and
#: broadcasting concurrently.
GATHER_REPLY_FACTOR = 1.0 / 256.0


def _params(seed: int, **overrides) -> SimParams:
    p = SimParams(seed=seed)
    for k, v in overrides.items():
        setattr(p, k, v)
    return p


def run_pattern(pattern: str, arch: str, workload: str | Workload,
                n_consumers: int, *,
                total_messages: int = 8192,
                n_runs: int = 3,
                seed: int = 0,
                engine: str = "heap",
                inventory: Optional[ClusterInventory] = None,
                cal: Optional[Calibration] = None,
                **param_overrides) -> list[RunResult]:
    """Run one (pattern, architecture, workload, consumer-count) cell.

    The paper averages three runs per data point; we run ``n_runs`` seeds.
    Work-sharing patterns use equal producer/consumer counts; broadcast
    patterns use a single producer (paper §5.2).  ``engine`` selects the
    simulator backend: ``"heap"`` (exact, one event per message-hop) or
    ``"vectorized"`` (batched array engine — orders of magnitude faster at
    high consumer counts; see :mod:`repro.core.vectorized`).
    """
    wl = get_workload(workload) if isinstance(workload, str) else workload
    param_overrides.setdefault("engine", engine)
    n_producers = 1 if pattern.startswith("broadcast") else n_consumers
    if pattern == "broadcast_gather" and "reply_factor" not in param_overrides:
        param_overrides["reply_factor"] = GATHER_REPLY_FACTOR
    results = []
    for r in range(n_runs):
        spec = ExperimentSpec(
            pattern=pattern, workload=wl, arch=arch,
            n_producers=n_producers, n_consumers=n_consumers,
            total_messages=total_messages,
            params=_params(seed + 1000 * r, **param_overrides))
        if cal is not None or inventory is not None:
            from repro.core.architectures import make_architecture
            inv = inventory or ClusterInventory()
            a = make_architecture(arch, inv, cal)
            results.append(run_experiment(spec, inv, a))
        else:
            results.append(run_experiment(spec))
    return results


def sweep(pattern: str, archs: Sequence[str], workload: str,
          consumers: Sequence[int] = CONSUMER_SWEEP, *,
          total_messages: int = 8192, n_runs: int = 3, seed: int = 0,
          engine: str = "heap",
          inventory: Optional[ClusterInventory] = None,
          cal: Optional[Calibration] = None,
          **param_overrides) -> list[Summary]:
    """Full paper-style sweep; returns averaged summaries per cell."""
    out: list[Summary] = []
    for arch in archs:
        for nc in consumers:
            rs = run_pattern(pattern, arch, workload, nc,
                             total_messages=total_messages, n_runs=n_runs,
                             seed=seed, engine=engine,
                             inventory=inventory, cal=cal,
                             **param_overrides)
            out.append(average_summaries([summarize(r) for r in rs]))
    return out


def average_summaries(ss: Sequence[Summary]) -> Summary:
    """Average the metric fields over repeated runs (paper: 3-run mean)."""
    import numpy as np
    first = ss[0]
    if not all(s.feasible for s in ss):
        return first
    out = Summary(**{**first.__dict__})
    for f in ("throughput_msgs_s", "median_rtt_s", "p95_rtt_s",
              "min_rtt_s", "goodput_gbps"):
        vals = [getattr(s, f) for s in ss]
        vals = [v for v in vals if np.isfinite(v)]
        setattr(out, f, float(np.mean(vals)) if vals else float("nan"))
    out.rejected = int(np.mean([s.rejected for s in ss]))
    out.n_messages = int(np.mean([s.n_messages for s in ss]))
    return out
