"""Experiment drivers for the paper's three messaging patterns (§5.1).

* **work sharing** — embarrassingly parallel fan-out (hyperparameter
  searches, Monte-Carlo ensembles): producers push to shared work queues,
  messages round-robin across consumers. Metric: aggregate throughput.
* **work sharing with feedback** — distribute-with-reply (TF-PS/MXNet-style
  data-parallel DL, master-worker task farms): requests via the work-queue
  model, replies via per-producer direct reply queues. Metric: RTT.
* **broadcast & gather** — DDP motif (NCCL/Gloo: weight fan-out +
  gradient reduce): one producer fans out via pub-sub to every consumer and
  gathers all replies from a single gather queue. Metrics: broadcast
  throughput + gather RTT.

Each driver returns (RunResult, Summary) pairs across a consumer sweep, and
is consumed both by benchmarks/ (paper figures) and tests/.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

from repro.core.architectures import Calibration
from repro.core.ds2hpc import ClusterInventory
from repro.core.metrics import (
    Summary, jain_fairness, summarize, tenant_median_rtts,
    tenant_throughputs)
from repro.core.simulator import (
    ExperimentSpec, RunResult, SimParams, run_experiment)
from repro.core.workloads import Workload, get_workload

#: the paper's consumer sweep (Figs 4-8)
CONSUMER_SWEEP = (1, 2, 4, 8, 16, 32, 64)

#: broadcast&gather replies are aggregation/metric payloads, much smaller
#: than the 4 MiB broadcast body (paper §5.1: "all workers send back metrics
#: to be reduced at the initiator"): 4 MiB / 256 = 16 KiB replies. The sharp
#: RTT increase beyond 4 consumers (Fig 7b) then emerges from broker-egress
#: saturation on the broadcast leg plus the single producer gathering and
#: broadcasting concurrently.
GATHER_REPLY_FACTOR = 1.0 / 256.0


def _params(seed: int, **overrides: Any) -> SimParams:
    # construct in one shot so SimParams.__post_init__ validates the
    # overrides (engine name, vec_round sub-multiple, positive knobs)
    return SimParams(seed=seed, **overrides)


#: Overflow-regime stress scenario: a regime the paper's configurations
#: never trigger, exercisable at scale on the vectorized engine.  A small
#: confirm window, slow consumers and a tight per-queue byte cap push the
#: work queues through repeated credit-flow blocking episodes
#: (publisher confirms withheld above ``FLOW_CREDIT x producers`` backlog)
#: into reject-publish overflow (producers observe rejects and re-publish
#: after the backoff).  ``queue_cap_msgs`` sits just above the credit
#: threshold so *both* mechanisms fire: the queue blocks at the threshold,
#: and the in-flight window landing on top of it overflows the cap.
#: the stress scenario's SimParams overrides (exported so benchmark cache
#: fingerprints can cover exactly what the runs used)
OVERFLOW_STRESS_DEFAULTS = dict(confirm_window=64, prefetch=16,
                                ack_batch=4, consumer_proc_s=2e-3)


def overflow_stress(arch: str, n_consumers: int, *,
                    workload: str | Workload = "dstream",
                    total_messages: Optional[int] = None,
                    queue_cap_msgs: Optional[int] = None,
                    n_runs: int = 1, seed: int = 0,
                    engine: Optional[str] = None,
                    **param_overrides: Any) -> list[RunResult]:
    """Run the overflow-regime stress cell (feedback pattern, equal
    producers/consumers, up to 1024 consumers on the vectorized engine).

    ``queue_cap_msgs`` defaults to ~6% above the credit threshold
    (``FLOW_CREDIT x producers``) so both mechanisms fire; pass a small
    explicit cap for large consumer counts to get a pure reject-publish
    regime at affordable message volumes (the credit threshold itself
    scales with producers).  Returns the per-seed :class:`RunResult`
    list; results report nonzero ``rejected_publishes`` (and, in the
    default both-mechanisms regime, ``blocked_confirms``)."""
    from repro.core.broker import ClassicQueue
    wl = get_workload(workload) if isinstance(workload, str) else workload
    if queue_cap_msgs is None:
        queue_cap_msgs = int(ClassicQueue.FLOW_CREDIT * n_consumers * 1.06)
    if total_messages is None:
        # enough volume for repeated blocking/overflow episodes per queue
        total_messages = max(8192, 4 * queue_cap_msgs)
    for k, v in OVERFLOW_STRESS_DEFAULTS.items():
        param_overrides.setdefault(k, v)
    param_overrides.setdefault("queue_max_bytes",
                               queue_cap_msgs * wl.payload_bytes)
    return run_pattern("feedback", arch, wl, n_consumers,
                       total_messages=total_messages, n_runs=n_runs,
                       seed=seed, engine=engine, **param_overrides)


#: the multi-tenant sweep (paper §6's MSS multi-user scalability claim,
#: made quantitative): number of independent workflows on one broker
TENANT_SWEEP = (1, 2, 4, 8, 16, 32, 64)


@dataclasses.dataclass
class TenantPoint:
    """One point of the multi-tenant contention curve: ``tenants``
    independent workflows sharing one deployment of ``arch``."""

    tenants: int
    isolation: str                   # "shared" | "vhost"
    arch: str
    workload: str
    feasible: bool
    #: mean per-tenant consumed-message rate (msgs/s per tenant)
    tenant_throughput_msgs_s: float = float("nan")
    #: mean of the per-tenant median request->reply RTTs (s)
    tenant_median_rtt_s: float = float("nan")
    #: Jain fairness index over the per-tenant throughputs (1.0 = even)
    fairness: float = float("nan")
    #: worst-off tenant's share of the best-off tenant's rate
    min_max_ratio: float = float("nan")
    #: per-tenant throughput relative to the explicit baseline cell
    #: (``multi_tenant(baseline_tenants=...)``, default the 1-tenant
    #: deployment; 1.0 = no degradation as tenants are added)
    degradation: float = float("nan")
    #: the busiest shared facility-ingress resource (DTS gateway NIC,
    #: PRS tunnel, MSS ingress, DSN NodePort NICs) as a fraction of the
    #: cell's bottleneck, from the static cost model: ~1.0 means the
    #: shared ingress is what every tenant is queueing on
    ingress_utilization: float = float("nan")
    rejected: float = 0.0
    blocked: float = 0.0
    n_runs: int = 0


#: resource-key prefixes that count as "shared facility ingress" for
#: :attr:`TenantPoint.ingress_utilization`.  Deliberately excluded:
#: per-tenant ``ttun:*`` pairs (dedicated, not shared) and the
#: broker-internal ``dsn_int:*`` SDN links (hence the colon-terminated
#: NodePort prefixes, which would otherwise prefix-match them).
INGRESS_RESOURCE_PREFIXES = (
    "dts_gw", "ingress_in", "ingress_out", "tunnel", "dsn_in:", "dsn_out:")


def _ingress_utilization(spec: ExperimentSpec,
                         inventory: Optional[ClusterInventory]) -> float:
    """Shared facility-ingress utilization of one cell, off the
    vectorized engine's static bottleneck analysis (a construction-time
    probe — no run needed, engine-choice independent)."""
    import numpy as np
    from repro.core.simulator import InfeasibleConfiguration
    from repro.core.vectorized import VectorizedStreamSim
    try:
        sim = VectorizedStreamSim(spec, inventory)
    except InfeasibleConfiguration:
        return float("nan")
    vals = [v for k, v in sim.resource_cost.items()
            if k.startswith(INGRESS_RESOURCE_PREFIXES)]
    if not vals or sim.bottleneck_cost <= 0:
        return float("nan")
    return float(np.max(vals) / sim.bottleneck_cost)


def multi_tenant(arch: str = "mss",
                 tenant_counts: Sequence[int] = TENANT_SWEEP, *,
                 isolation: str = "vhost",
                 producers_per_tenant: int = 1,
                 consumers_per_tenant: int = 1,
                 workload: str | Workload = "dstream",
                 messages_per_tenant: int = 256,
                 n_runs: int = 3, seed: int = 0,
                 engine: Optional[str] = None,
                 inventory: Optional[ClusterInventory] = None,
                 baseline_tenants: int = 1,
                 **param_overrides: Any) -> list[TenantPoint]:
    """Multi-tenant contention sweep: N independent feedback workflows
    (1 producer + 1 consumer each by default) share one deployment of
    ``arch``, as tenant count grows ``1 -> 64``.

    This quantifies the paper's §6 deployment-feasibility argument.
    What "sharing one deployment" means is architecture-specific:

    * ``mss`` — every tenant funnels through the same LB + ingress +
      broker fabric (the paper's "greater deployment feasibility and
      scalability across multiple users" claim);
    * ``dts`` — each tenant gets its own dedicated minimal-hop S2DS
      tunnel pair; contention appears at the shared facility gateway
      NIC the tunnels terminate on (see
      :class:`repro.core.architectures.DirectStreaming`);
    * ``prs-*`` — tenants multiplex the one shared proxy pair ahead of
      per-tenant queues (Stunnel's 16-connection cap makes large tenant
      counts infeasible, as in the paper's missing data points).

    ``isolation`` picks the broker layout: ``"vhost"`` gives each
    tenant its own queues in its own vhost (RabbitMQ namespacing — the
    S3M provisioning model's per-project isolation), ``"shared"`` drops
    every tenant into the same work queues (messages mix across
    tenants).

    Offered load scales with the tenant count (``messages_per_tenant``
    each), so a flat curve means perfect scaling.  All cells (every
    tenant count x ``n_runs`` seeds) go through one
    :func:`~repro.core.vectorized.run_many` call, so each cell's seeds
    stack as lanes of one batched engine run.  Returns one
    :class:`TenantPoint` per entry of ``tenant_counts``, with
    ``degradation`` relative to the explicit ``baseline_tenants`` cell
    — which is run even when the sweep itself starts at a higher
    tenant count, so a ``(4, 16, 64)`` sweep still reports degradation
    against the single-tenant deployment."""
    import numpy as np
    from repro.core.vectorized import run_many
    wl = get_workload(workload) if isinstance(workload, str) else workload
    if engine is not None:
        param_overrides.setdefault("engine", engine)

    def spec_of(T: int, r: int) -> ExperimentSpec:
        return ExperimentSpec(
            pattern="feedback", workload=wl, arch=arch,
            n_producers=T * producers_per_tenant,
            n_consumers=T * consumers_per_tenant,
            total_messages=T * messages_per_tenant,
            params=_params(seed + 1000 * r, **param_overrides),
            tenants=T, tenant_isolation=isolation)

    counts = list(tenant_counts)
    run_counts = list(counts)
    if baseline_tenants not in run_counts:
        run_counts.append(baseline_tenants)
    specs = [spec_of(T, r) for T in run_counts for r in range(n_runs)]
    results = run_many(specs, inventory)
    by_count = {T: results[i * n_runs:(i + 1) * n_runs]
                for i, T in enumerate(run_counts)}

    def stats_of(T: int) -> Optional[dict]:
        feas = [r for r in by_count[T] if r.feasible]
        if not feas:
            return None
        thr = np.stack([tenant_throughputs(r) for r in feas])
        rtt = np.stack([tenant_median_rtts(r) for r in feas])
        ratios = [float(row.min() / row.max())
                  for row in thr if np.isfinite(row).all() and row.max() > 0]
        return dict(
            per_thr=float(np.nanmean(thr)),
            rtt=float(np.nanmean(rtt)),
            fairness=float(np.nanmean([jain_fairness(row) for row in thr])),
            min_max=(float(np.mean(ratios)) if ratios else float("nan")),
            rejected=float(np.mean([r.rejected_publishes for r in feas])),
            blocked=float(np.mean([r.blocked_confirms for r in feas])),
            n_runs=len(feas))

    all_stats = {T: stats_of(T) for T in run_counts}
    base_st = all_stats.get(baseline_tenants)
    base = base_st["per_thr"] if base_st else None
    points: list[TenantPoint] = []
    for T in counts:
        st = all_stats[T]
        if st is None:
            points.append(TenantPoint(T, isolation, arch, wl.name, False))
            continue
        points.append(TenantPoint(
            tenants=T, isolation=isolation, arch=arch, workload=wl.name,
            feasible=True,
            tenant_throughput_msgs_s=st["per_thr"],
            tenant_median_rtt_s=st["rtt"],
            fairness=st["fairness"],
            min_max_ratio=st["min_max"],
            degradation=(st["per_thr"] / base if base else float("nan")),
            ingress_utilization=_ingress_utilization(spec_of(T, 0),
                                                     inventory),
            rejected=st["rejected"],
            blocked=st["blocked"],
            n_runs=st["n_runs"]))
    return points


# ---------------------------------------------------------------------------
# Cross-architecture deployment feasibility (paper §6, quantified)
# ---------------------------------------------------------------------------

#: the three deployment models of the §6 comparison (prs-haproxy rather
#: than prs-stunnel: the Stunnel tunnel's 16-connection cap makes most
#: of the tenant sweep infeasible, exactly the paper's missing points)
DEPLOYMENT_ARCHS = ("dts", "prs-haproxy", "mss")


@dataclasses.dataclass
class FeasibilityStudy:
    """Result of :func:`deployment_feasibility`: one multi-tenant curve
    per architecture plus the DTS-vs-MSS crossover headline."""

    archs: tuple
    tenant_counts: tuple
    #: arch name -> one TenantPoint per tenant count
    curves: dict[str, list[TenantPoint]]
    #: interpolated tenant count where MSS's shared-broker per-tenant
    #: throughput first meets per-tenant-tunnel DTS (NaN = no crossover
    #: inside the sweep)
    crossover_tenants: float = float("nan")
    #: DTS's shared facility-ingress utilization at the crossover
    crossover_utilization: float = float("nan")

    def headline(self) -> str:
        if self.crossover_tenants != self.crossover_tenants:   # NaN
            return ("no DTS-vs-MSS crossover inside the sweep "
                    f"(tenants {min(self.tenant_counts)}"
                    f"-{max(self.tenant_counts)})")
        return (f"MSS's shared broker overtakes per-tenant DTS tunnels "
                f"at ~{self.crossover_tenants:.1f} tenants "
                f"(DTS ingress utilization "
                f"{self.crossover_utilization:.2f})")


def crossover_point(a_pts: Sequence[TenantPoint],
                    b_pts: Sequence[TenantPoint]
                    ) -> tuple[float, float]:
    """First tenant count where curve ``b``'s per-tenant throughput
    meets/overtakes curve ``a``'s, interpolated in ``log2(tenants)``
    between the bracketing sweep points.  Returns ``(tenants,
    a_ingress_utilization_at_crossover)``; ``(nan, nan)`` when the
    curves never cross inside the sweep (or share no feasible tenant
    counts)."""
    import numpy as np
    a_by = {p.tenants: p for p in a_pts if p.feasible}
    b_by = {p.tenants: p for p in b_pts if p.feasible}
    common = sorted(set(a_by) & set(b_by))
    if not common:
        return float("nan"), float("nan")
    diffs = [b_by[T].tenant_throughput_msgs_s
             - a_by[T].tenant_throughput_msgs_s for T in common]
    if diffs[0] >= 0:
        return float(common[0]), float(a_by[common[0]].ingress_utilization)
    for (T0, d0), (T1, d1) in zip(zip(common, diffs),
                                  zip(common[1:], diffs[1:])):
        if d0 < 0 <= d1:
            f = -d0 / (d1 - d0) if d1 != d0 else 0.0
            lT = np.log2(T0) + f * (np.log2(T1) - np.log2(T0))
            u0 = a_by[T0].ingress_utilization
            u1 = a_by[T1].ingress_utilization
            return float(2.0 ** lT), float(u0 + f * (u1 - u0))
    return float("nan"), float("nan")


def deployment_feasibility(archs: Sequence[str] = DEPLOYMENT_ARCHS,
                           tenant_counts: Sequence[int] = TENANT_SWEEP, *,
                           isolation: str = "vhost",
                           workload: str | Workload = "dstream",
                           messages_per_tenant: int = 256,
                           n_runs: int = 3, seed: int = 0,
                           engine: Optional[str] = None,
                           inventory: Optional[ClusterInventory] = None,
                           baseline_tenants: int = 1,
                           **param_overrides: Any) -> FeasibilityStudy:
    """The paper's §6 deployment-feasibility argument, quantified: the
    same 1 -> N tenant sweep across all three architecture deployment
    models (per-tenant DTS tunnels vs PRS shared-proxy ingress vs the
    MSS managed broker), one :class:`TenantPoint` curve per
    architecture (each arch's cells batched through ``run_many``
    stacked execution — see :func:`multi_tenant`).

    The headline is the **crossover point**: DTS's dedicated per-tenant
    tunnels win at low tenant counts (minimal hops, no shared-fabric
    tax), but every tunnel terminates on the facility's gateway NIC —
    as that shared ingress saturates and the gateway's per-tenant
    endpoint overhead grows, MSS's wider managed ingress overtakes it.
    ``crossover_tenants`` / ``crossover_utilization`` report where, and
    at what DTS ingress utilization, that happens."""
    curves = {arch: multi_tenant(
                  arch, tenant_counts, isolation=isolation,
                  workload=workload,
                  messages_per_tenant=messages_per_tenant,
                  n_runs=n_runs, seed=seed, engine=engine,
                  inventory=inventory, baseline_tenants=baseline_tenants,
                  **param_overrides)
              for arch in archs}
    ct, cu = float("nan"), float("nan")
    if "dts" in curves and "mss" in curves:
        ct, cu = crossover_point(curves["dts"], curves["mss"])
    return FeasibilityStudy(archs=tuple(archs),
                            tenant_counts=tuple(tenant_counts),
                            curves=curves, crossover_tenants=ct,
                            crossover_utilization=cu)


def pattern_spec(pattern: str, arch: str, workload: str | Workload,
                 n_consumers: int, *,
                 total_messages: int = 8192,
                 seed: int = 0,
                 engine: Optional[str] = None,
                 **param_overrides: Any) -> ExperimentSpec:
    """The fully-resolved :class:`ExperimentSpec` for one (pattern, arch,
    workload, consumer-count) run — the single spec construction behind
    :func:`run_pattern` and the bench cache's engine resolution
    (``benchmarks.common``), so pattern-implied defaults (single
    broadcast producer, gather reply factor) resolve identically in the
    run and in its cache key."""
    wl = get_workload(workload) if isinstance(workload, str) else workload
    if engine is not None:
        param_overrides.setdefault("engine", engine)
    n_producers = 1 if pattern.startswith("broadcast") else n_consumers
    if pattern == "broadcast_gather" and "reply_factor" not in param_overrides:
        param_overrides["reply_factor"] = GATHER_REPLY_FACTOR
    return ExperimentSpec(
        pattern=pattern, workload=wl, arch=arch,
        n_producers=n_producers, n_consumers=n_consumers,
        total_messages=total_messages,
        params=_params(seed, **param_overrides))


def run_pattern(pattern: str, arch: str, workload: str | Workload,
                n_consumers: int, *,
                total_messages: int = 8192,
                n_runs: int = 3,
                seed: int = 0,
                engine: Optional[str] = None,
                inventory: Optional[ClusterInventory] = None,
                cal: Optional[Calibration] = None,
                **param_overrides: Any) -> list[RunResult]:
    """Run one (pattern, architecture, workload, consumer-count) cell.

    The paper averages three runs per data point; we run ``n_runs`` seeds.
    Work-sharing patterns use equal producer/consumer counts; broadcast
    patterns use a single producer (paper §5.2).  ``engine`` selects the
    simulator backend: ``"vectorized"`` (the default — batched array
    engine, orders of magnitude faster at high consumer counts; see
    :mod:`repro.core.vectorized`) or ``"heap"`` (the exact one-event-per-
    message-hop reference).  ``None`` uses ``SimParams.engine``'s default.
    """
    results = []
    for r in range(n_runs):
        spec = pattern_spec(pattern, arch, workload, n_consumers,
                            total_messages=total_messages,
                            seed=seed + 1000 * r, engine=engine,
                            **param_overrides)
        if cal is not None or inventory is not None:
            from repro.core.architectures import make_architecture
            inv = inventory or ClusterInventory()
            a = make_architecture(arch, inv, cal)
            results.append(run_experiment(spec, inv, a))
        else:
            results.append(run_experiment(spec))
    return results


def sweep(pattern: str, archs: Sequence[str], workload: str,
          consumers: Sequence[int] = CONSUMER_SWEEP, *,
          total_messages: int = 8192, n_runs: int = 3, seed: int = 0,
          engine: Optional[str] = None,
          inventory: Optional[ClusterInventory] = None,
          cal: Optional[Calibration] = None,
          **param_overrides: Any) -> list[Summary]:
    """Full paper-style sweep; returns averaged summaries per cell."""
    out: list[Summary] = []
    for arch in archs:
        for nc in consumers:
            rs = run_pattern(pattern, arch, workload, nc,
                             total_messages=total_messages, n_runs=n_runs,
                             seed=seed, engine=engine,
                             inventory=inventory, cal=cal,
                             **param_overrides)
            out.append(average_summaries([summarize(r) for r in rs]))
    return out


def average_summaries(ss: Sequence[Summary]) -> Summary:
    """Average the metric fields over repeated runs (paper: 3-run mean).

    Averages over the *feasible subset* and records how many runs went
    into the mean in ``Summary.n_runs`` — a mixed-feasibility cell (some
    seeds infeasible) must not silently report a single seed's full
    metrics as a multi-run mean.  With no feasible run at all, the cell
    is reported infeasible with ``n_runs=0``."""
    import numpy as np
    feas = [s for s in ss if s.feasible]
    if not feas:
        out = Summary(**{**ss[0].__dict__})
        out.feasible = False
        out.n_runs = 0
        return out
    out = Summary(**{**feas[0].__dict__})
    out.n_runs = len(feas)
    for f in ("throughput_msgs_s", "median_rtt_s", "p95_rtt_s",
              "min_rtt_s", "goodput_gbps"):
        vals = [getattr(s, f) for s in feas]
        vals = [v for v in vals if np.isfinite(v)]
        setattr(out, f, float(np.mean(vals)) if vals else float("nan"))
    # float means: int(np.mean(...)) floored rare-overflow cells (e.g. a
    # mean of 0.33 rejects across seeds) to an invisible 0
    out.rejected = float(np.mean([s.rejected for s in feas]))
    out.blocked = float(np.mean([s.blocked for s in feas]))
    out.n_messages = int(np.mean([s.n_messages for s in feas]))
    # surface a mixed-engine mean (e.g. some seeds fell back from jax)
    engines = sorted({s.engine for s in feas if s.engine})
    out.engine = engines[0] if len(engines) == 1 else "+".join(engines)
    return out
