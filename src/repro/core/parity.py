"""Engine-parity tolerance bands — the single source of truth.

Every numeric band the cross-engine correctness story rests on lives in
this module and nowhere else:

* the parity suites (``tests/test_engine_parity.py``,
  ``tests/test_multi_tenant.py``, ``tests/test_campaign.py``) import
  these constants for their assertions, and
* the parity-tolerance table in ``docs/engines.md`` carries one band id
  per row; the ``streamlint`` docs-drift rule (SL501, see
  ``tools/streamlint``) parses both sides and fails the build when a
  documented bound and the enforced constant disagree — in either
  direction.

Change a band here and the tests, the docs check, and the rule catalog
all follow; change the docs table alone and CI fails.

Keys are ``<cell>.<arch-or-scope>.<metric>``; values are *fractional*
relative deviations (``0.03`` = the docs table's "≤ 3%").
``FACTOR_BANDS`` holds the knife-edge counter bands, expressed as
``(lo, hi)`` multiplicative factors vs the reference realization.
"""

from __future__ import annotations

#: relative-deviation bounds of the batched engines (vectorized + jax)
#: vs the heap reference, as enforced by the parity suites
PARITY_BANDS: dict[str, float] = {
    # Fig 4: aggregate work-sharing throughput
    "work_sharing.dts.throughput": 0.03,
    "work_sharing.prs-haproxy.throughput": 0.02,
    "work_sharing.mss.throughput": 0.02,
    # Fig 6: feedback median RTT (throughput rides along for all archs)
    "feedback.dts.median_rtt": 0.035,
    "feedback.prs-haproxy.median_rtt": 0.02,
    "feedback.mss.median_rtt": 0.02,
    "feedback.all.throughput": 0.02,
    # Fig 7: broadcast throughput + gather RTT
    "broadcast_gather.all.throughput": 0.02,
    "broadcast_gather.dts.gather_rtt": 0.02,
    "broadcast_gather.prs-haproxy.gather_rtt": 0.03,
    "broadcast_gather.mss.gather_rtt": 0.02,
    # overflow stress cell (reject-publish + credit-flow both active)
    "overflow.dts.summary": 0.05,
    "overflow.dts.counters": 0.25,
    # multi-tenant cells, all three deployment archs, both isolations
    "multi_tenant.all.summary": 0.05,
    "multi_tenant.all.tenant_throughput": 0.08,
    # whole-run device program (jax_device_loop=True) vs the
    # vectorized cohort loop: the wave schedule is a static pipeline,
    # so these are modeling bands, not arithmetic-noise bands.  They
    # apply only inside the supported regime (the
    # ``_device_loop_ok`` gate in repro.core.jax_device_loop);
    # gated cells fall back to the per-cohort path and carry the
    # ordinary engine bands instead
    "device_loop.all.throughput": 0.06,
    "device_loop.all.median_rtt": 0.05,
    # stacked seed-lanes (campaign layer): non-pilot lanes vs solo runs
    "stacked.lanes.summary": 0.02,
    # stacked overflow-regime lanes vs their own solo *heap* runs
    "stacked_overflow.lanes.summary": 0.05,
}

#: knife-edge reject/block counters in stacked overflow lanes: the
#: threshold counts swing with the jitter realization in both engines,
#: so they are held to (lo, hi) factor bands vs the lane's heap run
#: (plus a hard nonzero requirement asserted in the tests)
FACTOR_BANDS: dict[str, tuple[float, float]] = {
    "stacked_overflow.lanes.rejected": (0.3, 3.0),
    "stacked_overflow.lanes.blocked": (0.5, 2.0),
}


def band(key: str) -> float:
    """Look up a parity band, with the known keys in the error."""
    try:
        return PARITY_BANDS[key]
    except KeyError:
        raise KeyError(
            f"unknown parity band {key!r}; known: "
            f"{sorted(PARITY_BANDS)}") from None


def factor_band(key: str) -> tuple[float, float]:
    """Look up a counter factor band, with the known keys in the error."""
    try:
        return FACTOR_BANDS[key]
    except KeyError:
        raise KeyError(
            f"unknown factor band {key!r}; known: "
            f"{sorted(FACTOR_BANDS)}") from None
