"""RabbitMQ-semantics streaming-service model (paper §4.2, §5.2).

The paper deploys a three-node RabbitMQ 4.0.5 cluster on the DSNs and drives
it through the AMQP 0-9-1 model. This module implements the *semantics* that
the evaluation depends on, in a time-agnostic way so that both engines can
drive it:

* the discrete-event simulator (:mod:`repro.core.simulator`) advances a
  virtual clock and asks the broker what to do next;
* the real-time ingest engine (:mod:`repro.streaming.rtbroker`) wraps the
  same state machine in locks/condvars for the training data plane.

Semantics implemented (all exercised by tests/test_broker.py):

* classic queues with FIFO order and bounded memory;
* ``reject-publish`` overflow policy — producers observe backpressure and may
  re-publish (paper §5.2);
* routing models: **work queue** (shared queue, round-robin across
  consumers), **direct** (per-producer reply queues), **fanout** (pub-sub
  broadcast) — the three models behind the paper's three messaging patterns;
* consumer prefetch windows (basic.qos) and **batch acknowledgements**;
* publisher confirms (batched), used for producer flow control;
* redelivery of unacked messages when a consumer disconnects/crashes —
  the "rare events will not be lost" property the paper calls out for
  GRETA/Deleria (§6);
* a 3-node cluster model with queue home-node placement: clients connected
  to a different node than the queue's home pay an extra intra-cluster hop
  (the simulator charges for it).
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict, deque
from typing import Iterable, Optional

from repro.core.workloads import MIB


# --------------------------------------------------------------------------
# Messages
# --------------------------------------------------------------------------

_msg_ids = itertools.count()


@dataclasses.dataclass
class Message:
    """One AMQP message. ``body`` may be None in pure-simulation runs where
    only ``size`` matters; the real-time path carries actual payloads."""

    routing_key: str
    size: int
    body: Optional[bytes] = None
    headers: dict = dataclasses.field(default_factory=dict)
    msg_id: int = dataclasses.field(default_factory=lambda: next(_msg_ids))
    producer_id: Optional[str] = None
    publish_time: float = 0.0          # stamped by the engine
    redelivered: bool = False
    reply_to: Optional[str] = None     # direct-reply routing (feedback pattern)
    correlation_id: Optional[int] = None


@dataclasses.dataclass
class Delivery:
    """A message handed to a consumer, pending ack."""

    message: Message
    consumer_id: str
    queue: str
    delivery_tag: int


# --------------------------------------------------------------------------
# Queues
# --------------------------------------------------------------------------


class OverflowPolicy:
    REJECT_PUBLISH = "reject-publish"
    DROP_HEAD = "drop-head"


@dataclasses.dataclass
class QueueStats:
    published: int = 0
    rejected: int = 0
    delivered: int = 0
    acked: int = 0
    redelivered: int = 0


class ClassicQueue:
    """RabbitMQ classic queue: FIFO, memory-bounded, round-robin delivery."""

    #: RabbitMQ credit-flow: a publishing channel is blocked when its
    #: un-drained backlog exceeds ~400 messages (credit_flow_default_credit)
    FLOW_CREDIT = 400

    def __init__(
        self,
        name: str,
        home_node: int,
        max_bytes: int,
        overflow: str = OverflowPolicy.REJECT_PUBLISH,
    ) -> None:
        self.name = name
        self.home_node = home_node
        self.max_bytes = max_bytes
        self.overflow = overflow
        self.ready: deque[Message] = deque()
        self.bytes_ready = 0
        self.stats = QueueStats()
        self.publishers: set[str] = set()
        # round-robin cursor over consumer ids (insertion-ordered)
        self._consumers: "OrderedDict[str, None]" = OrderedDict()

    # -- credit-based flow control -------------------------------------------
    @property
    def flow_threshold(self) -> int:
        return self.FLOW_CREDIT * max(1, len(self.publishers))

    @property
    def flow_blocked(self) -> bool:
        """True when publishers to this queue should be throttled (their
        confirms withheld) until the queue drains."""
        return len(self.ready) > self.flow_threshold

    @property
    def flow_resume(self) -> bool:
        return len(self.ready) <= self.flow_threshold // 2

    # -- consumer registry ---------------------------------------------------
    def add_consumer(self, consumer_id: str) -> None:
        self._consumers.setdefault(consumer_id, None)

    def remove_consumer(self, consumer_id: str) -> None:
        self._consumers.pop(consumer_id, None)

    @property
    def consumer_ids(self) -> list[str]:
        return list(self._consumers)

    # -- publish / requeue ----------------------------------------------------
    def offer(self, msg: Message) -> bool:
        """Try to enqueue. Returns False (reject-publish) when full."""
        if self.bytes_ready + msg.size > self.max_bytes:
            if self.overflow == OverflowPolicy.REJECT_PUBLISH:
                self.stats.rejected += 1
                return False
            while self.ready and self.bytes_ready + msg.size > self.max_bytes:
                dropped = self.ready.popleft()
                self.bytes_ready -= dropped.size
        self.ready.append(msg)
        self.bytes_ready += msg.size
        self.stats.published += 1
        return True

    def requeue_front(self, msgs: Iterable[Message]) -> None:
        """Redelivery path: crashed consumer's unacked messages go back to
        the *front* preserving original order, flagged redelivered."""
        for m in reversed(list(msgs)):
            m.redelivered = True
            self.ready.appendleft(m)
            self.bytes_ready += m.size
            self.stats.redelivered += 1

    def pop(self) -> Optional[Message]:
        if not self.ready:
            return None
        m = self.ready.popleft()
        self.bytes_ready -= m.size
        return m

    def __len__(self) -> int:
        return len(self.ready)


# --------------------------------------------------------------------------
# Consumers (broker-side channel state)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ConsumerChannel:
    consumer_id: str
    queue: str
    prefetch: int                      # basic.qos window (0 = unlimited)
    connected_node: int = 0
    next_tag: int = 1
    # unacked deliveries in tag order (for ack-multiple semantics)
    unacked: "OrderedDict[int, Delivery]" = dataclasses.field(
        default_factory=OrderedDict
    )

    @property
    def window_available(self) -> int:
        if self.prefetch <= 0:
            return 1 << 30
        return max(0, self.prefetch - len(self.unacked))


# --------------------------------------------------------------------------
# The broker cluster state machine
# --------------------------------------------------------------------------


class BrokerCluster:
    """Three-node RabbitMQ-model cluster (paper: RMQS1..3 on three DSNs).

    Memory accounting follows the paper's §5.2 configuration: of the RAM
    allocated per server, 80% is reserved for data-payload queues and 20%
    for control/management queues.
    """

    def __init__(
        self,
        n_nodes: int = 3,
        ram_bytes_per_node: int = 32 * 1024 * MIB,
        data_fraction: float = 0.8,
        default_prefetch: int = 64,
    ) -> None:
        self.n_nodes = n_nodes
        self.ram_bytes_per_node = ram_bytes_per_node
        self.data_fraction = data_fraction
        self.default_prefetch = default_prefetch
        self.queues: dict[str, ClassicQueue] = {}
        self.fanout_bindings: dict[str, list[str]] = {}  # exchange -> queues
        self.channels: dict[str, ConsumerChannel] = {}
        self._next_home = 0
        self.confirms_enabled = True

    # -- topology --------------------------------------------------------------
    @staticmethod
    def vhost_name(vhost: Optional[str], name: str) -> str:
        """Fully-qualified queue name: ``<vhost>/<name>`` (RabbitMQ-style
        virtual-host namespacing), or ``name`` for the default vhost."""
        return f"{vhost}/{name}" if vhost else name

    def declare_queue(
        self,
        name: str,
        *,
        control: bool = False,
        max_bytes: Optional[int] = None,
        home_node: Optional[int] = None,
        vhost: Optional[str] = None,
    ) -> ClassicQueue:
        """Declare (or return) a classic queue.  ``vhost`` namespaces the
        queue per tenant: the same base name declared in two vhosts
        yields two independent queues (multi-tenant MSS scenario); the
        returned queue's ``name`` is the fully-qualified one clients
        must publish/consume with."""
        name = self.vhost_name(vhost, name)
        if name in self.queues:
            return self.queues[name]
        if max_bytes is None:
            frac = (1.0 - self.data_fraction) if control else self.data_fraction
            # budget divided evenly among queues of the same class is an
            # approximation; the paper caps the whole class at frac*RAM.
            max_bytes = int(frac * self.ram_bytes_per_node)
        if home_node is None:
            home_node = self._next_home % self.n_nodes
            self._next_home += 1
        q = ClassicQueue(name, home_node, max_bytes)
        self.queues[name] = q
        return q

    def declare_fanout(self, exchange: str, queue_names: list[str]) -> None:
        for qn in queue_names:
            if qn not in self.queues:
                raise KeyError(f"fanout binding to undeclared queue {qn}")
        self.fanout_bindings[exchange] = list(queue_names)

    def bind_fanout(self, exchange: str, queue_name: str) -> None:
        self.fanout_bindings.setdefault(exchange, []).append(queue_name)

    # -- publish ----------------------------------------------------------------
    def publish(self, msg: Message) -> tuple[bool, list[str]]:
        """Route and enqueue. Returns (accepted, queues_enqueued).

        Work-queue / direct routing: routing_key == queue name.
        Fanout: routing_key == "fanout:<exchange>" replicates to all bound
        queues; accepted only if *all* bound queues accept (mirrors
        reject-publish on a full downstream queue).
        """
        if msg.routing_key.startswith("fanout:"):
            exchange = msg.routing_key.split(":", 1)[1]
            targets = self.fanout_bindings.get(exchange, [])
            if not targets:
                return False, []
            # check capacity first for atomicity
            for qn in targets:
                q = self.queues[qn]
                if q.bytes_ready + msg.size > q.max_bytes:
                    q.stats.rejected += 1
                    return False, []
            out = []
            for qn in targets:
                copy = dataclasses.replace(msg, msg_id=next(_msg_ids))
                q = self.queues[qn]
                if msg.producer_id:
                    q.publishers.add(msg.producer_id)
                q.offer(copy)
                out.append(qn)
            return True, out
        q = self.queues.get(msg.routing_key)
        if q is None:
            return False, []
        if msg.producer_id:
            q.publishers.add(msg.producer_id)
        ok = q.offer(msg)
        return ok, ([q.name] if ok else [])

    # -- consume ----------------------------------------------------------------
    def register_consumer(
        self,
        consumer_id: str,
        queue: str,
        prefetch: Optional[int] = None,
        connected_node: Optional[int] = None,
    ) -> ConsumerChannel:
        q = self.queues[queue]
        node = q.home_node if connected_node is None else connected_node
        ch = ConsumerChannel(
            consumer_id=consumer_id,
            queue=queue,
            prefetch=self.default_prefetch if prefetch is None else prefetch,
            connected_node=node,
        )
        self.channels[consumer_id] = ch
        q.add_consumer(consumer_id)
        return ch

    def next_delivery(self, queue_name: str) -> Optional[Delivery]:
        """Round-robin the queue's consumers respecting prefetch windows.

        Returns the next (consumer, message) pair, or None if the queue is
        empty or every consumer's window is closed. The engine decides *when*
        this delivery lands (service + network time).
        """
        q = self.queues[queue_name]
        if not len(q):
            return None
        ids = q.consumer_ids
        if not ids:
            return None
        for cid in ids:
            ch = self.channels[cid]
            if ch.window_available > 0:
                # rotate round-robin cursor: move cid to the back
                q.remove_consumer(cid)
                q.add_consumer(cid)
                msg = q.pop()
                assert msg is not None
                tag = ch.next_tag
                ch.next_tag += 1
                d = Delivery(msg, cid, queue_name, tag)
                ch.unacked[tag] = d
                q.stats.delivered += 1
                return d
        return None

    def drainable(self, queue_name: str) -> bool:
        q = self.queues[queue_name]
        if not len(q):
            return False
        return any(
            self.channels[c].window_available > 0 for c in q.consumer_ids
        )

    # -- acks --------------------------------------------------------------------
    def ack(self, consumer_id: str, delivery_tag: int, multiple: bool = False) -> int:
        """basic.ack; with multiple=True acks every tag <= delivery_tag
        (batch acknowledgements, paper §5.2). Returns #messages acked."""
        ch = self.channels[consumer_id]
        q = self.queues[ch.queue]
        acked = 0
        if multiple:
            for tag in [t for t in ch.unacked if t <= delivery_tag]:
                del ch.unacked[tag]
                acked += 1
        else:
            if delivery_tag in ch.unacked:
                del ch.unacked[delivery_tag]
                acked = 1
        q.stats.acked += acked
        return acked

    # -- failure handling ----------------------------------------------------------
    def consumer_crash(self, consumer_id: str) -> int:
        """Consumer disconnected without acking: requeue unacked in-order at
        the front (RabbitMQ behavior), deregister. Returns #redelivered."""
        ch = self.channels.pop(consumer_id, None)
        if ch is None:
            return 0
        q = self.queues[ch.queue]
        q.remove_consumer(consumer_id)
        pending = [d.message for d in ch.unacked.values()]
        q.requeue_front(pending)
        return len(pending)

    def node_failure(self, node: int) -> list[str]:
        """Queues homed on a failed node become unavailable; returns their
        names. (Classic queues are not replicated — the paper uses classic
        queues — so failover means re-declaring on a surviving node, which
        the engine layer handles.)"""
        lost = [q.name for q in self.queues.values() if q.home_node == node]
        return lost

    def rehome_queue(self, name: str, new_node: int) -> None:
        self.queues[name].home_node = new_node

    # -- introspection ----------------------------------------------------------
    def vhost_queues(self, vhost: str) -> list[str]:
        """Names of the queues living in ``vhost``."""
        prefix = f"{vhost}/"
        return [n for n in self.queues if n.startswith(prefix)]

    def total_ready(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def total_unacked(self) -> int:
        return sum(len(ch.unacked) for ch in self.channels.values())
