"""Metrics the paper reports (§5.2): aggregate consumer throughput
(messages/second), per-message round-trip time (median + CDF), and the
streaming *overhead* of PRS/MSS relative to the DTS baseline."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.simulator import RunResult


@dataclasses.dataclass
class Summary:
    arch: str
    pattern: str
    workload: str
    n_producers: int
    n_consumers: int
    feasible: bool
    throughput_msgs_s: float = float("nan")
    median_rtt_s: float = float("nan")
    p95_rtt_s: float = float("nan")
    min_rtt_s: float = float("nan")
    goodput_gbps: float = float("nan")
    #: reject-publish / credit-flow-block counts.  Float because multi-
    #: run cells report the *mean* over seeds — flooring small nonzero
    #: means to int silently hid rare-overflow cells (0.33 -> 0).
    rejected: float = 0
    blocked: float = 0
    n_messages: int = 0
    #: how many (feasible) runs a multi-seed mean covers; 1 for a single
    #: run, set by patterns.average_summaries
    n_runs: int = 1
    #: the cell's tenancy (paper §6 deployment study); 1 = single-user
    tenants: int = 1
    #: the engine that actually ran the cell — may differ from the
    #: requested one when ``run_many`` falls back (e.g. ``engine="jax"``
    #: without jax installed runs on "vectorized"); "" when the result
    #: predates the field
    engine: str = ""


def throughput_msgs_per_s(result: RunResult, warmup_frac: float = 0.05) -> float:
    """Aggregate message rate across all consumers, excluding warm-up
    (paper: aggregate message rate from all consumers in each experiment)."""
    ts = np.sort(result.consume_times)
    if ts.size < 2:
        return float("nan")
    k = int(ts.size * warmup_frac)
    ts = ts[k:]
    span = ts[-1] - ts[0]
    if span <= 0:
        return float("nan")
    return float((ts.size - 1) / span)


def summarize(result: RunResult) -> Summary:
    spec = result.spec
    s = Summary(arch=spec.arch, pattern=spec.pattern,
                workload=spec.workload.name,
                n_producers=spec.n_producers, n_consumers=spec.n_consumers,
                feasible=result.feasible,
                rejected=result.rejected_publishes,
                blocked=result.blocked_confirms,
                n_messages=result.n_consumed,
                tenants=spec.tenants,
                engine=spec.params.engine)
    if not result.feasible:
        return s
    thr = throughput_msgs_per_s(result)
    s.throughput_msgs_s = thr
    s.goodput_gbps = thr * spec.workload.message_bits / 1e9
    if result.rtts.size:
        s.median_rtt_s = float(np.median(result.rtts))
        s.p95_rtt_s = float(np.percentile(result.rtts, 95))
        s.min_rtt_s = float(result.rtts.min())
    return s


def rtt_cdf(result: RunResult, n_points: int = 200) -> tuple[np.ndarray, np.ndarray]:
    """Empirical CDF of per-message RTTs (paper Figs 5, 8)."""
    r = np.sort(result.rtts)
    if r.size == 0:
        return np.zeros(0), np.zeros(0)
    q = np.linspace(0.0, 1.0, n_points, endpoint=True)
    x = np.quantile(r, q)
    return x, q


def rtt_fraction_under(result: RunResult, threshold_s: float) -> float:
    """e.g. the paper's "PRS keeps 80% of message RTTs under 0.7 s"."""
    if result.rtts.size == 0:
        return float("nan")
    return float((result.rtts <= threshold_s).mean())


def jain_fairness(values: Sequence[float]) -> float:
    """Jain's fairness index over per-tenant (or per-flow) rates:
    ``(sum x)^2 / (n * sum x^2)``.  1.0 = perfectly even shares, ``1/n``
    = one tenant starves all others.  NaN when no finite positive data."""
    v = np.asarray(values, dtype=float)
    v = v[np.isfinite(v)]
    if v.size == 0 or not np.any(v):
        return float("nan")
    return float(v.sum() ** 2 / (v.size * (v ** 2).sum()))


def tenant_throughputs(result: RunResult) -> np.ndarray:
    """Per-tenant consumed-message rate (msgs/s) over the run's active
    span, from the result's producer-attribution arrays.  Shape
    ``(spec.tenants,)``."""
    T = max(1, result.spec.tenants)
    ts = result.consume_times
    if ts.size < 2 or result.consume_producers.size != ts.size:
        return np.full(T, float("nan"))
    span = float(ts.max() - ts.min())
    if span <= 0:
        return np.full(T, float("nan"))
    tenant = result.tenant_of_producer(result.consume_producers)
    counts = np.bincount(tenant, minlength=T)[:T]
    return counts / span


def tenant_median_rtts(result: RunResult) -> np.ndarray:
    """Per-tenant median round-trip time (s); NaN for tenants with no
    RTT samples.  Shape ``(spec.tenants,)``."""
    T = max(1, result.spec.tenants)
    out = np.full(T, float("nan"))
    if result.rtts.size == 0 or \
            result.rtt_producers.size != result.rtts.size:
        return out
    tenant = result.tenant_of_producer(result.rtt_producers)
    for t in range(T):
        sel = result.rtts[tenant == t]
        if sel.size:
            out[t] = float(np.median(sel))
    return out


def overhead_vs_baseline(value: float, baseline: float,
                         higher_is_better: bool) -> float:
    """Paper §5.2: overhead of an architecture relative to DTS.

    For throughput (higher better): baseline/value; for RTT (lower better):
    value/baseline. 1.0 = parity, 2.5 = "2.5x overhead"."""
    if not np.isfinite(value) or not np.isfinite(baseline) or value <= 0 or baseline <= 0:
        return float("nan")
    return baseline / value if higher_is_better else value / baseline


def overhead_table(summaries: Sequence[Summary],
                   metric: str = "throughput_msgs_s") -> dict[tuple, float]:
    """Map (arch, workload, n_consumers) -> overhead vs the DTS run with the
    same (workload, pattern, n_consumers)."""
    higher_better = metric == "throughput_msgs_s"
    base: dict[tuple, float] = {}
    for s in summaries:
        if s.arch == "dts":
            base[(s.pattern, s.workload, s.n_consumers)] = getattr(s, metric)
    out: dict[tuple, float] = {}
    for s in summaries:
        if s.arch == "dts" or not s.feasible:
            continue
        b = base.get((s.pattern, s.workload, s.n_consumers))
        if b is None:
            continue
        out[(s.arch, s.workload, s.n_consumers)] = overhead_vs_baseline(
            getattr(s, metric), b, higher_better)
    return out
