"""S3M (Secure Scientific Service Mesh) managed-provisioning model
(paper §3.1, §4.5 — the facility service behind the MSS architecture).

S3M fronts MSS: users present project-scoped, time-limited tokens; the
Streaming API validates them against project allocations and policy
rules, provisions the requested streaming service onto DSNs, and
returns an FQDN-based AMQPS URL — web-style access on port 443, the
property that makes MSS the most deployable of the three architectures
(outbound 443 is all a user needs).

What each paper section contributes here
----------------------------------------

* **§3.1 (S3M overview)** — the service-mesh framing: per-project
  allocations (:meth:`S3MService.register_project`), Istio-style policy
  checks on every call (:meth:`S3MService._authorize` — unknown/forged
  token, expiry, permission scope), and the **Compute API** hook
  (:meth:`S3MService.submit_compute`) for dynamic compute orchestration
  — the piece the training integration uses to trigger an HPC job as
  part of a streaming workflow.
* **§4.5 (MSS deployment)** — the REST provisioning call the paper
  issues, mirrored by :meth:`S3MService.provision_cluster`::

      POST /olcf/v1alpha/streaming/rabbitmq/provision_cluster
      {"kind": "general", "name": "rabbitmq",
       "resourceSettings": {"cpus": 12, "ram-gbs": 32, "nodes": 3,
                            "max-msg-size": 536870912}}

  :class:`ResourceSettings` enforces the allocation-policy bounds, and
  the returned :class:`ManagedCluster` carries the user-facing FQDN
  (``rabbitmq-<project>-<n>.apps.olivine.ccs.ornl.gov``) plus the DSN
  placement.
* **§6 (multi-user scalability)** — per-project cluster quotas model
  the managed service's tenancy limits.  The *quantitative* side of the
  multi-user claim lives in :func:`repro.core.patterns.multi_tenant`,
  which sweeps N tenant workflows against one provisioned deployment
  (per-tenant vhost queues mirror S3M's per-project isolation).

Consumed by: :class:`repro.core.architectures.ManagedServiceStreaming`
(an optional provisioned :class:`ManagedCluster` describes what the MSS
hop graph fronts), the steering/serving examples, and
``tests/test_core_system.py`` (auth + quota failure modes).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Callable, Optional

S3M_BASE_URL = "https://s3m.apps.olivine.ccs.ornl.gov/olcf/v1alpha"

_cluster_counter = itertools.count(1)


class S3MError(RuntimeError):
    pass


class S3MAuthError(S3MError):
    pass


@dataclasses.dataclass(frozen=True)
class Token:
    project: str
    permissions: frozenset[str]
    issued_at: float
    ttl_s: float
    secret: str

    def expired(self, now: float) -> bool:
        return now > self.issued_at + self.ttl_s


@dataclasses.dataclass
class ResourceSettings:
    cpus: int = 12
    ram_gbs: int = 32
    nodes: int = 3
    max_msg_size: int = 536_870_912

    def validate(self) -> None:
        if self.nodes < 1 or self.nodes > 8:
            raise S3MError(f"nodes={self.nodes} outside allocation policy [1,8]")
        if self.cpus < 1 or self.cpus > 48:
            raise S3MError(f"cpus={self.cpus} outside allocation policy [1,48]")
        if self.ram_gbs < 1 or self.ram_gbs > 256:
            raise S3MError(f"ram-gbs={self.ram_gbs} outside allocation policy")


@dataclasses.dataclass
class ManagedCluster:
    """What provision_cluster returns: an FQDN users hand to their AMQP
    client plus the provisioned resource footprint."""

    name: str
    kind: str
    project: str
    settings: ResourceSettings
    fqdn: str
    amqps_url: str
    dsn_placement: list[int]


class S3MService:
    """The facility side: Istio-style policy checks + provisioning."""

    def __init__(self, n_dsn: int = 3,
                 clock: Optional[Callable[[], float]] = None) -> None:
        self.n_dsn = n_dsn
        self._clock = clock or (lambda: 0.0)
        self._tokens: dict[str, Token] = {}
        self._allocations: dict[str, dict] = {}     # project -> quota
        self.clusters: dict[str, ManagedCluster] = {}

    # -- auth ----------------------------------------------------------------
    def register_project(self, project: str, max_clusters: int = 2) -> None:
        self._allocations[project] = {
            "max_clusters": max_clusters, "clusters": 0}

    def issue_token(self, project: str,
                    permissions: tuple[str, ...] = ("streaming:provision",),
                    ttl_s: float = 3600.0) -> Token:
        if project not in self._allocations:
            raise S3MAuthError(f"project {project!r} has no allocation")
        secret = hashlib.sha256(
            f"{project}:{self._clock()}:{len(self._tokens)}".encode()
        ).hexdigest()
        tok = Token(project=project, permissions=frozenset(permissions),
                    issued_at=self._clock(), ttl_s=ttl_s, secret=secret)
        self._tokens[secret] = tok
        return tok

    def _authorize(self, token: Token, permission: str) -> None:
        known = self._tokens.get(token.secret)
        if known is None or known != token:
            raise S3MAuthError("unknown or forged token")
        if token.expired(self._clock()):
            raise S3MAuthError("token expired")
        if permission not in token.permissions:
            raise S3MAuthError(f"token lacks permission {permission!r}")

    # -- Streaming API ----------------------------------------------------------
    def provision_cluster(self, token: Token, *, kind: str = "general",
                          name: str = "rabbitmq",
                          settings: Optional[ResourceSettings] = None
                          ) -> ManagedCluster:
        self._authorize(token, "streaming:provision")
        settings = settings or ResourceSettings()
        settings.validate()
        alloc = self._allocations[token.project]
        if alloc["clusters"] >= alloc["max_clusters"]:
            raise S3MError(
                f"project {token.project} at cluster quota "
                f"({alloc['max_clusters']})")
        if settings.nodes > self.n_dsn:
            raise S3MError(
                f"requested {settings.nodes} nodes but only {self.n_dsn} DSNs")
        cid = next(_cluster_counter)
        fqdn = f"{name}-{token.project}-{cid}.apps.olivine.ccs.ornl.gov"
        cluster = ManagedCluster(
            name=name, kind=kind, project=token.project, settings=settings,
            fqdn=fqdn, amqps_url=f"amqps://{fqdn}:443",
            dsn_placement=list(range(settings.nodes)))
        alloc["clusters"] += 1
        self.clusters[fqdn] = cluster
        return cluster

    def deprovision(self, token: Token, fqdn: str) -> None:
        self._authorize(token, "streaming:provision")
        c = self.clusters.pop(fqdn, None)
        if c is not None:
            self._allocations[c.project]["clusters"] -= 1

    # -- Compute API (dynamic compute orchestration, §3.1) -------------------------
    def submit_compute(self, token: Token, *, system: str,
                       job_spec: dict) -> dict:
        self._authorize(token, "compute:submit")
        return {
            "system": system,
            "job_id": f"{system}-{hashlib.sha1(repr(sorted(job_spec.items())).encode()).hexdigest()[:8]}",
            "state": "QUEUED",
            "spec": dict(job_spec),
        }
