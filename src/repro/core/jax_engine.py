"""JAX-native StreamSim engine (``engine="jax"``).

:class:`JaxStreamSim` ports the vectorized engine's hot kernels to
``jax.jit`` device programs and keeps everything else — the batch event
loop, the hop-graph resolution, the publish/deliver choreography — from
:class:`~repro.core.vectorized.VectorizedStreamSim`:

* **prefix-scan FIFO** — ``_fifo_scan`` becomes ``jnp.cumsum`` +
  ``lax.cummax``, ``jax.vmap``-ed over the trailing lane axis, so one
  device program serves every stacked seed-lane of a resource batch;
* **cohort admission** — the per-message arrival-order admission walk
  (byte-cap rejects, credit-threshold crossings, high-water marks)
  becomes one ``lax.scan`` over the cohort with the per-queue drain
  counts precomputed by a vmapped ``searchsorted``;
* **masked depart stores** — the per-lane depart *heaps* are replaced
  by ``(entries, lanes)`` time arrays plus a consumed mask; pops are
  masked reductions (``segment-min``-style ``where``/``argsort``
  kernels) instead of heap mutations;
* **windowed broker pump** — the fast path's strict round-robin
  split/gate arithmetic is one fused gather, and the slow path's
  per-message ``next_delivery`` selection is a ``lax.scan`` over a
  fixed-shape chunk carrying the rotated consumer order and window
  gates.

**Pad-and-mask contract.**  Every kernel call pads its cohort axis to
the next power of two (bounding jit recompiles to ``O(log n)`` distinct
shapes) with *inert* values — ``+inf`` arrival clocks, ``consumed=True``
depart rows, ``valid=False`` scan steps — that can never perturb a real
lane's arithmetic.  ``tests/test_jax_engine.py`` property-tests this
invariance.

**x64 is forced, scoped.**  Time arithmetic must match the float64
NumPy engines (under f32 a 1e-4 s service hold vanishes against a 1e3 s
clock), but this repo's model/kernel stack runs JAX at default x32 —
so every engine kernel runs under the ``jax.experimental.enable_x64``
context instead of flipping the global flag.

The module imports without JAX installed; only constructing
:class:`JaxStreamSim` (or calling a kernel) requires it.
:func:`~repro.core.vectorized.run_many` consults :func:`jax_supported`
and falls back to the vectorized engine per cell when JAX is missing.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import numpy as np

from repro.core.simulator import ENGINES, ExperimentSpec, RunResult
from repro.core.vectorized import VectorizedStreamSim


def jax_available() -> bool:
    """True when ``import jax`` succeeds in this environment."""
    try:
        import jax  # noqa: F401
    except Exception:
        return False
    return True


def jax_supported(spec: ExperimentSpec) -> tuple[bool, str]:
    """Can the JAX engine take this cell?  Returns ``(ok, reason)``.

    The engine inherits the full vectorized event loop, so every cell
    shape the vectorized engine accepts is supported; the only current
    blocker is JAX itself being unavailable.  ``run_many`` records the
    fallback per cell (the result's ``spec.params.engine``)."""
    if not jax_available():
        return False, "jax is not importable in this environment"
    return True, ""


def _pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1) — the pad-and-mask shape
    bucket, bounding distinct jit shapes per call site to O(log n)."""
    return 1 << max(0, int(n) - 1).bit_length()


#: jax arrays flow through the kernels, but jax is only imported
#: lazily inside _kernels — Any keeps the annotations honest without
#: a module-level jax dependency
Array = Any


@functools.lru_cache(maxsize=1)
def _kernels() -> Any:
    """Build (once) the jitted kernel set.  Raises ImportError without
    JAX.  Every kernel is wrapped to run under a scoped x64 context."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64

    def x64(fn: Callable[..., Any]) -> Callable[..., Any]:
        jfn = jax.jit(fn)

        @functools.wraps(fn)
        def call(*args: Any) -> Any:
            with enable_x64():
                return jfn(*args)
        return call

    def fifo1(a: Array, h: Array, carry: Array) -> Array:
        # e_j = max(a_j, e_{j-1}) + h_j in closed form (see _fifo_scan)
        a = jnp.maximum(a, carry)
        H = jnp.cumsum(h)
        return H + lax.cummax(a - (H - h), axis=0)

    class K:
        fifo_scan_1d = x64(fifo1)
        #: the lane axis is a vmap over the solo scan — the identity
        #: test_fifo_scan_lane_axis_matches_per_lane property-tests
        fifo_scan_lanes = x64(jax.vmap(fifo1, in_axes=(1, 1, 0),
                                       out_axes=1))
        #: (cell x lane)-batched scan: one device program serves a whole
        #: campaign round's worth of (C, N, L) FIFO scans — the NumPy
        #: engine must loop C python calls for the same work
        fifo_scan_cells = x64(jax.vmap(jax.vmap(fifo1,
                                                in_axes=(1, 1, 0),
                                                out_axes=1)))

        @x64
        def pop_until(t: Array, used: Array,
                      thresh: Array) -> tuple[Array, Array, Array]:
            """Masked depart-cursor advance: consume every recorded,
            unconsumed depart <= thresh.  Returns (n_popped, last_pop_t,
            used')."""
            ready = (~used) & (t <= thresh)
            return (ready.sum(),
                    jnp.max(jnp.where(ready, t, -jnp.inf)),
                    used | ready)

        @x64
        def pop_k(t: Array, used: Array,
                  k: Array) -> tuple[Array, Array, Array]:
            """Consume the k earliest unconsumed departs (the heap's
            pop-to-target).  Returns (n_popped, last_pop_t, used')."""
            masked = jnp.where(used, jnp.inf, t)
            order = jnp.argsort(masked)
            npop = jnp.minimum(k, (~used).sum())
            sel = jnp.arange(t.shape[0]) < npop
            return (npop,
                    jnp.max(jnp.where(sel, masked[order], -jnp.inf)),
                    used.at[order].set(used[order] | sel))

        @x64
        def next_drain(t: Array, used: Array) -> Array:
            """Masked segment-min: the earliest unconsumed depart
            (+inf when none is recorded)."""
            return jnp.min(jnp.where(used, jnp.inf, t))

        @x64
        def admit_walk(t: Array, valid: Array, dep_sorted: Array,
                       dep0: Array, n_enq0: Array, caps: Array,
                       credits: Array
                       ) -> tuple[Array, Array, Array, Array, Array]:
            """One lane's arrival-order admission walk as a lax.scan.

            ``t``: (M,) member clocks (sorted; +inf pads), ``valid``:
            (M,) real-member mask, ``dep_sorted``: (Q, D) each tracked
            queue's sorted unconsumed depart times (+inf pads),
            ``dep0``/``n_enq0``: (Q,) cursor/enqueue counts at entry,
            ``caps``/``credits``: (Q,) with a huge sentinel for
            untracked limits.  Returns per-member (admitted,
            first_full_queue, blocked_queue) plus per-queue admitted
            high-water marks and the admitted count."""
            Q = dep_sorted.shape[0]
            # total departed at each member's clock, per queue — the
            # lazy heap pops are monotone in t, so a prefix count of
            # the sorted drains reproduces the cursor exactly
            dc = dep0[:, None] + jax.vmap(
                lambda d: jnp.searchsorted(d, t, side="right"))(
                    dep_sorted)                      # (Q, M)

            def step(adm: Array, xs: tuple[Array, Array]
                     ) -> tuple[Array, tuple[Array, Array, Array, Array]]:
                dci, ok = xs
                backlog = n_enq0 + adm - dci         # (Q,) pre-admit
                fullv = backlog >= caps
                first_full = jnp.where(
                    ok, jnp.where(fullv.any(), jnp.argmax(fullv), Q),
                    -1)
                admit = ok & ~fullv.any()
                one = admit.astype(n_enq0.dtype)
                backlog_after = backlog + one
                crossed = backlog_after > credits
                blocked = jnp.where(admit & crossed.any(),
                                    jnp.argmax(crossed), Q)
                return adm + one, (admit, first_full, backlog_after,
                                   blocked)

            n_adm, (admit, first_full, backlog_after, blocked) = \
                lax.scan(step, jnp.int64(0), (dc.T, valid))
            hwm = jnp.max(jnp.where(admit[:, None], backlog_after, -1),
                          axis=0)
            return admit, first_full, blocked, hwm, n_adm

        @x64
        def rr_assign(t: Array, assigned0: Array, offs: Array,
                      ack_win: Array, P: Array) -> tuple[Array, Array]:
            """The pump fast path's round-robin split as one fused
            gather: message r goes to consumer r % k; its depart gates
            on the ack that freed its window slot, read from the
            per-consumer ack window ``ack_win[x] = ack_time[offs[x]:]``
            (NaN pads past the acked prefix are unreachable on this
            path).  ``t`` is (n,) or (n, lanes)."""
            n = t.shape[0]
            k = assigned0.shape[0]
            cons_of = jnp.arange(n) % k
            j_all = assigned0[cons_of] + jnp.arange(n) // k
            idx = jnp.clip(j_all - P - offs[cons_of], 0,
                           ack_win.shape[1] - 1)
            g = ack_win[cons_of, idx]
            m = j_all >= P
            if g.ndim == 2:
                m = m[:, None]
            return j_all, jnp.maximum(t, jnp.where(m, g, -jnp.inf))

        @x64
        def assign_chunk(tv: Array, t0: Array, valid: Array, g0: Array,
                         assigned0: Array, offs: Array, ack_win: Array,
                         P: Array) -> tuple[Array, ...]:
            """The pump slow path (the heap broker's per-message
            ``next_delivery`` in virtual time) as a lax.scan.

            ``tv``: (T, L) member ready clocks (pads invalid), ``t0``:
            (T,) pilot clocks, ``g0``: (k, L) initial window gates
            (NaN = re-opening unknown), ``ack_win``: (k, W, L) each
            consumer's upcoming ack clocks.  Carries the rotated
            round-robin order, per-consumer assignment counts and the
            stopped flag; emits per-step (assigned?, consumer, tag,
            depart)."""
            k = g0.shape[0]
            W = ack_win.shape[1]

            def step(carry: tuple[Array, Array, Array, Array],
                     xs: tuple[Array, Array, Array]
                     ) -> tuple[tuple[Array, Array, Array, Array],
                                tuple[Array, Array, Array, Array]]:
                g, order, nass, stopped = carry
                tvi, ti, ok = xs
                go = g[order]                        # (k, L)
                go0 = go[:, 0]                       # pilot column
                open_m = go0 <= ti                   # NaN -> False
                finite = jnp.isfinite(go0)
                can = ok & ~stopped & (open_m.any() | finite.any())
                pos = jnp.where(open_m.any(), jnp.argmax(open_m),
                                jnp.argmin(jnp.where(finite, go0,
                                                     jnp.inf)))
                x = order[pos]
                depart = jnp.maximum(tvi, go[pos])
                j = assigned0[x] + nass[x]
                idx = jnp.clip(j + 1 - P - offs[x], 0, W - 1)
                gnew = jnp.where(j + 1 >= P, ack_win[x, idx], -jnp.inf)
                g2 = jnp.where(can, g.at[x].set(gnew), g)
                rot = jnp.where(jnp.arange(k) < pos, order,
                                jnp.roll(order, -1)).at[k - 1].set(x)
                order2 = jnp.where(can, rot, order)
                nass2 = jnp.where(can, nass.at[x].add(1), nass)
                return ((g2, order2, nass2, stopped | (ok & ~can)),
                        (can, x, j, depart))

            init = (g0, jnp.arange(k), jnp.zeros(k, jnp.int64), False)
            (g, order, nass, _), outs = lax.scan(step, init,
                                                 (tv, t0, valid))
            return (order, nass) + outs

    return K


def _jax_fifo_scan(a: np.ndarray, h: np.ndarray, carry: Any) -> np.ndarray:
    """Drop-in ``_fifo_scan`` port: pad the cohort axis to a power of
    two with inert ``+inf`` arrivals / zero holds, run the jitted scan
    (lane-vmapped when a lane axis is present), slice the pads off."""
    K = _kernels()
    a = np.asarray(a, dtype=np.float64)
    h = np.broadcast_to(np.asarray(h, dtype=np.float64), a.shape)
    n = a.shape[0]
    m = _pow2(n)
    if a.ndim == 1:
        ap = np.full(m, np.inf)
        hp = np.zeros(m)
        ap[:n], hp[:n] = a, h
        out = K.fifo_scan_1d(ap, hp, float(np.asarray(carry)))
        return np.asarray(out)[:n]
    L = a.shape[1]
    ap = np.full((m, L), np.inf)
    hp = np.zeros((m, L))
    ap[:n], hp[:n] = a, h
    c = np.broadcast_to(np.asarray(carry, dtype=np.float64), (L,))
    return np.asarray(K.fifo_scan_lanes(ap, hp, c))[:n]


#: sentinel for "no cap/credit limit" inside integer kernels (far above
#: any reachable backlog, far below int64 overflow under += 1)
_NO_LIMIT = np.int64(2) ** 62


class JaxStreamSim(VectorizedStreamSim):
    """The vectorized engine with its hot kernels on JAX devices.

    Same constructor/run/stacking contract as the base class; only the
    kernel layer differs, so parity vs the heap engine inherits the
    vectorized engine's tolerance bands (the arithmetic is the same
    float64 recurrences, re-associated at worst at the 1e-16 level).
    """

    #: device batches amortize better over wide lane axes, so jax
    #: groups stack 4x more seed-lanes per run than the NumPy engine
    STACK_MAX_LANES = 64

    _scan_impl = staticmethod(_jax_fifo_scan)

    def __init__(self, *args: Any, **kwargs: Any) -> None:
        if not jax_available():
            raise ImportError(
                "engine='jax' requires jax; install jax or use "
                "engine='vectorized' (run_many falls back automatically)")
        self._K = _kernels()
        super().__init__(*args, **kwargs)

    # -- whole-run device program (opt-in; repro.core.jax_device_loop) -----
    def _use_device_loop(self) -> bool:
        """True when ``params.jax_device_loop`` requests the whole-run
        device program *and* this cell is wave-formulated.  Off by
        default: the device loop trades the cohort engines' event
        ordering for one fused ``lax.scan``, so it matches them at the
        ``device_loop.*`` parity bands instead of bit-for-bit."""
        if not self.p.jax_device_loop:
            return False
        from repro.core import jax_device_loop
        ok, _why = jax_device_loop._device_loop_ok(self)
        return ok

    def run(self) -> RunResult:
        if self._use_device_loop():
            from repro.core import jax_device_loop
            return jax_device_loop.run_wave_results(self)[0]
        return super().run()

    def run_stacked(self) -> list[RunResult]:
        if self._use_device_loop():
            from repro.core import jax_device_loop
            return jax_device_loop.run_wave_results(self)
        return super().run_stacked()

    # -- masked depart store (replaces the per-lane heaps) -----------------
    def _queue_state(self, qkey: tuple, consumers: list[int],
                     size: int, *,
                     credit: Optional[int] = None,
                     cap_msgs: Optional[int] = None) -> dict:
        fresh = qkey not in self._queues
        q = super()._queue_state(qkey, consumers, size, credit=credit,
                                 cap_msgs=cap_msgs)
        if fresh and q["track"]:
            L = self._lanes
            # masked store: one row per recorded release (all lanes),
            # consumed flags per (entry, lane); padded rows are born
            # consumed with +inf clocks — inert under every kernel
            q["depart_heap"] = None
            q["dep_t"] = np.empty((0, L))
            q["dep_used"] = np.empty((0, L), dtype=bool)
            q["dep_n"] = 0
        return q

    def _dep_col(self, q: dict, lane: int) -> tuple[np.ndarray,
                                                    np.ndarray]:
        """One lane's depart column, padded to the pow2 shape bucket
        (+inf / consumed pads)."""
        n = q["dep_n"]
        m = _pow2(n)
        t = np.full(m, np.inf)
        u = np.ones(m, dtype=bool)
        t[:n] = q["dep_t"][:n, lane]
        u[:n] = q["dep_used"][:n, lane]
        return t, u

    def _record_departs(self, q: dict, departs: np.ndarray) -> None:
        if not q["track"]:
            return
        cols = np.asarray(departs, dtype=np.float64).reshape(
            departs.shape[0], self._lanes)
        n0, m = q["dep_n"], cols.shape[0]
        if n0 + m > q["dep_t"].shape[0]:
            cap = max(n0 + m, 2 * q["dep_t"].shape[0], 64)
            t = np.full((cap, self._lanes), np.inf)
            u = np.ones((cap, self._lanes), dtype=bool)
            t[:n0] = q["dep_t"][:n0]
            u[:n0] = q["dep_used"][:n0]
            q["dep_t"], q["dep_used"] = t, u
        q["dep_t"][n0:n0 + m] = cols
        q["dep_used"][n0:n0 + m] = False
        q["dep_n"] = n0 + m
        q["released"] += m
        if q["deferred"]:
            self._try_resume(q)

    def _pop_lane(self, q: dict, lane: int, t: float) -> None:
        n = q["dep_n"]
        if n == 0:
            return
        col, used = self._dep_col(q, lane)
        cnt, last, used2 = self._K.pop_until(col, used, float(t))
        cnt = int(cnt)
        if cnt:
            q["dep_used"][:n, lane] = np.asarray(used2)[:n]
            q["departed"][lane] += cnt
            q["last_pop_t"][lane] = float(last)

    def _pop_to_target(self, q: dict, lane: int, target: int) -> None:
        need = int(target) - int(q["departed"][lane])
        n = q["dep_n"]
        if need <= 0 or n == 0:
            return
        col, used = self._dep_col(q, lane)
        cnt, last, used2 = self._K.pop_k(col, used, need)
        cnt = int(cnt)
        if cnt:
            q["dep_used"][:n, lane] = np.asarray(used2)[:n]
            q["departed"][lane] += cnt
            q["last_pop_t"][lane] = float(last)

    def _next_drain(self, q: dict, lane: int) -> Optional[float]:
        if q["dep_n"] == 0:
            return None
        nd = float(self._K.next_drain(*self._dep_col(q, lane)))
        return nd if np.isfinite(nd) else None

    # -- cohort admission as one device scan -------------------------------
    def _admit_walk(self, tracked: list, lane: int, ks: np.ndarray,
                    T: np.ndarray) -> tuple[np.ndarray, list]:
        m = ks.size
        if m == 0:
            return np.zeros(0, dtype=int), []
        Q = len(tracked)
        tl = np.asarray(T[ks, lane], dtype=np.float64)
        deps = []
        for q in tracked:
            n = q["dep_n"]
            col = q["dep_t"][:n, lane]
            deps.append(np.sort(col[~q["dep_used"][:n, lane]]))
        D = _pow2(max((d.size for d in deps), default=1))
        dep_pad = np.full((Q, D), np.inf)
        for qi, d in enumerate(deps):
            dep_pad[qi, :d.size] = d
        caps = np.array([q["cap"] if q["cap"] is not None else _NO_LIMIT
                         for q in tracked], dtype=np.int64)
        credits = np.array(
            [q["credit"] if q["credit"] is not None else _NO_LIMIT
             for q in tracked], dtype=np.int64)
        n_enq0 = np.array([q["n_enq"][lane] for q in tracked],
                          dtype=np.int64)
        dep0 = np.array([q["departed"][lane] for q in tracked],
                        dtype=np.int64)
        M = _pow2(m)
        t_pad = np.full(M, np.inf)
        t_pad[:m] = tl
        valid = np.zeros(M, dtype=bool)
        valid[:m] = True
        admit, first_full, blocked_q, hwm, n_adm = self._K.admit_walk(
            t_pad, valid, dep_pad, dep0, n_enq0, caps, credits)
        admit = np.asarray(admit)[:m]
        first_full = np.asarray(first_full)[:m]
        blocked_q = np.asarray(blocked_q)[:m]
        n_adm = int(n_adm)
        for qi, q in enumerate(tracked):
            # sync the store to the walk: queue qi was popped by every
            # member the per-queue loop reached (first_full >= qi)
            reach = first_full >= qi
            if reach.any():
                self._pop_lane(q, lane, float(tl[reach].max()))
            q["n_enq"][lane] += n_adm
            if n_adm:
                q["hwm"][lane] = max(q["hwm"][lane], int(hwm[qi]))
        blocked = [(int(ks[i]), tracked[int(blocked_q[i])])
                   for i in np.nonzero(admit & (blocked_q < Q))[0]]
        return ks[admit], blocked

    # -- windowed broker pump kernels --------------------------------------
    def _rr_assign(self, ids: list, t_sl: np.ndarray, P: int
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        n_rem = t_sl.shape[0]
        k = len(ids)
        cnts = [(n_rem - r + k - 1) // k for r in range(k)]
        chans = [self._chan(c) for c in ids]
        for ch, cnt in zip(chans, cnts):
            self._chan_grow(ch, cnt)
        assigned0 = np.array([ch["assigned"] for ch in chans],
                             dtype=np.int64)
        offs = np.maximum(assigned0 - P, 0)
        W = _pow2(max(cnts) + 1)
        lane_tail = t_sl.shape[1:]
        ack_win = np.full((k, W) + lane_tail, np.nan)
        for x, ch in enumerate(chans):
            seglen = min(W, ch["ack_time"].shape[0] - int(offs[x]))
            if seglen > 0:
                ack_win[x, :seglen] = \
                    ch["ack_time"][int(offs[x]):int(offs[x]) + seglen]
        M = _pow2(n_rem)
        t_pad = np.full((M,) + lane_tail, np.inf)
        t_pad[:n_rem] = t_sl
        j_all, depart = self._K.rr_assign(t_pad, assigned0, offs,
                                          ack_win, int(P))
        for ch, cnt in zip(chans, cnts):
            ch["assigned"] += cnt
        cons = np.array(ids)[np.arange(n_rem) % k]
        return (cons, np.asarray(j_all)[:n_rem],
                np.asarray(depart)[:n_rem])

    def _assign_chunk(self, seg: dict, ids: list, P: int
                      ) -> tuple[list, list]:
        chunk = max(1, self.p.ack_batch)
        take = min(chunk, seg["idx"].size - seg["pos"])
        if take <= 0:
            return [], list(ids)
        k = len(ids)
        L = self._lanes
        solo = L == 1
        chans = [self._chan(c) for c in ids]
        for ch in chans:
            self._chan_grow(ch, take)
        assigned0 = np.array([ch["assigned"] for ch in chans],
                             dtype=np.int64)
        offs = np.maximum(assigned0 + 1 - P, 0)
        W = _pow2(take + 1)
        ack_win = np.full((k, W, L), np.nan)
        g0 = np.empty((k, L))
        for x, ch in enumerate(chans):
            at = ch["ack_time"].reshape(ch["ack_time"].shape[0], L)
            j = int(assigned0[x])
            g0[x] = -np.inf if j < P else at[j - P]
            seglen = min(W, at.shape[0] - int(offs[x]))
            if seglen > 0:
                ack_win[x, :seglen] = at[int(offs[x]):int(offs[x])
                                         + seglen]
        T = _pow2(take)
        sl = slice(seg["pos"], seg["pos"] + take)
        tv = np.full((T, L), np.inf)
        tv[:take] = seg["t"][sl].reshape(take, L)
        t0 = np.full(T, np.inf)
        t0[:take] = np.asarray(_lane0_col(seg["t"][sl]))
        valid = np.zeros(T, dtype=bool)
        valid[:take] = True
        order, nass, can, xs, js, departs = self._K.assign_chunk(
            tv, t0, valid, g0, assigned0, offs, ack_win, int(P))
        can = np.asarray(can)
        xs, js = np.asarray(xs), np.asarray(js)
        departs = np.asarray(departs)
        n_t = int(can.sum())          # stop/pad flags form a suffix
        rel = []
        for i in range(n_t):
            x = int(xs[i])
            d = departs[i]
            rel.append((seg["idx"][seg["pos"]], ids[x], int(js[i]),
                        float(d[0]) if solo else d.copy()))
            seg["pos"] += 1
        for x, ch in enumerate(chans):
            ch["assigned"] += int(nass[x])
        return rel, [ids[int(x)] for x in np.asarray(order)]


def _lane0_col(a: np.ndarray) -> np.ndarray:
    return a if a.ndim == 1 else a[:, 0]


ENGINES["jax"] = JaxStreamSim
