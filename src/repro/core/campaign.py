"""Batched sweep campaigns: whole experiment grids through the engine.

``patterns.sweep`` runs every (pattern x architecture x workload x
consumer-count x seed) cell as a serial Python loop over the engine —
so the very sweeps the vectorized engine made fast are bottlenecked by
cell-at-a-time orchestration.  This module executes whole grids as
*batched work*:

* a declarative :class:`CampaignSpec` names the grid axes plus optional
  per-cell :class:`~repro.core.simulator.SimParams` overrides;
* the runner groups structurally-identical cells — same hop graph,
  different seeds — and pushes each group through
  :func:`repro.core.vectorized.run_many`, which stacks the seeds as
  cohort lanes of **one** batched engine run (a 3-seed cell costs barely
  more than one run; see ``docs/engines.md``);
* heterogeneous groups fan out across a small process pool
  (``workers``), largest first;
* every finished group is written through a fingerprinted cache (a
  ``benchmarks.common.Cache``-compatible object: a ``data`` dict plus
  ``save()``), so an interrupted campaign resumes where it stopped and
  an engine/params change can never serve stale numbers;
* overflow-regime cells (explicit ``queue_max_bytes`` caps,
  credit-flow-reachable publish surpluses) batch like everything else —
  flow control is lane-resolved in the stacked engine, so every seed
  lane carries its own reject/block accounting; only heap-engine cells
  fall back to per-cell execution.

Quick start::

    from repro.core.campaign import CampaignSpec, run_campaign

    spec = CampaignSpec(name="fig6-mini", patterns=("feedback",),
                        architectures=("dts", "mss"), workloads=("dstream",),
                        consumers=(4, 8), n_runs=3, total_messages=2048)
    res = run_campaign(spec, workers=0)      # 12 cells, batched
    for s in res.averaged:
        print(s.arch, s.n_consumers, round(s.throughput_msgs_s, 1))
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Any, Callable, Optional, Sequence

from repro.core.metrics import Summary, summarize
from repro.core.patterns import GATHER_REPLY_FACTOR, average_summaries
from repro.core.simulator import ExperimentSpec, SimParams
from repro.core.workloads import get_workload

#: the single definition of the cache-key version shared with the bench
#: cache (benchmarks/common.py imports it), so one
#: results/bench_cache.json holds both figure-bench and campaign cells
#: and a version bump invalidates them together
CACHE_KEY_VERSION = "v2"


def params_fingerprint(params: SimParams) -> str:
    """Short stable hash of a fully-resolved :class:`SimParams` — the
    one fingerprint construction behind both ``benchmarks.common``
    cache keys and campaign :func:`cell_key`\\ s, so any change to
    simulator defaults (not just explicit overrides) invalidates both."""
    blob = repr(sorted(params.__dict__.items()))
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


def resolved_engine(spec: ExperimentSpec) -> str:
    """The engine a spec will *actually* run on, after the ``run_many``
    fallback: a requested ``"jax"`` cell that ``jax_supported`` rejects
    executes on the vectorized engine, and must be cached as such.

    Every cache key MUST be built from this, never from the requested
    ``spec.params.engine`` — keying a fallback cell under ``jax`` both
    poisons the jax namespace (a later run in a jax-capable environment
    is served vectorized numbers) and forks it from the identical
    vectorized cell (same computation measured twice)."""
    eng = spec.params.engine
    if eng == "jax":
        from repro.core import jax_engine
        ok, _why = jax_engine.jax_supported(spec)
        if not ok:
            return "vectorized"
    return eng


# ---------------------------------------------------------------------------
# Declarative campaign grids
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One fully-resolved campaign cell (a single seeded engine run)."""

    pattern: str
    arch: str
    workload: str
    n_consumers: int
    total_messages: int
    seed: int
    tenants: int = 1
    tenant_isolation: str = "shared"
    #: sorted (name, value) SimParams overrides, seed excluded
    overrides: tuple = ()

    def experiment(self) -> ExperimentSpec:
        n_producers = (1 if self.pattern.startswith("broadcast")
                       else self.n_consumers)
        ov = dict(self.overrides)
        if (self.pattern == "broadcast_gather"
                and "reply_factor" not in ov):
            ov["reply_factor"] = GATHER_REPLY_FACTOR
        return ExperimentSpec(
            pattern=self.pattern, workload=get_workload(self.workload),
            arch=self.arch, n_producers=n_producers,
            n_consumers=self.n_consumers,
            total_messages=self.total_messages,
            params=SimParams(seed=self.seed, **ov),
            tenants=self.tenants, tenant_isolation=self.tenant_isolation)

    def group_key(self) -> tuple:
        """Cells equal under this key differ only by seed — the runner
        stacks them through one batched run."""
        return (self.pattern, self.arch, self.workload, self.n_consumers,
                self.total_messages, self.tenants, self.tenant_isolation,
                self.overrides)


@dataclasses.dataclass
class CampaignSpec:
    """A declarative sweep grid: the cross product of the axes below,
    repeated over ``n_runs`` seeds per cell.

    ``cell_params`` applies targeted SimParams overrides: a list of
    ``(match, overrides)`` pairs where ``match`` is a dict over the axis
    names (``pattern``/``arch``/``workload``/``n_consumers``/
    ``tenants``); every cell whose axes match all entries gets the
    overrides (later pairs win on conflicts).  ``params`` applies to
    every cell."""

    name: str
    patterns: Sequence[str] = ("work_sharing",)
    architectures: Sequence[str] = ("dts",)
    workloads: Sequence[str] = ("dstream",)
    consumers: Sequence[int] = (8,)
    n_runs: int = 3
    seed: int = 0
    total_messages: int = 8192
    tenants: Sequence[int] = (1,)
    tenant_isolation: str = "shared"
    params: dict = dataclasses.field(default_factory=dict)
    cell_params: list = dataclasses.field(default_factory=list)

    #: axis names a cell_params match may constrain
    AXES = ("pattern", "arch", "workload", "n_consumers", "tenants")

    def __post_init__(self) -> None:
        self._validate_engines()

    def _validate_engines(self) -> None:
        """Resolve every engine name the grid can select — ``params`` and
        each ``cell_params`` override — at construction, so a typo like
        ``engine="jaxx"`` fails here with the offending override named,
        not as a bare SimParams error from deep inside the grid walk."""
        from repro.core.simulator import get_engine
        sources = [("params", self.params)]
        sources += [(f"cell_params[{i}] (match={dict(m)!r})", o)
                    for i, (m, o) in enumerate(self.cell_params)]
        for where, ov in sources:
            eng = ov.get("engine") if isinstance(ov, dict) else None
            if eng is None:
                continue
            try:
                get_engine(eng)
            except ValueError as err:
                raise ValueError(
                    f"campaign {self.name!r}: {where} sets an invalid "
                    f"engine: {err}") from None

    def _validate_tenant_grid(self) -> None:
        """A tenant sweep crosses *every* (pattern, arch, consumers)
        combination — reject the cross products that cannot mean
        anything before any cell runs, with the offending combo named
        (an :class:`ExperimentSpec` error deep inside a 100-cell grid
        is much harder to act on)."""
        if max(self.tenants, default=1) <= 1:
            return
        bad_pat = [p for p in self.patterns
                   if p not in ("work_sharing", "feedback")]
        if bad_pat:
            raise ValueError(
                f"campaign {self.name!r} sweeps tenants="
                f"{tuple(self.tenants)} but includes pattern(s) "
                f"{bad_pat}: multi-tenant cells support only "
                f"work_sharing/feedback.  Split the broadcast patterns "
                f"into their own campaign, or drop tenants > 1.")
        bad = [(nc, t) for nc in self.consumers
               for t in self.tenants if t > 1 and nc % t]
        if bad:
            raise ValueError(
                f"campaign {self.name!r} crosses consumers x tenants "
                f"into ambiguous cells {bad}: each tenant count must "
                f"evenly divide each consumer count (producers/"
                f"consumers partition into contiguous tenant blocks).  "
                f"Align the axes (e.g. powers of two), or use separate "
                f"campaigns per tenant count.")

    def cells(self) -> list[CellSpec]:
        self._validate_tenant_grid()
        for match, _ in self.cell_params:
            unknown = set(match) - set(self.AXES)
            if unknown:
                raise ValueError(
                    f"cell_params match uses unknown axis name(s) "
                    f"{sorted(unknown)}; known axes: {list(self.AXES)}")
        out = []
        for pat in self.patterns:
            for arch in self.architectures:
                for wl in self.workloads:
                    for nc in self.consumers:
                        for t in self.tenants:
                            ov = dict(self.params)
                            axes = {"pattern": pat, "arch": arch,
                                    "workload": wl, "n_consumers": nc,
                                    "tenants": t}
                            for match, extra in self.cell_params:
                                if all(axes.get(k) == v
                                       for k, v in match.items()):
                                    ov.update(extra)
                            for r in range(self.n_runs):
                                out.append(CellSpec(
                                    pattern=pat, arch=arch, workload=wl,
                                    n_consumers=nc,
                                    total_messages=self.total_messages,
                                    seed=self.seed + 1000 * r,
                                    tenants=t,
                                    tenant_isolation=self.tenant_isolation,
                                    overrides=tuple(sorted(ov.items()))))
        return out

    # -- (de)serialization for the benchmarks/run.py campaign CLI ----------
    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["cell_params"] = [list(p) for p in self.cell_params]
        return json.dumps(d, indent=1)

    @staticmethod
    def from_json(blob: str) -> "CampaignSpec":
        d = json.loads(blob)
        d["cell_params"] = [(dict(m), dict(o))
                            for m, o in d.get("cell_params", [])]
        return CampaignSpec(**d)


def cell_key(cell: CellSpec) -> str:
    """Versioned, engine+params-fingerprinted cache key for one cell —
    same contract as ``benchmarks.common.cache_key`` (a simulator-default
    change or engine switch can never serve a stale campaign cell).
    Fingerprints the *fully-resolved* experiment params, including
    pattern-implied defaults like the broadcast-gather reply factor.

    Keys on the :func:`resolved_engine`, not the requested one: a jax
    cell that falls back to vectorized shares its key (tag *and*
    fingerprint) with the identical genuine-vectorized cell — it ran
    the same computation — and never occupies the jax namespace."""
    exp = cell.experiment()
    p = exp.params
    eng = resolved_engine(exp)
    if eng != p.engine:
        p = dataclasses.replace(p, engine=eng)
    fp = params_fingerprint(p)
    return (f"{CACHE_KEY_VERSION}|engine={eng}|p={fp}|campaign|"
            f"{cell.pattern}|{cell.arch}|{cell.workload}|"
            f"c{cell.n_consumers}|m{cell.total_messages}|"
            f"t{cell.tenants}.{cell.tenant_isolation}|s{cell.seed}")


# ---------------------------------------------------------------------------
# The batched runner
# ---------------------------------------------------------------------------


def _run_group(cells: Sequence[CellSpec]) -> list[dict]:
    """Execute one structurally-identical group (worker-side): the seeds
    stack into one batched engine run via ``run_many``."""
    from repro.core.vectorized import run_many
    results = run_many([c.experiment() for c in cells])
    return [dataclasses.asdict(summarize(r)) for r in results]


@dataclasses.dataclass
class CampaignResult:
    spec: CampaignSpec
    cells: list            # CellSpec per executed/cached cell
    summaries: list        # Summary per cell (same order)
    averaged: list         # Summary per unique cell group (seed-averaged)
    wall_s: float
    n_cached: int          # cells served from the cache
    #: cells that requested one engine but ran another (the ``run_many``
    #: jax→vectorized fallback); surfaced in the JSON so a "jax
    #: campaign" whose numbers are actually vectorized is never silent
    n_fallback: int = 0

    def to_json(self) -> str:
        return json.dumps({
            "name": self.spec.name,
            "spec": json.loads(self.spec.to_json()),
            "wall_s": self.wall_s,
            "n_cells": len(self.cells),
            "n_cached": self.n_cached,
            "n_fallback": self.n_fallback,
            "cells": [{"key": cell_key(c),
                       "summary": dataclasses.asdict(s)}
                      for c, s in zip(self.cells, self.summaries)],
            "averaged": [dataclasses.asdict(s) for s in self.averaged],
        }, indent=1)


def run_campaign(spec: CampaignSpec, *, cache: Optional[Any] = None,
                 workers: Optional[int] = None,
                 progress: Optional[Callable[[str], None]] = None
                 ) -> CampaignResult:
    """Execute a campaign grid as batched work.

    ``cache`` is a ``benchmarks.common.Cache``-compatible object (a
    ``data`` dict of ``key -> dict`` plus a ``save()`` method): each
    freshly-computed group is written through and saved as it
    completes, so an interrupted campaign resumes.  The cache unit is
    the *group*: hits are only served when every cell of a group is
    present, otherwise the whole group re-runs (and overwrites any
    partial entries) — a group's seeds always stack behind the same
    pilot lane, so a cached cell's numbers never depend on which cells
    happened to be computed before an interruption.  ``workers`` bounds
    the process fan-out across cell groups (``0``/``1`` = in-process;
    ``None`` = one per CPU, capped by the group count).  Seeds within a
    group never fan out — they run stacked in one engine loop, which is
    where the batching win comes from."""
    # streamlint: disable=SL403 -- wall_s is campaign telemetry (how long
    # the run took), reported alongside results, never fed into them
    t0 = time.time()
    cells = spec.cells()
    for c in cells:
        c.experiment()   # validate the whole grid before burning time
    say = progress or (lambda msg: None)
    summaries: dict[int, Summary] = {}
    n_cached = 0
    by_group: dict[tuple, list[int]] = {}
    for i, c in enumerate(cells):
        by_group.setdefault(c.group_key(), []).append(i)
    fields = {f.name for f in dataclasses.fields(Summary)}

    def rehydrate(h: object) -> Optional[Summary]:
        # a cached dict from another Summary schema generation (field
        # added/removed/renamed) is a cache miss, not a crash or a
        # silently-defaulted mixture
        if not isinstance(h, dict) or set(h) != fields:
            return None
        return Summary(**h)

    todo: dict[tuple, list[int]] = {}
    for gkey, idxs in by_group.items():
        hits = ([rehydrate(cache.data.get(cell_key(cells[i])))
                 for i in idxs] if cache is not None else [None])
        if all(h is not None for h in hits):
            for i, h in zip(idxs, hits):
                summaries[i] = h
            n_cached += len(idxs)
        else:
            todo[gkey] = idxs
    say(f"{len(cells)} cells: {n_cached} cached, "
        f"{len(todo)} group(s) to run")

    # largest groups first: better packing across workers
    groups = sorted(todo.values(),
                    key=lambda idxs: -cells[idxs[0]].total_messages
                    * len(idxs) * cells[idxs[0]].n_consumers)

    def record(idxs: list[int], dicts: list[dict]) -> None:
        for i, d in zip(idxs, dicts):
            summaries[i] = Summary(**d)
            if cache is not None:
                cache.data[cell_key(cells[i])] = d
        if cache is not None:
            cache.save()         # one write per finished group

    if workers is None:
        workers = min(len(groups), os.cpu_count() or 1)
    if workers <= 1 or len(groups) <= 1:
        for idxs in groups:
            record(idxs, _run_group([cells[i] for i in idxs]))
            say(f"group {cells[idxs[0]].group_key()[:4]} done")
    else:
        from concurrent.futures import ProcessPoolExecutor, as_completed
        with ProcessPoolExecutor(max_workers=workers) as ex:
            futs = {ex.submit(_run_group, [cells[i] for i in idxs]): idxs
                    for idxs in groups}
            for fut in as_completed(futs):
                record(futs[fut], fut.result())
                say(f"group {cells[futs[fut][0]].group_key()[:4]} done")

    ordered = [summaries[i] for i in range(len(cells))]
    n_fallback = sum(
        1 for c, s in zip(cells, ordered)
        if s.engine and s.engine != c.experiment().params.engine)
    if n_fallback:
        import warnings
        warnings.warn(
            f"campaign {spec.name!r}: {n_fallback}/{len(cells)} cell(s) "
            f"fell back from the requested engine (see Summary.engine); "
            f"reported numbers are NOT from the engine you asked for",
            RuntimeWarning, stacklevel=2)
    grouped: dict[tuple, list[Summary]] = {}
    for c, s in zip(cells, ordered):
        grouped.setdefault(c.group_key(), []).append(s)
    averaged = [average_summaries(ss) for ss in grouped.values()]
    return CampaignResult(spec=spec, cells=cells, summaries=ordered,
                          # streamlint: disable=SL403 -- telemetry (see t0)
                          averaged=averaged, wall_s=time.time() - t0,
                          n_cached=n_cached, n_fallback=n_fallback)
