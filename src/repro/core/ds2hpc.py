"""DS2HPC / ACE infrastructure model (paper §3.1, §4.1 — the testbed
every simulated architecture is deployed onto).

What each paper section contributes here
----------------------------------------

* **§3.1 (Data Streaming to HPC, DS2HPC)** — the notion of dedicated
  *Data Streaming Nodes* (DSNs) at the facility edge, bridging external
  producers and internal HPC consumers.  :class:`ClusterInventory` is
  that testbed: how many DSNs and client nodes exist, and the effective
  link rates between them.  It is the single source of truth the
  architecture models (:mod:`repro.core.architectures`) turn into
  shared contention resources (``dsn_in:*``, ``plink:*``, ...), so a
  what-if like the §6 100 Gbps projection is one call
  (:meth:`ClusterInventory.highspeed`).
* **§4.1 (deployment environment)** — the concrete hardware:
  :data:`DSN_SPEC` (Olivine OpenShift nodes: 2x 32-core 2.70 GHz AMD
  EPYC 9334, 512 GiB RAM, 100 Gbps-capable NICs *currently limited to
  ~1 Gbps effective* — the SRIOV/RHCOS issue §6 revisits) and
  :data:`ANDES_SPEC` (client nodes: 2x 16-core 3.0 GHz EPYC 7302,
  256 GiB; 16 producer + 16 consumer nodes + 1 coordinator, §5.2).
* **§4.3 (DTS mechanics)** — NodePort allocation
  (:class:`NodePortService`, range 30000-32767; AMQP 30672 / AMQPS
  30671) and the Bitnami Helm release the paper installs
  (:class:`RabbitMQRelease`: 3 replicas with pod anti-affinity across
  DSNs, 12 CPUs + 32 GiB per pod, TLS, 512 MiB max message).

Consumed by: ``architectures.py`` (resource construction + node
placement maps), both StreamSim engines (producer/consumer -> node
mapping), ``benchmarks/bench_highspeed_projection.py`` and the engine
scaling benches (the upgraded-fabric what-if).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.core.workloads import GBIT, MIB


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    name: str
    cores: int
    ghz: float
    ram_gib: int
    nic_gbps: float          # effective, not nameplate
    nic_capable_gbps: float


DSN_SPEC = NodeSpec("dsn", cores=64, ghz=2.70, ram_gib=512,
                    nic_gbps=1.0, nic_capable_gbps=100.0)
ANDES_SPEC = NodeSpec("andes", cores=32, ghz=3.0, ram_gib=256,
                      nic_gbps=1.0, nic_capable_gbps=1.0)

NODEPORT_RANGE = (30000, 32767)
AMQP_NODEPORT = 30672
AMQPS_NODEPORT = 30671


@dataclasses.dataclass
class ClusterInventory:
    """The emulated testbed: 3 DSNs (brokers/proxies) + Andes clients."""

    n_dsn: int = 3
    n_producer_nodes: int = 16
    n_consumer_nodes: int = 16
    dsn: NodeSpec = DSN_SPEC
    client: NodeSpec = ANDES_SPEC
    # §6: effective link between Andes and the DSNs
    client_link_gbps: float = 1.0
    dsn_link_gbps: float = 1.0

    def client_link_Bps(self) -> float:
        return self.client_link_gbps * GBIT / 8.0

    def dsn_link_Bps(self) -> float:
        return self.dsn_link_gbps * GBIT / 8.0

    def producer_node_of(self, producer_idx: int) -> int:
        return producer_idx % self.n_producer_nodes

    def consumer_node_of(self, consumer_idx: int) -> int:
        return consumer_idx % self.n_consumer_nodes

    def highspeed(self) -> "ClusterInventory":
        """Paper §6 projection: DSN 100 Gbps NICs fully usable."""
        return dataclasses.replace(
            self, dsn_link_gbps=100.0, client_link_gbps=10.0
        )


# --------------------------------------------------------------------------
# Deployment descriptors (Helm-chart / NodePort mechanics of §4.3)
# --------------------------------------------------------------------------

_nodeport_counter = itertools.count(30600)


@dataclasses.dataclass
class NodePortService:
    name: str
    node: int
    port: int

    @staticmethod
    def allocate(name: str, node: int, port: Optional[int] = None) -> "NodePortService":
        p = next(_nodeport_counter) if port is None else port
        lo, hi = NODEPORT_RANGE
        if not (lo <= p <= hi):
            raise ValueError(f"NodePort {p} outside {NODEPORT_RANGE}")
        return NodePortService(name, node, p)


@dataclasses.dataclass
class RabbitMQRelease:
    """Mirror of the Bitnami Helm values the paper deploys (§4.3):
    3 replicas, pod anti-affinity (one server per DSN), 12 CPUs + 32 GiB per
    pod, 15 GiB persistent storage, TLS with auto-generated certs, NodePorts
    30672 (AMQP) / 30671 (AMQPS)."""

    namespace: str = "abc123"
    replicas: int = 3
    cpus_per_pod: int = 12
    ram_gib_per_pod: int = 32
    storage_gib_per_pod: int = 15
    tls: bool = True
    amqp_nodeport: int = AMQP_NODEPORT
    amqps_nodeport: int = AMQPS_NODEPORT
    max_message_bytes: int = 512 * MIB   # 536870912, from the S3M example

    def pod_placement(self, inventory: ClusterInventory) -> list[int]:
        """Anti-affinity: each server pod on a distinct DSN."""
        if self.replicas > inventory.n_dsn:
            raise ValueError("anti-affinity violated: more replicas than DSNs")
        return list(range(self.replicas))

    def helm_command(self) -> str:
        return (
            f"helm install rabbitmq bitnami/rabbitmq "
            f"--namespace {self.namespace} -f rabbit.yaml"
        )
