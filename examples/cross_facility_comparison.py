"""The paper's full evaluation in miniature: all three patterns x three
architectures, printing a compact version of Figs 4/6/7 plus the headline
overhead ratios (§6 conclusions).

    PYTHONPATH=src python examples/cross_facility_comparison.py
"""

from repro.core import run_pattern, summarize
from repro.core.metrics import overhead_table

ARCHS = ("dts", "prs-haproxy", "mss")


def main() -> None:
    print("== Fig4 (mini): work-sharing throughput, dstream ==")
    ws = []
    for arch in ARCHS:
        for nc in (1, 8, 32):
            s = summarize(run_pattern("work_sharing", arch, "dstream", nc,
                                      total_messages=2048, n_runs=1)[0])
            ws.append(s)
            print(f"  {arch:13s} c={nc:2d}  {s.throughput_msgs_s:8.0f} msgs/s")
    print("== Fig6 (mini): feedback median RTT, dstream ==")
    for arch in ARCHS:
        for nc in (1, 8):
            s = summarize(run_pattern("feedback", arch, "dstream", nc,
                                      total_messages=1536, n_runs=1)[0])
            print(f"  {arch:13s} c={nc:2d}  {s.median_rtt_s * 1e3:8.0f} ms")
    print("== Fig7a (mini): broadcast throughput, generic ==")
    for arch in ARCHS:
        s = summarize(run_pattern("broadcast", arch, "generic", 8,
                                  total_messages=256, n_runs=1)[0])
        print(f"  {arch:13s} c= 8  {s.throughput_msgs_s:8.0f} msgs/s")
    print("== overhead vs DTS (work sharing) ==")
    for (arch, wl, nc), ov in sorted(overhead_table(ws).items()):
        print(f"  {arch:13s} c={nc:2d}  {ov:.2f}x")


if __name__ == "__main__":
    main()
