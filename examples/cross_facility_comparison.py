"""The paper's full evaluation in miniature: all three patterns x three
architectures, printing a compact version of Figs 4/6/7 plus the headline
overhead ratios (§6 conclusions).

    PYTHONPATH=src python examples/cross_facility_comparison.py
    PYTHONPATH=src python examples/cross_facility_comparison.py --engine heap
    PYTHONPATH=src python examples/cross_facility_comparison.py --scale

Runs on the vectorized batched engine by default; ``--engine heap`` is
the escape hatch to the exact one-event-per-hop reference.  ``--scale``
extends the sweep to 256 consumers (interactive only on the vectorized
engine).
"""

import argparse

from repro.core import run_pattern, summarize
from repro.core.metrics import overhead_table

ARCHS = ("dts", "prs-haproxy", "mss")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("heap", "vectorized"),
                    default="vectorized", help="StreamSim backend")
    ap.add_argument("--scale", action="store_true",
                    help="extend the work-sharing sweep to 256 consumers")
    args = ap.parse_args()
    eng = args.engine

    ws_consumers = (1, 8, 32, 256) if args.scale else (1, 8, 32)
    print(f"== Fig4 (mini): work-sharing throughput, dstream [{eng}] ==")
    ws = []
    for arch in ARCHS:
        for nc in ws_consumers:
            s = summarize(run_pattern("work_sharing", arch, "dstream", nc,
                                      total_messages=max(2048, 16 * nc),
                                      n_runs=1, engine=eng)[0])
            ws.append(s)
            print(f"  {arch:13s} c={nc:3d}  {s.throughput_msgs_s:8.0f} msgs/s")
    print(f"== Fig6 (mini): feedback median RTT, dstream [{eng}] ==")
    for arch in ARCHS:
        for nc in (1, 8):
            s = summarize(run_pattern("feedback", arch, "dstream", nc,
                                      total_messages=1536, n_runs=1,
                                      engine=eng)[0])
            print(f"  {arch:13s} c={nc:3d}  {s.median_rtt_s * 1e3:8.0f} ms")
    print(f"== Fig7a (mini): broadcast throughput, generic [{eng}] ==")
    for arch in ARCHS:
        s = summarize(run_pattern("broadcast", arch, "generic", 8,
                                  total_messages=256, n_runs=1,
                                  engine=eng)[0])
        print(f"  {arch:13s} c=  8  {s.throughput_msgs_s:8.0f} msgs/s")
    print("== overhead vs DTS (work sharing) ==")
    for (arch, wl, nc), ov in sorted(overhead_table(ws).items()):
        print(f"  {arch:13s} c={nc:3d}  {ov:.2f}x")


if __name__ == "__main__":
    main()
