"""Serving with experimental steering (work sharing with feedback at
inference time): batched generation answers streamed analysis requests and
publishes per-request results back to the producers' reply queues — the
LCLS 'recommend parameter changes while the sample is in the beam' loop.

    PYTHONPATH=src python examples/steering_serve.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.broker import Message
from repro.core.workloads import DSTREAM, tokens_from_payload
from repro.launch.serve import generate
from repro.models.zoo import build_model
from repro.streaming import EdgeProducer, RealtimeBroker, SteeringFeedback


def main() -> None:
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))

    broker = RealtimeBroker()
    broker.declare_queue("work:0")
    broker.declare_queue("work:1")
    fb = SteeringFeedback(broker, ["beamline-0", "beamline-1"])
    producers = [
        EdgeProducer(broker, DSTREAM, lambda i, j=j: f"work:{j}",
                     rate_msgs_s=50, n_messages=6,
                     producer_id=f"beamline-{j}",
                     reply_queue=fb.reply_queue(f"beamline-{j}"))
        for j in (0, 1)]
    for p in producers:
        p.start()
    broker.register_consumer("hpc", "work:0")

    served = 0
    while served < 4:
        d = broker.consume("hpc", timeout=5.0)
        if d is None:
            break
        prompt = tokens_from_payload(d.message.body, cfg.vocab_size, 8)
        toks = generate(model, params, jnp.asarray(prompt)[None, :],
                        max_new=8)
        broker.ack("hpc", d.delivery_tag)
        # steer the producing instrument with the "analysis" result
        fb.publish_step(served, float(toks.sum()) % 7, backpressure=False)
        served += 1
        print(f"request {d.message.headers['seq']} from "
              f"{d.message.headers['producer']}: generated "
              f"{toks.shape[1]} tokens -> feedback published")
    for p in producers:
        r = p.poll_feedback(timeout=1.0)
        print(f"{p.id} received steering: {r}")
        p.stop(join=False)
    print(f"served {served} streamed requests")


if __name__ == "__main__":
    main()
