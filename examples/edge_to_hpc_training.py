"""End-to-end edge->HPC driver (the paper's motivating workflow): synthetic
detector producers stream Dstream-shaped events through the broker; a
~100M-parameter LM trains on the streamed tokens for a few hundred steps
with checkpointing and steering feedback; a consumer is crashed mid-run to
demonstrate redelivery-based fault tolerance.

    PYTHONPATH=src python examples/edge_to_hpc_training.py [--steps 200]
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ArchConfig
from repro.launch import train as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/edge2hpc_ckpt")
    args_in = ap.parse_args()

    # ~100M-parameter llama-style model (20L x 640d)
    cfg = ArchConfig(name="edge-100m", family="dense", n_layers=20,
                     d_model=640, n_heads=10, n_kv_heads=5, d_ff=1792,
                     vocab_size=8192, remat=False)

    import repro.configs as C
    C._MODULES["edge-100m"] = type("M", (), {"CONFIG": cfg,
                                             "SMOKE_CONFIG": cfg})
    args = argparse.Namespace(
        arch="edge-100m", steps=args_in.steps, batch=8, seq=128, lr=3e-4,
        seed=0, microbatches=1, data="stream", ckpt_dir=args_in.ckpt_dir,
        ckpt_every=50, resume=True, log_every=10, feedback_every=10,
        crash_consumer_at=args_in.steps // 3)
    out = T.run(args)
    n = cfg.param_count()
    print(f"\nmodel: {n/1e6:.0f}M params | first loss "
          f"{out['losses'][0]:.3f} -> final {out['final_loss']:.3f}")
    assert out["final_loss"] < out["losses"][0], "training must make progress"


if __name__ == "__main__":
    main()
