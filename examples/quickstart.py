"""Quickstart: the paper's three cross-facility streaming architectures in
60 seconds — deploy each control plane, run a small work-sharing
experiment, and print the throughput/overhead comparison (paper Fig 4 in
miniature).

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --engine heap

Runs on the vectorized StreamSim engine by default; ``--engine heap``
selects the exact one-event-per-hop reference.
"""

import argparse

from repro.core import (
    ResourceSettings, S3MService, establish_prs_session, make_architecture,
    overhead_table, run_pattern, summarize)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("heap", "vectorized"),
                    default="vectorized", help="StreamSim backend")
    args = ap.parse_args()
    print("== deploying the three architectures ==")
    # DTS: NodePort-exposed RabbitMQ (helm release, direct connectivity)
    dts = make_architecture("dts")
    print(f"DTS : {dts.deployment_feasibility}")

    # PRS: SciStream S2UC -> S2CS handshake builds the overlay session
    sess = establish_prs_session(num_conn=1, tunnel="haproxy")
    print(f"PRS : overlay {' -> '.join(sess.hops)} (uid={sess.uid})")

    # MSS: S3M token-authenticated provisioning returns an FQDN URL
    s3m = S3MService()
    s3m.register_project("abc123")
    token = s3m.issue_token("abc123")
    cluster = s3m.provision_cluster(token, settings=ResourceSettings(
        cpus=12, ram_gbs=32, nodes=3))
    print(f"MSS : provisioned {cluster.amqps_url}")

    print("\n== work-sharing throughput, Dstream, 8 producers/consumers ==")
    summaries = []
    for arch in ("dts", "prs-haproxy", "prs-stunnel", "mss"):
        r = run_pattern("work_sharing", arch, "dstream", 8,
                        total_messages=2048, n_runs=1,
                        engine=args.engine)[0]
        s = summarize(r)
        summaries.append(s)
        if s.feasible:
            print(f"{arch:14s} {s.throughput_msgs_s:8.0f} msgs/s "
                  f"({s.goodput_gbps:.2f} Gbps)")
        else:
            print(f"{arch:14s} INFEASIBLE")
    print("\noverhead vs DTS (paper: PRS/MSS up to ~2.5x):")
    for (arch, wl, nc), ov in overhead_table(summaries).items():
        print(f"  {arch:14s} {ov:.2f}x")


if __name__ == "__main__":
    main()
