"""The streamlint rule engine.

Responsibilities split cleanly:

* :class:`SourceFile` — one parsed python file: source text, AST, and
  the ``# streamlint: disable=...`` suppression map (extracted with
  :mod:`tokenize` so ``#`` inside string literals never confuses it).
* :class:`Project` — the analysis root plus a lazy parse index keyed by
  repo-relative posix paths.  Rules pull cross-file targets (the three
  engine modules, the campaign layer, the docs table) through it on
  demand, so scanning ``benchmarks/`` alone still checks project-level
  contracts against ``src/``.
* :class:`Config` — where the contract-bearing files live.  Tests point
  it at fixture trees; the defaults match this repo's layout.
* :func:`run_analysis` — collect diagnostics from every registered
  rule, apply suppressions, append the engine's own hygiene findings
  (SL001 unjustified / SL002 unused suppressions), and wrap the lot in
  an :class:`Analysis` with a JSON-serializable report.

Rule modules register themselves via the :func:`rule` decorator at
import time; :mod:`tools.streamlint.rules` imports them all.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import sys
import tokenize
from pathlib import Path
from typing import Callable, Iterable, Iterator

#: ``# streamlint: disable=SL101,SL403 -- optional justification``
_SUPPRESS_RE = re.compile(
    r"#\s*streamlint:\s*disable=([A-Za-z0-9_,\s]+?)(?:\s+--\s*(\S.*?))?\s*$")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: a rule id anchored to a file:line."""

    rule: str
    file: str
    line: int
    message: str
    suppressed: bool = False
    justified: bool = False

    def format(self) -> str:
        tag = "  [suppressed]" if self.suppressed else ""
        return f"{self.file}:{self.line}: {self.rule} {self.message}{tag}"

    def as_dict(self) -> dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Suppression:
    """A parsed suppression comment and the line range it covers."""

    rules: frozenset[str]
    comment_line: int
    target_line: int
    justification: str | None
    used: bool = False


class SourceFile:
    """A parsed python file plus its suppression map."""

    def __init__(self, path: str, text: str) -> None:
        self.path = path
        self.text = text
        self.tree = ast.parse(text, filename=path)
        self.suppressions = _extract_suppressions(text)

    def suppression_for(self, rule_id: str, line: int) -> Suppression | None:
        for sup in self.suppressions:
            if sup.target_line == line and rule_id in sup.rules:
                return sup
        return None


def _extract_suppressions(text: str) -> list[Suppression]:
    sups: list[Suppression] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, IndentationError):
        return sups
    lines = text.splitlines()
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        rules = frozenset(
            r.strip() for r in m.group(1).split(",") if r.strip())
        line = tok.start[0]
        code_before = lines[line - 1][: tok.start[1]].strip() \
            if line - 1 < len(lines) else ""
        # A trailing comment guards its own line; a comment alone on a
        # line guards the next code line (justifications may wrap onto
        # further comment lines).
        target = line
        if not code_before:
            target = line + 1
            while target <= len(lines) and (
                    not lines[target - 1].strip()
                    or lines[target - 1].lstrip().startswith("#")):
                target += 1
        sups.append(Suppression(rules=rules, comment_line=line,
                                target_line=target,
                                justification=m.group(2)))
    return sups


@dataclasses.dataclass
class Config:
    """Where the contract-bearing files live, relative to the root."""

    heap_engine: str = "src/repro/core/simulator.py"
    vectorized_engine: str = "src/repro/core/vectorized.py"
    jax_engine: str = "src/repro/core/jax_engine.py"
    campaign: str = "src/repro/core/campaign.py"
    bench_common: str = "benchmarks/common.py"
    parity_constants: str = "src/repro/core/parity.py"
    engines_doc: str = "docs/engines.md"
    parity_tests: tuple[str, ...] = (
        "tests/test_engine_parity.py", "tests/test_multi_tenant.py")
    #: path prefixes whose modules count as deterministic engine paths
    determinism_scope: tuple[str, ...] = ("src/repro/core/",)
    #: names that wrap a function into a jitted kernel in the jax module
    jit_wrappers: tuple[str, ...] = ("x64", "jit")


class Project:
    """Analysis root + lazy parse index over repo-relative paths."""

    def __init__(self, root: Path, config: Config | None = None) -> None:
        self.root = Path(root)
        self.config = config or Config()
        self._files: dict[str, SourceFile | None] = {}
        self.parse_errors: list[Diagnostic] = []

    def file(self, relpath: str) -> SourceFile | None:
        """Parse (and cache) ``root/relpath``; None if absent/bad."""
        if relpath not in self._files:
            full = self.root / relpath
            sf: SourceFile | None = None
            if full.is_file():
                try:
                    sf = SourceFile(relpath,
                                    full.read_text(encoding="utf-8"))
                except SyntaxError as exc:
                    self.parse_errors.append(Diagnostic(
                        rule="SL900", file=relpath,
                        line=exc.lineno or 1,
                        message=f"syntax error: {exc.msg}"))
            self._files[relpath] = sf
        return self._files[relpath]

    def text(self, relpath: str) -> str | None:
        """Raw text of a (possibly non-python) file, or None."""
        full = self.root / relpath
        if not full.is_file():
            return None
        return full.read_text(encoding="utf-8")

    def scan(self, paths: Iterable[str]) -> list[SourceFile]:
        """Parse every ``*.py`` under the given root-relative paths."""
        out: list[SourceFile] = []
        for rel in _collect_py(self.root, paths):
            sf = self.file(rel)
            if sf is not None:
                out.append(sf)
        return out


def _collect_py(root: Path, paths: Iterable[str]) -> Iterator[str]:
    seen: list[str] = []
    for p in paths:
        full = (root / p).resolve()
        if full.is_file() and full.suffix == ".py":
            cands = [full]
        elif full.is_dir():
            cands = sorted(full.rglob("*.py"))
        else:
            cands = []
        for c in cands:
            rel = c.relative_to(root.resolve()).as_posix()
            if rel not in seen:
                seen.append(rel)
                yield rel


# ---------------------------------------------------------------------------
# rule registry

RuleFn = Callable[[Project, list[SourceFile]], Iterable[Diagnostic]]

#: rule id -> (one-line description, check function)
RULES: dict[str, tuple[str, RuleFn]] = {}


def rule(rule_id: str, description: str) -> Callable[[RuleFn], RuleFn]:
    """Register ``fn`` as the checker behind ``rule_id``."""

    def deco(fn: RuleFn) -> RuleFn:
        RULES[rule_id] = (description, fn)
        return fn

    return deco


def _load_rules() -> None:
    # Imported lazily so ``engine`` itself stays import-cycle free.
    from tools.streamlint import rules  # noqa: F401


# ---------------------------------------------------------------------------
# analysis driver


@dataclasses.dataclass
class Analysis:
    """The outcome of one streamlint run."""

    root: str
    files_scanned: list[str]
    diagnostics: list[Diagnostic]

    @property
    def failures(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if not d.suppressed]

    @property
    def exit_code(self) -> int:
        return 1 if self.failures else 0

    def report(self) -> dict[str, object]:
        counts: dict[str, int] = {}
        for d in self.failures:
            counts[d.rule] = counts.get(d.rule, 0) + 1
        return {
            "version": 1,
            "root": self.root,
            "files_scanned": self.files_scanned,
            "rules": {rid: desc for rid, (desc, _) in sorted(RULES.items())},
            "diagnostics": [d.as_dict() for d in self.diagnostics],
            "counts": counts,
            "exit_code": self.exit_code,
        }

    def write_json(self, path: str | Path) -> None:
        Path(path).write_text(
            json.dumps(self.report(), indent=2) + "\n", encoding="utf-8")


def run_analysis(root: str | Path, paths: Iterable[str] = ("src",),
                 config: Config | None = None,
                 only: Iterable[str] | None = None) -> Analysis:
    """Run every registered rule over the tree rooted at ``root``.

    ``paths`` are root-relative files/directories to scan for per-file
    rules; project-level rules additionally pull their fixed targets
    (``config``) through the parse index regardless of ``paths``.
    ``only`` restricts to a subset of rule ids (used by fixture tests).
    """
    _load_rules()
    project = Project(Path(root), config)
    scanned = project.scan(list(paths))
    wanted = set(only) if only is not None else None

    raw: list[Diagnostic] = []
    for rule_id, (_, fn) in sorted(RULES.items()):
        if wanted is not None and rule_id not in wanted:
            continue
        raw.extend(fn(project, scanned))
    raw.extend(project.parse_errors)

    final: list[Diagnostic] = []
    for diag in raw:
        sf = project.file(diag.file) if diag.file.endswith(".py") else None
        sup = sf.suppression_for(diag.rule, diag.line) if sf else None
        if sup is not None:
            sup.used = True
            final.append(dataclasses.replace(
                diag, suppressed=True,
                justified=sup.justification is not None))
        else:
            final.append(diag)

    # Engine-level hygiene over every suppression comment encountered.
    hygiene = wanted is None or wanted & {"SL001", "SL002"}
    if hygiene:
        for sf in scanned:
            for sup in sf.suppressions:
                ids = ",".join(sorted(sup.rules))
                if (wanted is None or "SL001" in wanted) \
                        and sup.justification is None:
                    final.append(Diagnostic(
                        rule="SL001", file=sf.path, line=sup.comment_line,
                        message=(f"suppression of {ids} has no "
                                 "justification; append ' -- <reason>'")))
                if (wanted is None or "SL002" in wanted) and not sup.used:
                    final.append(Diagnostic(
                        rule="SL002", file=sf.path, line=sup.comment_line,
                        message=(f"suppression of {ids} matched no "
                                 "diagnostic on its line; remove it")))

    final.sort(key=lambda d: (d.file, d.line, d.rule))
    return Analysis(root=str(project.root),
                    files_scanned=[sf.path for sf in scanned],
                    diagnostics=final)


@rule("SL001", "suppression comments must carry a ' -- reason' "
               "justification")
def _sl001_doc_only(project: Project,
                    scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    # Emitted by the engine itself after suppression accounting; the
    # registration here exists so the rule shows up in --list-rules and
    # the JSON report's rule catalog.
    return ()


@rule("SL002", "suppressions must actually suppress a diagnostic")
def _sl002_doc_only(project: Project,
                    scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    return ()


def main(argv: list[str] | None = None) -> int:
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m tools.streamlint",
        description="AST-level engine-contract analysis for this repo.")
    parser.add_argument("paths", nargs="*", default=["src", "benchmarks"],
                        help="root-relative files/dirs to scan "
                             "(default: src benchmarks)")
    parser.add_argument("--root", default=".",
                        help="analysis root (default: cwd)")
    parser.add_argument("--json", metavar="FILE", default=None,
                        help="also write the full JSON report here")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    _load_rules()
    if args.list_rules:
        for rid, (desc, _) in sorted(RULES.items()):
            print(f"{rid}  {desc}")
        return 0

    analysis = run_analysis(args.root, args.paths or ["src", "benchmarks"])
    for diag in analysis.diagnostics:
        if not diag.suppressed:
            print(diag.format())
    n_sup = sum(1 for d in analysis.diagnostics if d.suppressed)
    print(f"streamlint: {len(analysis.files_scanned)} files, "
          f"{len(analysis.failures)} finding(s), "
          f"{n_sup} suppressed", file=sys.stderr)
    if args.json:
        analysis.write_json(args.json)
    return analysis.exit_code
