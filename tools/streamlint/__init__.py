"""streamlint — AST-level engine-contract analysis for this repo.

The repo's correctness story — three ``Engine`` backends held to
documented parity bands, a resumable campaign cache keyed by
``SimParams`` fingerprints, pilot bit-identity across stacked seed
lanes — is enforced empirically by the parity suites.  Every one of
those contracts is *also* a structural property of the source, and this
package checks them statically, before a single cell runs:

========  ==========================================================
family    invariant
========  ==========================================================
SL0xx     suppression hygiene (justifications, unused suppressions)
SL1xx     engine-contract symmetry: every ``RunResult`` field the
          heap engine populates is populated by the vectorized
          engine and handled by the jax engine
SL2xx     cache-key completeness: every ``SimParams`` /
          ``ExperimentSpec`` / ``CellSpec`` field flows into
          ``params_fingerprint`` / ``cell_key``
SL3xx     jit/x64 purity: no global ``jax_enable_x64`` flips, no
          host syncs or data-dependent Python branches inside the
          jitted kernel seams
SL4xx     determinism: no ``random.*``, unseeded RNGs, wall-clock
          reads, or unordered-set iteration in engine paths
SL5xx     doc/test tolerance drift: the ``docs/engines.md`` parity
          table matches ``repro.core.parity`` band constants, and
          the parity suites import them
========  ==========================================================

Run ``python -m tools.streamlint src benchmarks`` from the repo root;
suppress a finding in place with ``# streamlint: disable=SL403 -- why``
(the justification is mandatory — SL001 fires on bare suppressions).
See ``docs/static_analysis.md`` for the rule catalog.

Stdlib-only by design (``ast`` + ``tokenize``): no new runtime deps.
"""

from tools.streamlint.engine import (  # noqa: F401
    Analysis, Config, Diagnostic, Project, SourceFile, run_analysis)

__all__ = ["Analysis", "Config", "Diagnostic", "Project", "SourceFile",
           "run_analysis"]
