"""SL2xx — cache-key completeness for the resumable campaign cache.

PR 2's ``LegacyCacheError`` made a stale fingerprint *loud*; these
rules make the underlying mistake impossible to commit.  A knob added
to ``SimParams`` or ``ExperimentSpec`` that does not reach the
fingerprint/cell key would let two different configurations share a
cache entry — silent result poisoning across resumes.

* SL201 — ``SimParams`` field not covered by
  ``campaign.params_fingerprint``.  Covering the whole ``__dict__``
  (or ``dataclasses.asdict``/``fields``/``astuple``) is
  field-complete by construction and passes outright.
* SL202 — ``CellSpec`` field that never flows into ``cell_key``
  (directly, or via the ``cell.experiment()`` expansion).
* SL203 — ``ExperimentSpec`` field not threaded through the
  ``ExperimentSpec(...)`` construction inside ``CellSpec.experiment``
  (a spec knob campaigns could never set — and therefore never key).
* SL204 — a cache-key builder that keys on the *requested* engine
  instead of the *resolved* one.  ``run_many`` silently downgrades
  unsupported jax cells to the vectorized engine; a key built before
  that resolution caches vectorized numbers under the jax namespace
  (poisoning later genuinely-jax runs) and forks them from the
  identical vectorized cell.  ``campaign.cell_key`` and
  ``benchmarks.common.resolve_engine`` must both route through
  ``campaign.resolved_engine``.
"""

from __future__ import annotations

import ast
from typing import Iterable

from tools.streamlint.engine import (Diagnostic, Project, SourceFile,
                                     rule)
from tools.streamlint.rules._helpers import (attr_reads, calls_to,
                                             dataclass_fields, dotted,
                                             find_class, find_func,
                                             kwarg_names)

#: accessing any of these on the params argument covers every field
_WHOLESALE = {"__dict__"}
_WHOLESALE_CALLS = {"asdict", "astuple", "fields", "vars"}


def _spec_fields(project: Project, name: str) -> dict[str, int] | None:
    heap = project.file(project.config.heap_engine)
    if heap is None:
        return None
    cls = find_class(heap.tree, name)
    return dataclass_fields(cls) if cls is not None else None


def _covers_wholesale(func: ast.FunctionDef, arg: str) -> bool:
    if attr_reads(func, arg) & _WHOLESALE:
        return True
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            d = dotted(node.func) or ""
            if d.split(".")[-1] in _WHOLESALE_CALLS and any(
                    isinstance(a, ast.Name) and a.id == arg
                    for a in node.args):
                return True
    return False


@rule("SL201", "every SimParams field must flow into "
               "campaign.params_fingerprint")
def sl201(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    camp = project.file(project.config.campaign)
    fields = _spec_fields(project, "SimParams")
    if camp is None or fields is None:
        return
    func = find_func(camp.tree, "params_fingerprint")
    if func is None or not func.args.args:
        return
    arg = func.args.args[0].arg
    if _covers_wholesale(func, arg):
        return
    covered = attr_reads(func, arg)
    for field in sorted(set(fields) - covered):
        yield Diagnostic(
            rule="SL201", file=camp.path, line=func.lineno,
            message=(f"params_fingerprint does not cover SimParams "
                     f"field {field!r}; a campaign varying it would "
                     f"reuse stale cache entries"))


@rule("SL202", "every CellSpec field must flow into campaign.cell_key")
def sl202(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    camp = project.file(project.config.campaign)
    if camp is None:
        return
    cls = find_class(camp.tree, "CellSpec")
    func = find_func(camp.tree, "cell_key")
    if cls is None or func is None or not func.args.args:
        return
    fields = dataclass_fields(cls)
    arg = func.args.args[0].arg
    covered = attr_reads(func, arg)
    if "experiment" in covered:
        # cell.experiment() expands the cell into an ExperimentSpec;
        # whatever that expansion reads off self is covered too.
        exp = find_func(cls, "experiment")
        if exp is not None:
            covered |= attr_reads(exp, "self")
    for field in sorted(set(fields) - covered):
        yield Diagnostic(
            rule="SL202", file=camp.path, line=func.lineno,
            message=(f"cell_key does not cover CellSpec field "
                     f"{field!r}; two cells differing only in it "
                     f"would collide in the cache"))


def _calls_name(func: ast.AST, name: str) -> bool:
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            d = dotted(node.func) or ""
            if d.split(".")[-1] == name:
                return True
    return False


@rule("SL204", "cache keys must be built from the resolved engine, "
               "never the requested one")
def sl204(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    camp = project.file(project.config.campaign)
    if camp is not None:
        func = find_func(camp.tree, "cell_key")
        if func is not None and not _calls_name(func, "resolved_engine"):
            yield Diagnostic(
                rule="SL204", file=camp.path, line=func.lineno,
                message=("cell_key never calls resolved_engine: a jax "
                         "cell the run_many fallback downgrades to "
                         "vectorized would be cached under the jax "
                         "namespace, poisoning later genuinely-jax "
                         "runs"))
    bench = project.file(project.config.bench_common)
    if bench is not None:
        func = find_func(bench.tree, "resolve_engine")
        if func is not None and not _calls_name(func, "resolved_engine"):
            yield Diagnostic(
                rule="SL204", file=bench.path, line=func.lineno,
                message=("benchmarks.common.resolve_engine never "
                         "consults campaign.resolved_engine: bench "
                         "cache keys for fallback cells would carry "
                         "the requested engine instead of the one "
                         "that actually ran"))


@rule("SL203", "every ExperimentSpec field must be threaded through "
               "CellSpec.experiment")
def sl203(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    camp = project.file(project.config.campaign)
    fields = _spec_fields(project, "ExperimentSpec")
    if camp is None or fields is None:
        return
    cls = find_class(camp.tree, "CellSpec")
    if cls is None:
        return
    exp = find_func(cls, "experiment")
    if exp is None:
        return
    for call in calls_to(exp, "ExperimentSpec"):
        for field in sorted(set(fields) - kwarg_names(call)):
            yield Diagnostic(
                rule="SL203", file=camp.path, line=call.lineno,
                message=(f"CellSpec.experiment builds ExperimentSpec "
                         f"without {field!r}; campaigns can never set "
                         f"(or cache-key) it"))
