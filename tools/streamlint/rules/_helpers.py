"""Shared AST plumbing for the rule modules."""

from __future__ import annotations

import ast
from typing import Iterator


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def find_class(tree: ast.Module, name: str) -> ast.ClassDef | None:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def find_func(tree: ast.AST, name: str) -> ast.FunctionDef | None:
    """First (module- or class-level) def with the given name."""
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def dataclass_fields(cls: ast.ClassDef) -> dict[str, int]:
    """Dataclass field name -> lineno (AnnAssign class-level targets,
    minus ClassVar annotations — matching dataclasses' own semantics)."""
    fields: dict[str, int] = {}
    for node in cls.body:
        if not isinstance(node, ast.AnnAssign):
            continue
        if not isinstance(node.target, ast.Name):
            continue
        ann = dotted(node.annotation) or ""
        if isinstance(node.annotation, ast.Subscript):
            ann = dotted(node.annotation.value) or ""
        if ann.split(".")[-1] == "ClassVar":
            continue
        fields[node.target.id] = node.lineno
    return fields


def calls_to(tree: ast.AST, name: str) -> Iterator[ast.Call]:
    """Every ``name(...)`` call (bare name or trailing attribute)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d is not None and d.split(".")[-1] == name:
                yield node


def kwarg_names(call: ast.Call) -> set[str]:
    return {kw.arg for kw in call.keywords if kw.arg is not None}


def attr_reads(tree: ast.AST, base: str) -> set[str]:
    """Attribute names read off a given base name (``base.<attr>``)."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == base:
            out.add(node.attr)
    return out


def engine_registrations(tree: ast.Module) -> dict[str, str]:
    """``ENGINES["heap"] = StreamSim``-style registrations found in a
    module: engine name -> class name."""
    regs: dict[str, str] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript) \
                    and isinstance(tgt.value, ast.Name) \
                    and tgt.value.id == "ENGINES" \
                    and isinstance(tgt.slice, ast.Constant) \
                    and isinstance(tgt.slice.value, str) \
                    and isinstance(node.value, ast.Name):
                regs[tgt.slice.value] = node.value.id
    return regs


def enclosing_class(tree: ast.Module,
                    node: ast.AST) -> ast.ClassDef | None:
    """The top-level ClassDef whose subtree contains ``node``."""
    for top in tree.body:
        if isinstance(top, ast.ClassDef):
            for sub in ast.walk(top):
                if sub is node:
                    return top
    return None


def const_str(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
