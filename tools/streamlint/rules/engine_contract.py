"""SL1xx — engine-contract symmetry across the three backends.

The heap engine (``simulator.py``) is the reference: the set of
``RunResult`` fields it populates on a feasible run *is* the engine
contract.  PR 3 and PR 5 both fixed, by hand, the bug class where a new
field (``consume_producers``, tenant attribution) was threaded through
one engine and silently dropped by another; these rules make that class
a lint failure:

* SL101 — field populated by the heap engine but missing from a
  feasible ``RunResult`` construction in the vectorized engine.
* SL102 — field populated by the vectorized engine but not by the heap
  reference (the asymmetry in the other direction).
* SL103 — ``RunResult`` dataclass field that no feasible heap
  construction populates at all (a field nobody fills).
* SL104 — the jax engine neither subclasses the vectorized engine
  class nor provides its own complete feasible ``RunResult``
  construction (subclassing *is* the sanctioned way to "handle" the
  contract: ``JaxStreamSim`` inherits ``_result``).

Infeasible constructions (``feasible=False``) are exempt everywhere —
they legitimately carry only ``spec``/``feasible``/``infeasible_reason``.
"""

from __future__ import annotations

import ast
from typing import Iterable

from tools.streamlint.engine import (Diagnostic, Project, SourceFile,
                                     rule)
from tools.streamlint.rules._helpers import (calls_to, dataclass_fields,
                                             dotted, engine_registrations,
                                             find_class, kwarg_names)

#: fields a feasible construction is not required to pass explicitly
_EXEMPT = {"spec", "feasible", "infeasible_reason"}


def _feasible_calls(tree: ast.AST) -> list[ast.Call]:
    out = []
    for call in calls_to(tree, "RunResult"):
        feas = next((kw.value for kw in call.keywords
                     if kw.arg == "feasible"), None)
        if isinstance(feas, ast.Constant) and feas.value is False:
            continue
        out.append(call)
    return out


def _contract_fields(calls: list[ast.Call]) -> set[str]:
    fields: set[str] = set()
    for call in calls:
        fields |= kwarg_names(call)
    return fields - _EXEMPT


@rule("SL101", "RunResult field populated by the heap engine must be "
               "populated by the vectorized engine")
def sl101(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    cfg = project.config
    heap = project.file(cfg.heap_engine)
    vec = project.file(cfg.vectorized_engine)
    if heap is None or vec is None:
        return
    contract = _contract_fields(_feasible_calls(heap.tree))
    for call in _feasible_calls(vec.tree):
        for field in sorted(contract - kwarg_names(call)):
            yield Diagnostic(
                rule="SL101", file=vec.path, line=call.lineno,
                message=(f"feasible RunResult omits {field!r}, which "
                         f"the heap engine populates"))


@rule("SL102", "RunResult field populated by the vectorized engine "
               "must be populated by the heap reference")
def sl102(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    cfg = project.config
    heap = project.file(cfg.heap_engine)
    vec = project.file(cfg.vectorized_engine)
    if heap is None or vec is None:
        return
    heap_fields = _contract_fields(_feasible_calls(heap.tree))
    for call in _feasible_calls(vec.tree):
        for field in sorted(kwarg_names(call) - heap_fields - _EXEMPT):
            yield Diagnostic(
                rule="SL102", file=vec.path, line=call.lineno,
                message=(f"feasible RunResult passes {field!r}, which "
                         f"the heap reference never populates"))


@rule("SL103", "every non-exempt RunResult dataclass field must be "
               "populated by the heap engine")
def sl103(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    cfg = project.config
    heap = project.file(cfg.heap_engine)
    if heap is None:
        return
    cls = find_class(heap.tree, "RunResult")
    if cls is None:
        return
    calls = _feasible_calls(heap.tree)
    if not calls:
        return
    populated = _contract_fields(calls)
    for field, lineno in dataclass_fields(cls).items():
        if field in _EXEMPT or field in populated:
            continue
        yield Diagnostic(
            rule="SL103", file=heap.path, line=lineno,
            message=(f"RunResult field {field!r} is never populated by "
                     f"a feasible heap-engine construction"))


@rule("SL104", "the jax engine must subclass the vectorized engine or "
               "construct the full RunResult contract itself")
def sl104(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    cfg = project.config
    heap = project.file(cfg.heap_engine)
    vec = project.file(cfg.vectorized_engine)
    jax_mod = project.file(cfg.jax_engine)
    if heap is None or vec is None or jax_mod is None:
        return
    contract = _contract_fields(_feasible_calls(heap.tree))
    vec_cls = engine_registrations(vec.tree).get(
        "vectorized", "VectorizedStreamSim")

    jax_cls_name = engine_registrations(jax_mod.tree).get("jax")
    jax_cls = (find_class(jax_mod.tree, jax_cls_name)
               if jax_cls_name else None)
    subclasses_vec = jax_cls is not None and any(
        (dotted(base) or "").split(".")[-1] == vec_cls
        for base in jax_cls.bases)

    calls = _feasible_calls(jax_mod.tree)
    if calls:
        # The jax engine opted into constructing results itself — each
        # feasible construction must then carry the full contract.
        for call in calls:
            for field in sorted(contract - kwarg_names(call)):
                yield Diagnostic(
                    rule="SL104", file=jax_mod.path, line=call.lineno,
                    message=(f"feasible RunResult omits {field!r}, "
                             f"which the heap engine populates"))
    elif not subclasses_vec:
        line = jax_cls.lineno if jax_cls is not None else 1
        yield Diagnostic(
            rule="SL104", file=jax_mod.path, line=line,
            message=(f"jax engine neither subclasses {vec_cls} nor "
                     f"constructs RunResult; the engine contract is "
                     f"unhandled"))
