"""Rule modules register themselves with the engine on import."""

from tools.streamlint.rules import (  # noqa: F401
    cache_key, determinism, doc_drift, engine_contract, jax_purity)
