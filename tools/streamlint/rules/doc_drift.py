"""SL5xx — doc/test tolerance drift.

The parity bands live once, in ``repro.core.parity``; the suites
import them and every row of the ``docs/engines.md`` parity table
carries a ``band:<key>`` id.  These rules close the loop in both
directions:

* SL501 — a ``band:<key>`` in the docs that names an unknown band, or
  whose documented bound (``≤ N%`` / ``lo–hi×``) disagrees with the
  constant the tests enforce.
* SL502 — a band constant no docs row documents.
* SL503 — a parity test file that does not import the shared band
  constants (literal drift would be invisible to SL501).

The constants are read from the ``PARITY_BANDS`` / ``FACTOR_BANDS``
dict literals by AST (``ast.literal_eval``), not by importing the
module — the analyzer must work on fixture trees that are not
importable packages.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable

from tools.streamlint.engine import (Diagnostic, Project, SourceFile,
                                     rule)

_BAND_ID_RE = re.compile(r"band:([a-z0-9_.\-]+)")
#: "≤ 3%", "<= 3.5 %"
_PCT_RE = re.compile(r"(?:≤|<=)\s*([0-9.]+)\s*%")
#: "0.3–3×", "0.5-2.0 x"
_FACTOR_RE = re.compile(r"([0-9.]+)\s*[–-]\s*([0-9.]+)\s*[×x]")


def _literal_dict(tree: ast.Module, name: str) -> tuple[dict, int] | None:
    """(literal value, lineno) of a module-level ``name = {...}``."""
    for node in tree.body:
        target: ast.expr | None = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target = node.targets[0]
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            target = node.target
        else:
            continue
        if isinstance(target, ast.Name) and target.id == name:
            try:
                value = ast.literal_eval(node.value)
            except ValueError:
                return None
            if isinstance(value, dict):
                return value, node.lineno
    return None


def _bands(project: Project) -> tuple[dict, dict, SourceFile] | None:
    sf = project.file(project.config.parity_constants)
    if sf is None:
        return None
    parity = _literal_dict(sf.tree, "PARITY_BANDS")
    factor = _literal_dict(sf.tree, "FACTOR_BANDS")
    if parity is None or factor is None:
        return None
    return parity[0], factor[0], sf


@rule("SL501", "docs parity table must match the enforced band "
               "constants")
def sl501(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    bands = _bands(project)
    doc = project.text(project.config.engines_doc)
    if bands is None or doc is None:
        return
    parity, factor, _ = bands
    doc_path = project.config.engines_doc
    for lineno, line in enumerate(doc.splitlines(), start=1):
        ids = _BAND_ID_RE.findall(line)
        if not ids:
            continue
        pcts = [float(m) for m in _PCT_RE.findall(line)]
        factors = [(float(lo), float(hi))
                   for lo, hi in _FACTOR_RE.findall(line)]
        for key in ids:
            if key in parity:
                want = parity[key] * 100.0
                if not any(abs(p - want) < 1e-9 for p in pcts):
                    got = ", ".join(f"{p:g}%" for p in pcts) or "none"
                    yield Diagnostic(
                        rule="SL501", file=doc_path, line=lineno,
                        message=(f"band:{key} documents {got} but the "
                                 f"tests enforce ≤ {want:g}%"))
            elif key in factor:
                want_f = tuple(factor[key])
                if not any(abs(lo - want_f[0]) < 1e-9
                           and abs(hi - want_f[1]) < 1e-9
                           for lo, hi in factors):
                    got = ", ".join(f"{lo:g}–{hi:g}×"
                                    for lo, hi in factors) or "none"
                    yield Diagnostic(
                        rule="SL501", file=doc_path, line=lineno,
                        message=(f"band:{key} documents {got} but the "
                                 f"tests enforce "
                                 f"{want_f[0]:g}–{want_f[1]:g}×"))
            else:
                yield Diagnostic(
                    rule="SL501", file=doc_path, line=lineno,
                    message=(f"band:{key} is not a known parity band; "
                             f"known keys live in "
                             f"{project.config.parity_constants}"))


@rule("SL502", "every enforced band constant must be documented in "
               "the docs parity table")
def sl502(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    bands = _bands(project)
    doc = project.text(project.config.engines_doc)
    if bands is None or doc is None:
        return
    parity, factor, sf = bands
    documented = set(_BAND_ID_RE.findall(doc))
    for name, table in (("PARITY_BANDS", parity),
                        ("FACTOR_BANDS", factor)):
        loc = _literal_dict(sf.tree, name)
        line = loc[1] if loc is not None else 1
        for key in sorted(set(table) - documented):
            yield Diagnostic(
                rule="SL502", file=sf.path, line=line,
                message=(f"band {key!r} is enforced by the tests but "
                         f"has no band:{key} row in "
                         f"{project.config.engines_doc}"))


@rule("SL503", "parity test files must import the shared band "
               "constants")
def sl503(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    for rel in project.config.parity_tests:
        sf = project.file(rel)
        if sf is None:
            continue
        imports_parity = False
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod.endswith(".parity") or any(
                        a.name in ("parity", "PARITY_BANDS",
                                   "FACTOR_BANDS", "band", "factor_band")
                        for a in node.names):
                    imports_parity = True
            elif isinstance(node, ast.Import):
                if any(a.name.endswith(".parity") for a in node.names):
                    imports_parity = True
        if not imports_parity:
            yield Diagnostic(
                rule="SL503", file=rel, line=1,
                message=("parity suite does not import the shared "
                         "band constants (repro.core.parity); its "
                         "literal tolerances can drift from the docs"))
