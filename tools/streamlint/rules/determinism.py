"""SL4xx — determinism in the engine paths.

Pilot bit-identity (stacked lane 0 must reproduce the solo vectorized
run exactly) and campaign cache reuse both assume the engines are pure
functions of ``(spec, seed)``.  Within the configured determinism
scope (``src/repro/core/`` by default) these rules forbid every
ambient-entropy source:

* SL401 — the stdlib ``random`` module (process-global Mersenne state).
* SL402 — unseeded ``np.random.default_rng()`` or legacy global-state
  ``np.random.*`` calls.
* SL403 — wall-clock reads (``time.time``/``monotonic``/
  ``perf_counter``, ``datetime.now``, …).
* SL404 — direct iteration over an unordered ``set``/``frozenset``
  (hash-order dependent; wrap in ``sorted(...)``).
"""

from __future__ import annotations

import ast
from typing import Iterable, Iterator

from tools.streamlint.engine import (Diagnostic, Project, SourceFile,
                                     rule)
from tools.streamlint.rules._helpers import dotted

_LEGACY_NP_RANDOM = {
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "shuffle", "permutation", "uniform", "normal", "choice", "bytes",
}

_WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
}
_WALL_CLOCK_SUFFIXES = (
    "datetime.now", "datetime.utcnow", "datetime.today", "date.today",
)


def _in_scope(project: Project,
              scanned: list[SourceFile]) -> Iterator[SourceFile]:
    for sf in scanned:
        if any(sf.path.startswith(p)
               for p in project.config.determinism_scope):
            yield sf


@rule("SL401", "no stdlib random in engine paths")
def sl401(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    for sf in _in_scope(project, scanned):
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Import):
                names = [a.name for a in node.names
                         if a.name == "random"]
            elif isinstance(node, ast.ImportFrom):
                names = ["random"] if node.module == "random" else []
            else:
                continue
            if names:
                yield Diagnostic(
                    rule="SL401", file=sf.path, line=node.lineno,
                    message=("stdlib random is process-global state; "
                             "use a seeded np.random.default_rng"))


@rule("SL402", "numpy RNGs in engine paths must be explicitly seeded")
def sl402(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    for sf in _in_scope(project, scanned):
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func) or ""
            parts = d.split(".")
            if parts[-1] == "default_rng" and not node.args \
                    and not node.keywords:
                yield Diagnostic(
                    rule="SL402", file=sf.path, line=node.lineno,
                    message=("unseeded default_rng(); pass a seed "
                             "derived from the spec"))
            elif len(parts) >= 2 and parts[-2] == "random" \
                    and parts[0] in ("np", "numpy") \
                    and parts[-1] in _LEGACY_NP_RANDOM:
                yield Diagnostic(
                    rule="SL402", file=sf.path, line=node.lineno,
                    message=(f"{d}() uses numpy's global RNG state; "
                             f"use a seeded Generator"))


@rule("SL403", "no wall-clock reads in engine paths")
def sl403(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    for sf in _in_scope(project, scanned):
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func) or ""
            if d in _WALL_CLOCK or d.endswith(_WALL_CLOCK_SUFFIXES):
                yield Diagnostic(
                    rule="SL403", file=sf.path, line=node.lineno,
                    message=(f"{d}() reads the wall clock; engine "
                             f"results must be pure in (spec, seed)"))


def _iter_sources(tree: ast.AST) -> Iterator[tuple[ast.AST, int]]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            yield node.iter, node.lineno
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                yield gen.iter, node.lineno


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        d = dotted(node.func) or ""
        return d in ("set", "frozenset")
    return False


@rule("SL404", "no iteration over unordered sets in engine paths")
def sl404(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    for sf in _in_scope(project, scanned):
        for it, lineno in _iter_sources(sf.tree):
            if _is_set_expr(it):
                yield Diagnostic(
                    rule="SL404", file=sf.path, line=lineno,
                    message=("iterating an unordered set; hash order "
                             "leaks into results — wrap in sorted()"))
