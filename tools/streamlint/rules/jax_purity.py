"""SL3xx — jit/x64 purity in the jax engine.

The jax backend owes its parity story to two disciplines: x64 is
enabled *scoped* (the ``enable_x64`` context inside the ``x64`` kernel
wrapper), never via the process-global config flip that would silently
retrace every other jax user in the process; and the jitted kernel
seams stay pure — no host syncs, no Python control flow on traced
values (shape/ndim dispatch is fine: it is resolved at trace time).

* SL301 — global x64 flip: ``jax.config.update("jax_enable_x64", …)``
  (any spelling) or assignment to ``jax.config.jax_enable_x64``.
  Checked in **every** scanned file.
* SL302 — host sync inside a jitted kernel: ``.item()``/``.tolist()``/
  ``.block_until_ready()``, any ``np.*``/``numpy.*`` call, or
  ``float()``/``int()``/``bool()`` on a non-constant value.
* SL303 — data-dependent Python branch inside a jitted kernel: ``if``/
  ``while``/``assert`` whose test involves anything beyond shapes,
  dtypes, ``len()``/``isinstance()`` and constants.

"Jitted kernel" means, within the configured jax-engine module: any
def decorated with a jit wrapper (``x64``, ``jit``, ``jax.jit``), any
def whose name is passed into such a wrapper (including through
``jax.vmap(...)``), and every def nested inside one.
"""

from __future__ import annotations

import ast
from typing import Iterable, Iterator

from tools.streamlint.engine import (Diagnostic, Project, SourceFile,
                                     rule)
from tools.streamlint.rules._helpers import dotted

#: attribute accesses that are resolved at trace time, not run time
_STATIC_ATTRS = {"ndim", "shape", "dtype", "size"}
_STATIC_CALLS = {"len", "isinstance", "issubclass"}
_HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_CASTS = {"float", "int", "bool"}


def _is_x64_flip(node: ast.AST) -> bool:
    if isinstance(node, ast.Call):
        d = dotted(node.func) or ""
        if d.split(".")[-1] == "update" and node.args:
            arg = node.args[0]
            return (isinstance(arg, ast.Constant)
                    and arg.value == "jax_enable_x64")
    if isinstance(node, ast.Assign):
        return any((dotted(t) or "").endswith("config.jax_enable_x64")
                   for t in node.targets)
    return False


@rule("SL301", "never flip jax_enable_x64 globally; use a scoped "
               "enable_x64 context")
def sl301(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    for sf in scanned:
        for node in ast.walk(sf.tree):
            if _is_x64_flip(node):
                yield Diagnostic(
                    rule="SL301", file=sf.path, line=node.lineno,
                    message=("global jax_enable_x64 flip; use "
                             "jax.experimental.enable_x64() scoped "
                             "around kernel builds instead"))


def _wrapper_hit(node: ast.AST, wrappers: tuple[str, ...]) -> bool:
    d = dotted(node)
    if d is None:
        return False
    return d.split(".")[-1] in wrappers or d == "jax.jit"


def _names_fed_to_wrappers(tree: ast.Module,
                           wrappers: tuple[str, ...]) -> set[str]:
    """Function names passed into jit wrappers, unwrapping nested
    transforms (``x64(jax.vmap(fifo1))`` feeds ``fifo1``)."""
    roots: set[str] = set()

    def harvest(arg: ast.AST) -> None:
        if isinstance(arg, ast.Name):
            roots.add(arg.id)
        elif isinstance(arg, ast.Call):
            for sub in arg.args:
                harvest(sub)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _wrapper_hit(node.func, wrappers):
            for arg in node.args:
                harvest(arg)
    return roots


def _jitted_defs(tree: ast.Module,
                 wrappers: tuple[str, ...]) -> Iterator[ast.FunctionDef]:
    roots = _names_fed_to_wrappers(tree, wrappers)
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        if node.name in roots or any(
                _wrapper_hit(d if not isinstance(d, ast.Call) else d.func,
                             wrappers)
                for d in node.decorator_list):
            yield node


def _static_test(node: ast.AST) -> bool:
    """True when the expression is resolvable at trace time."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute):
        return node.attr in _STATIC_ATTRS
    if isinstance(node, ast.Subscript):
        return _static_test(node.value) and isinstance(
            node.slice, ast.Constant)
    if isinstance(node, ast.Call):
        d = dotted(node.func) or ""
        return d.split(".")[-1] in _STATIC_CALLS
    if isinstance(node, ast.BoolOp):
        return all(_static_test(v) for v in node.values)
    if isinstance(node, ast.UnaryOp):
        return _static_test(node.operand)
    if isinstance(node, ast.BinOp):
        return _static_test(node.left) and _static_test(node.right)
    if isinstance(node, ast.Compare):
        return _static_test(node.left) and all(
            _static_test(c) for c in node.comparators)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_static_test(e) for e in node.elts)
    return False


def _check_kernel_body(sf: SourceFile,
                       fn: ast.FunctionDef) -> Iterator[Diagnostic]:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            d = dotted(node.func) or ""
            parts = d.split(".")
            if parts[-1] in _HOST_SYNC_METHODS and len(parts) > 1:
                yield Diagnostic(
                    rule="SL302", file=sf.path, line=node.lineno,
                    message=(f".{parts[-1]}() inside jitted kernel "
                             f"{fn.name!r} forces a host sync"))
            elif parts[0] in ("np", "numpy") and len(parts) > 1:
                yield Diagnostic(
                    rule="SL302", file=sf.path, line=node.lineno,
                    message=(f"{d}() inside jitted kernel {fn.name!r}; "
                             f"numpy calls sync traced values to host"))
            elif d in _CASTS and node.args and not isinstance(
                    node.args[0], ast.Constant):
                yield Diagnostic(
                    rule="SL302", file=sf.path, line=node.lineno,
                    message=(f"{d}() on a traced value inside jitted "
                             f"kernel {fn.name!r} forces a host sync"))
        elif isinstance(node, ast.While):
            yield Diagnostic(
                rule="SL303", file=sf.path, line=node.lineno,
                message=(f"Python while-loop inside jitted kernel "
                         f"{fn.name!r}; use lax.while_loop"))
        elif isinstance(node, ast.If) and not _static_test(node.test):
            yield Diagnostic(
                rule="SL303", file=sf.path, line=node.lineno,
                message=(f"data-dependent Python branch inside jitted "
                         f"kernel {fn.name!r}; use jnp.where/lax.cond"))
        elif isinstance(node, ast.Assert) and not _static_test(node.test):
            yield Diagnostic(
                rule="SL303", file=sf.path, line=node.lineno,
                message=(f"assert on a traced value inside jitted "
                         f"kernel {fn.name!r}"))


@rule("SL302", "no host syncs inside jitted kernel seams")
def sl302(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    yield from _kernel_findings(project, scanned, "SL302")


@rule("SL303", "no data-dependent Python control flow inside jitted "
               "kernel seams")
def sl303(project: Project,
          scanned: list[SourceFile]) -> Iterable[Diagnostic]:
    yield from _kernel_findings(project, scanned, "SL303")


def _kernel_findings(project: Project, scanned: list[SourceFile],
                     rule_id: str) -> Iterator[Diagnostic]:
    cfg = project.config
    sf = next((s for s in scanned if s.path == cfg.jax_engine), None) \
        or project.file(cfg.jax_engine)
    if sf is None:
        return
    seen: set[tuple[str, int, str]] = set()
    for fn in _jitted_defs(sf.tree, cfg.jit_wrappers):
        for diag in _check_kernel_body(sf, fn):
            # Nested jitted defs are walked by their enclosing def too;
            # report each site once, for the rule being evaluated.
            key = (diag.rule, diag.line, diag.message)
            if diag.rule == rule_id and key not in seen:
                seen.add(key)
                yield diag
