"""``python -m tools.streamlint [paths...] [--json report.json]``."""

import sys

from tools.streamlint.engine import main

if __name__ == "__main__":
    sys.exit(main())
