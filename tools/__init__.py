"""Repo tooling: the streamlint static analyzer and CI helpers."""
