"""Record the line-coverage baseline of ``src/repro/core`` without
pytest-cov.

The CI coverage job runs tier-1 under ``pytest-cov`` and fails below a
recorded ``--cov-fail-under`` threshold (see .github/workflows/ci.yml),
so engine refactors can't silently drop tested paths.  Re-recording
that baseline normally means running pytest-cov; this tool produces a
close approximation in environments where pytest-cov isn't installed
(e.g. an air-gapped container with only the runtime deps):

* executed lines are collected with a ``sys.settrace`` tracer filtered
  to files under ``src/repro/core``;
* executable lines come from compiling each module and walking its code
  objects' ``co_lines()`` tables — the same line universe the trace
  events draw from.

The number differs from coverage.py's statement coverage by a few
points (docstring/def-line accounting), so record the CI threshold with
margin below the measurement::

    PYTHONPATH=src python tools/coverage_baseline.py tests/test_simulator.py ...
    # or the default core-focused selection:
    PYTHONPATH=src python tools/coverage_baseline.py
"""

from __future__ import annotations

import os
import sys

CORE = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src", "repro", "core"))

#: the test files that exercise repro.core (the default selection)
CORE_TESTS = [
    "tests/test_simulator.py", "tests/test_broker.py",
    "tests/test_core_system.py", "tests/test_engine_parity.py",
    "tests/test_campaign.py", "tests/test_multi_tenant.py",
    "tests/test_flow_control_props.py", "tests/test_bench_cache.py",
    "tests/test_jax_engine.py",
]


def executable_lines(path: str) -> set[int]:
    with open(path) as f:
        code = compile(f.read(), path, "exec")
    lines: set[int] = set()
    stack = [code]
    while stack:
        co = stack.pop()
        for _, _, line in co.co_lines():
            if line is not None:
                lines.add(line)
        for const in co.co_consts:
            if hasattr(const, "co_lines"):
                stack.append(const)
    return lines


def main() -> int:
    import pytest

    hit: dict[str, set[int]] = {}

    def local(frame, event, arg):
        if event == "line":
            hit.setdefault(frame.f_code.co_filename, set()).add(
                frame.f_lineno)
        return local

    def tracer(frame, event, arg):
        if frame.f_code.co_filename.startswith(CORE):
            return local
        return None

    args = sys.argv[1:] or CORE_TESTS
    sys.settrace(tracer)
    try:
        rc = pytest.main(["-x", "-q", "-p", "no:cacheprovider", *args])
    finally:
        sys.settrace(None)
    if rc != 0:
        print(f"pytest failed (rc={rc}); coverage numbers unreliable")
        return int(rc)

    total_exec = total_hit = 0
    print(f"\n{'file':<42}{'lines':>7}{'hit':>7}{'cov':>8}")
    for fn in sorted(os.listdir(CORE)):
        if not fn.endswith(".py"):
            continue
        path = os.path.join(CORE, fn)
        ex = executable_lines(path)
        got = hit.get(path, set()) & ex
        total_exec += len(ex)
        total_hit += len(got)
        pct = 100.0 * len(got) / len(ex) if ex else 100.0
        print(f"{fn:<42}{len(ex):>7}{len(got):>7}{pct:>7.1f}%")
    pct = 100.0 * total_hit / max(1, total_exec)
    print(f"{'TOTAL src/repro/core':<42}{total_exec:>7}{total_hit:>7}"
          f"{pct:>7.1f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
