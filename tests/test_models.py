"""Model zoo: per-arch smoke tests (all 10 assigned architectures at
reduced config), decode/forward consistency, family-specific invariants,
and hypothesis property tests (causality)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import layers as L
from repro.models import xlstm as XL
from repro.models.moe import moe_dense, router_probs
from repro.models.zoo import build_model

KEY = jax.random.key(0)


# ---------------------- per-arch smoke (assigned archs) ----------------------

@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    """Reduced same-family config: one forward + one train step on CPU,
    asserting output shapes and no NaNs (per the brief)."""
    cfg = get_smoke_config(name)
    m = build_model(cfg)
    params = m.init_params(KEY)
    batch = m.make_batch(jax.random.key(1), 2, 32)
    logits = m.forward(params, batch)
    S_out = 32 if cfg.family != "vlm" else 32
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(lambda p: m.loss(p, batch))(params)
    assert bool(jnp.isfinite(loss))
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_configs_match_assignment(name):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(name)
    expected = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49280),  # padded 49155
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_expert_counts():
    m = get_config("moonshot-v1-16b-a3b")
    assert (m.n_experts, m.experts_per_token, m.n_shared_experts) == (64, 6, 2)
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.experts_per_token, q.n_shared_experts) == (128, 8, 0)


def test_param_counts_in_expected_range():
    """Rough sanity: named sizes should be near their advertised params."""
    # NB: targets follow from the ASSIGNED hyperparameters, which for
    # moonshot (48L x 64 experts x d_ff 1408) imply ~29B total (the "16B"
    # in the marketing name corresponds to a different layer count).
    approx = {"granite-8b": 8e9, "granite-34b": 34e9, "gemma2-9b": 9e9,
              "pixtral-12b": 12e9, "moonshot-v1-16b-a3b": 29e9,
              "qwen3-moe-30b-a3b": 30e9, "xlstm-1.3b": 1.3e9,
              "zamba2-7b": 7e9}
    for name, target in approx.items():
        n = get_config(name).param_count()
        assert 0.5 * target < n < 1.7 * target, (name, n / 1e9)
    # MoE active << total
    q = get_config("qwen3-moe-30b-a3b")
    assert q.active_param_count() < 0.25 * q.param_count()


# ---------------------- decode == forward ------------------------------------

@pytest.mark.parametrize("name", ["granite-34b", "gemma2-9b",
                                  "qwen3-moe-30b-a3b", "zamba2-7b",
                                  "xlstm-1.3b", "musicgen-large"])
def test_decode_matches_forward(name):
    cfg = get_smoke_config(name)
    m = build_model(cfg)
    params = m.init_params(KEY)
    S = 12
    batch = m.make_batch(jax.random.key(2), 2, S)
    if "embeds" in batch or "patch_embeds" in batch:
        pytest.skip("token-free frontends covered by smoke test")
    full = m.forward(params, batch)
    cache = m.init_cache(2, S)
    step = jax.jit(m.decode_step)
    errs = []
    for t in range(S):
        pos = jnp.full((2,), t, jnp.int32)
        lg, cache = step(params, cache, batch["tokens"][:, t], pos)
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 2.5e-2, errs


# ---------------------- family invariants -------------------------------------

def test_gemma2_local_differs_from_global():
    """The sliding window must change attention output beyond the window."""
    q = jax.random.normal(KEY, (1, 32, 2, 8))
    k = jax.random.normal(jax.random.key(1), (1, 32, 2, 8))
    v = jax.random.normal(jax.random.key(2), (1, 32, 2, 8))
    pos = jnp.arange(32)
    a_g = L.attention_reference(q, k, v, pos, pos, window=0)
    a_l = L.attention_reference(q, k, v, pos, pos, window=4)
    assert float(jnp.abs(a_g[:, :4] - a_l[:, :4]).max()) < 1e-6
    assert float(jnp.abs(a_g[:, 8:] - a_l[:, 8:]).max()) > 1e-4


def test_softcap_bounds_logits():
    x = jnp.linspace(-1000, 1000, 101)
    assert float(jnp.abs(L.softcap(x, 30.0)).max()) <= 30.0


def test_router_gates_normalized():
    x = jax.random.normal(KEY, (64, 16))
    w = jax.random.normal(jax.random.key(1), (16, 8))
    gates, idx, probs = router_probs(x, w, k=2)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert idx.shape == (64, 2)
    assert int(idx.max()) < 8


def test_moe_dense_matches_manual_combine():
    D, E, F, T = 8, 4, 16, 6
    params = {
        "router": jax.random.normal(KEY, (D, E)),
        "wi": jax.random.normal(jax.random.key(1), (E, D, 2 * F)) * 0.1,
        "wo": jax.random.normal(jax.random.key(2), (E, F, D)) * 0.1,
    }
    x = jax.random.normal(jax.random.key(3), (1, T, D))
    y = moe_dense(x, params, k=2)
    # manual: for each token, run its top-2 experts
    gates, idx, _ = router_probs(x.reshape(T, D), params["router"], 2)
    manual = np.zeros((T, D), np.float32)
    for t in range(T):
        for j in range(2):
            e = int(idx[t, j])
            h = x.reshape(T, D)[t] @ params["wi"][e]
            g, u = np.split(np.asarray(h), 2)
            act = np.asarray(jax.nn.silu(g)) * u
            manual[t] += float(gates[t, j]) * (act @ np.asarray(params["wo"][e]))
    np.testing.assert_allclose(np.asarray(y[0]), manual, rtol=2e-4,
                               atol=2e-5)


def test_mlstm_chunked_equals_stepwise():
    """Chunked mLSTM == its own sequential recurrence."""
    B, S, nh, hd = 2, 16, 2, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, nh, hd))
    k = jax.random.normal(ks[1], (B, S, nh, hd))
    v = jax.random.normal(ks[2], (B, S, nh, hd))
    ig = jax.random.normal(ks[3], (B, S, nh))
    fg = jax.random.normal(ks[4], (B, S, nh)) + 2.0
    h_chunk, (finC, finN) = XL.mlstm_chunked(q, k, v, ig, fg, chunk=4)
    state = (jnp.zeros((B, nh, hd, hd)), jnp.zeros((B, nh, hd)))
    outs = []
    for t in range(S):
        o, state = XL.mlstm_decode_step(q[:, t], k[:, t], v[:, t],
                                        ig[:, t], fg[:, t], state)
        outs.append(o)
    h_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(finC), np.asarray(state[0]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(finN), np.asarray(state[1]),
                               rtol=2e-4, atol=2e-4)


# ---------------------- property: causality -----------------------------------

@settings(max_examples=8, deadline=None)
@given(t_cut=st.integers(2, 10), seed=st.integers(0, 100))
def test_property_causality(t_cut, seed):
    """Perturbing tokens at position >= t_cut must not change logits at
    positions < t_cut (decoder-only causal invariant)."""
    cfg = get_smoke_config("granite-8b")
    m = build_model(cfg)
    params = m.init_params(KEY)
    toks = jax.random.randint(jax.random.key(seed), (1, 12), 0,
                              cfg.vocab_size, jnp.int32)
    toks2 = toks.at[0, t_cut:].set(
        (toks[0, t_cut:] + 7) % cfg.vocab_size)
    l1 = m.forward(params, {"tokens": toks})
    l2 = m.forward(params, {"tokens": toks2})
    np.testing.assert_allclose(np.asarray(l1[0, :t_cut]),
                               np.asarray(l2[0, :t_cut]),
                               rtol=1e-3, atol=1e-3)


def test_gemma2_pair_scan_equals_unrolled():
    """The local/global pair-scan (scan_layers=True) must match the
    python-unrolled loop — the structural trick behind correct gemma2
    FLOP accounting."""
    import dataclasses
    cfg = get_smoke_config("gemma2-9b")
    m_scan = build_model(dataclasses.replace(cfg, scan_layers=True))
    m_unroll = build_model(dataclasses.replace(cfg, scan_layers=False))
    params = m_scan.init_params(KEY)
    batch = m_scan.make_batch(jax.random.key(3), 2, 24)
    a = m_scan.forward(params, batch)
    b = m_unroll.forward(params, batch)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-2, atol=2e-2)   # bf16 order-of-ops


def test_moe_capacity_dropping_grace():
    """With capacity_factor << 1 the EP-style capacity math drops tokens;
    dropped tokens must pass through as zeros in the routed output (the
    residual carries them), never NaN."""
    from repro.compat import AxisType, make_mesh, shard_map
    from repro.models.moe import _ep_local

    D, E, T = 16, 4, 32
    k1, k2, k3 = jax.random.split(KEY, 3)
    xt = jax.random.normal(k1, (T, D))
    router = jax.random.normal(k2, (D, E))
    wi = 0.1 * jax.random.normal(k3, (E, D, 64))
    wo = 0.1 * jax.random.normal(k3, (E, 32, D))
    mesh = make_mesh((1,), ("model",), axis_types=(AxisType.Auto,))
    P = jax.sharding.PartitionSpec
    fn = shard_map(
        lambda x: _ep_local(x, router, wi, wo, k=2, n_experts=E,
                            capacity_factor=0.25, model_axis="model",
                            n_model=1, tokens_replicated=True),
        mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False)
    out = fn(xt)
    assert bool(jnp.isfinite(out).all())
    # some rows must be exactly zero (dropped) at cf=0.25
    row_norms = jnp.linalg.norm(out, axis=-1)
    assert int((row_norms == 0).sum()) > 0


def test_long_500k_config_consistency():
    """long_500k decode state sizes are O(1) in sequence for the two
    long-capable archs (the DESIGN §Arch-applicability requirement)."""
    from repro.configs.shapes import LONG_CAPABLE
    for name in LONG_CAPABLE:
        cfg = get_smoke_config(name)
        m = build_model(cfg)
        c_small = m.init_cache(1, 64)
        c_large = m.init_cache(1, 256)
        import jax as _j
        small = [x.size for x in _j.tree.leaves(c_small)]
        large = [x.size for x in _j.tree.leaves(c_large)]
        # ssm/recurrent states identical; only attention KV (hybrid) grows
        grows = sum(1 for s, l in zip(small, large) if l > s)
        same = sum(1 for s, l in zip(small, large) if l == s)
        assert same >= grows, (name, small, large)
