"""``hypothesis`` when installed, a tiny deterministic fallback otherwise.

CI installs the real library via the ``dev`` extra (``pip install -e
.[dev]``) and gets full shrinking/edge-case generation.  Bare environments
(e.g. an air-gapped container with only the runtime deps) still *collect and
run* every property test: the fallback re-implements just the strategy
surface this suite uses — ``integers``, ``lists``, ``sampled_from`` — and
runs each property ``max_examples`` times with a seeded RNG, so failures
are reproducible even without hypothesis.

Usage (instead of ``from hypothesis import ...``)::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options) -> _Strategy:
            opts = list(options)
            return _Strategy(lambda rng: rng.choice(opts))

        @staticmethod
        def lists(elements: _Strategy, *, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    st = _Strategies()

    def settings(max_examples: int = 20, **_ignored):
        """Record ``max_examples``; other hypothesis knobs are no-ops."""
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            import inspect

            def wrapper(*args, **kwargs):
                # read from the wrapper: @settings is usually stacked
                # *above* @given and annotates the wrapped function
                n = getattr(wrapper, "_compat_max_examples", 20)
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # not functools.wraps: copying __wrapped__ would re-expose the
            # strategy parameters and pytest would treat them as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # expose only the *non*-strategy parameters, so stacking
            # @pytest.mark.parametrize above @given keeps working (pytest
            # resolves fixtures/params from the visible signature), while
            # the drawn strategy arguments stay hidden from it
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            wrapper._compat_max_examples = getattr(
                fn, "_compat_max_examples", 20)
            return wrapper
        return deco
