"""StreamSim behavior: determinism, paper-trend reproduction at reduced
message counts, feasibility gates, conservation."""

import numpy as np

from repro.core.metrics import rtt_cdf, summarize, throughput_msgs_per_s
from repro.core.patterns import run_pattern
from repro.core.simulator import (
    ExperimentSpec, SimParams, StreamSim)
from repro.core.workloads import get_workload

MSGS = 1500


def _run(pattern, arch, wl, nc, seed=0, msgs=MSGS, **kw):
    return run_pattern(pattern, arch, wl, nc, total_messages=msgs,
                       n_runs=1, seed=seed, **kw)[0]


def test_deterministic_given_seed():
    r1 = _run("work_sharing", "dts", "dstream", 4, seed=3)
    r2 = _run("work_sharing", "dts", "dstream", 4, seed=3)
    assert np.array_equal(r1.consume_times, r2.consume_times)
    r3 = _run("work_sharing", "dts", "dstream", 4, seed=4)
    assert not np.array_equal(r1.consume_times, r3.consume_times)


def test_all_messages_consumed():
    r = _run("work_sharing", "mss", "dstream", 8)
    assert r.n_consumed == (MSGS // 8) * 8


def test_clock_monotone_nonnegative():
    r = _run("feedback", "dts", "dstream", 2)
    assert (np.diff(np.sort(r.consume_times)) >= 0).all()
    assert (r.rtts > 0).all()
    assert r.sim_time > 0


def test_stunnel_infeasible_beyond_16():
    r = _run("work_sharing", "prs-stunnel", "dstream", 32)
    assert not r.feasible and "connection limit" in r.infeasible_reason
    assert _run("work_sharing", "prs-stunnel", "dstream", 16).feasible


def test_dts_outperforms_mss_at_scale():
    """Paper Fig 4a: DTS >> MSS in work-sharing throughput at scale."""
    t_dts = throughput_msgs_per_s(_run("work_sharing", "dts", "dstream", 16))
    t_mss = throughput_msgs_per_s(_run("work_sharing", "mss", "dstream", 16))
    assert t_dts > 1.8 * t_mss


def test_stunnel_flat_scaling():
    """Paper: Stunnel shows no improvement beyond one consumer."""
    t1 = throughput_msgs_per_s(
        _run("work_sharing", "prs-stunnel", "dstream", 1))
    t8 = throughput_msgs_per_s(
        _run("work_sharing", "prs-stunnel", "dstream", 8))
    assert t8 < 1.25 * t1


def test_prs_matches_dts_in_feedback():
    """Paper §5.4: PRS performs as well as or better than DTS (vs MSS's
    clear overhead) in the feedback pattern."""
    m_dts = summarize(_run("feedback", "dts", "dstream", 4)).median_rtt_s
    m_prs = summarize(
        _run("feedback", "prs-haproxy", "dstream", 4)).median_rtt_s
    m_mss = summarize(_run("feedback", "mss", "dstream", 4)).median_rtt_s
    assert m_prs < 3.0 * m_dts
    assert m_mss > m_dts


def test_broadcast_copies_scale_with_consumers():
    r2 = _run("broadcast", "dts", "generic", 2, msgs=120)
    r8 = _run("broadcast", "dts", "generic", 8, msgs=120)
    assert r8.n_consumed == 120 * 8
    t2 = throughput_msgs_per_s(r2)
    t8 = throughput_msgs_per_s(r8)
    assert t8 > 2.5 * t2


def test_broadcast_gather_rtt_knee_beyond_4():
    """Paper Fig 7b: <5 s up to 4 consumers, sharp increase beyond."""
    m4 = summarize(_run("broadcast_gather", "dts", "generic", 4,
                        msgs=400)).median_rtt_s
    m16 = summarize(_run("broadcast_gather", "dts", "generic", 16,
                         msgs=400)).median_rtt_s
    assert m4 < 5.0
    assert m16 > 3.0 * m4


def test_rtt_cdf_monotone():
    r = _run("feedback", "mss", "dstream", 4)
    x, q = rtt_cdf(r)
    assert (np.diff(x) >= -1e-12).all() and q[-1] == 1.0


def test_reject_publish_backpressure_counted():
    """Tiny queue memory forces reject-publish; producers must retry and
    all messages still arrive (guaranteed delivery, paper §6)."""
    spec = ExperimentSpec(
        pattern="work_sharing", workload=get_workload("dstream"),
        arch="dts", n_producers=2, n_consumers=2, total_messages=400,
        params=SimParams(seed=0, prefetch=2, consumer_proc_s=5e-3))
    sim = StreamSim(spec)
    for q in sim.broker.queues.values():
        q.max_bytes = 64 * 1024          # ~4 messages deep
    res = sim.run()
    assert res.rejected_publishes > 0
    assert res.n_consumed == 400
