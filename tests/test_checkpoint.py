"""Checkpointing: atomic roundtrip, retention, corruption tolerance,
async writer, and train-resume determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer, latest_checkpoint, restore_checkpoint,
    save_checkpoint)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "scalar": jnp.float32(3.5)}


def test_roundtrip(tmp_path):
    t = _tree()
    path = save_checkpoint(str(tmp_path), 7, t)
    step, restored = restore_checkpoint(path, jax.eval_shape(lambda: t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, _tree(), keep=3)
    assert latest_checkpoint(str(tmp_path)).endswith("step_0000000005")
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3


def test_partial_checkpoint_ignored(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    # simulate a crashed writer: tmp dir + a dir without manifest
    os.makedirs(tmp_path / "step_0000000009.tmp")
    os.makedirs(tmp_path / "step_0000000008")
    assert latest_checkpoint(str(tmp_path)).endswith("step_0000000001")


def test_shape_mismatch_rejected(tmp_path):
    path = save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.zeros((3, 3))})


def test_leaf_count_mismatch_rejected(tmp_path):
    path = save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.zeros(2), "x": jnp.zeros(2)})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20):
        ck.save(s, _tree(s))
    ck.close()
    assert latest_checkpoint(str(tmp_path)).endswith("step_0000000020")
    step, restored = restore_checkpoint(
        latest_checkpoint(str(tmp_path)), jax.eval_shape(lambda: _tree()))
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_tree(20)["w"]))


def test_resume_determinism(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint/restore + 3: identical."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import build_train_step
    from repro.models.zoo import build_model
    from repro.optim import AdamW

    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    step_fn = jax.jit(build_train_step(model, opt, None, microbatches=1))
    batches = [model.make_batch(jax.random.key(i), 2, 16) for i in range(6)]

    p1 = model.init_params(jax.random.key(0))
    s1 = opt.init(p1)
    for b in batches:
        p1, s1, _ = step_fn(p1, s1, b)

    p2 = model.init_params(jax.random.key(0))
    s2 = opt.init(p2)
    for b in batches[:3]:
        p2, s2, _ = step_fn(p2, s2, b)
    path = save_checkpoint(str(tmp_path), 3, (p2, s2))
    _, (p3, s3) = restore_checkpoint(
        path, jax.eval_shape(lambda: (p2, s2)))
    for b in batches[3:]:
        p3, s3, _ = step_fn(p3, s3, b)

    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
