"""Distribution correctness, run in subprocesses with 8 forced host
devices (the parent process must keep seeing 1 device — the brief forbids
setting XLA_FLAGS globally).

Covered:
  * DP x TP train step == single-device numerics
  * MoE expert-parallel (shard_map + all_to_all) == dense oracle
  * decode with a sequence-sharded KV cache == unsharded decode
  * a miniature multi-pod (2,2,2) dry-run lowers AND compiles
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 420) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax
        assert jax.device_count() == {devices}
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_dp_tp_train_step_matches_single_device():
    run_sub("""
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_local_mesh
        from repro.launch.shardings import assemble, opt_state_shardings
        from repro.launch.steps import build_train_step
        from repro.models.zoo import build_model
        from repro.optim import AdamW

        cfg = get_smoke_config("granite-8b")
        model = build_model(cfg)
        opt = AdamW(learning_rate=1e-3)
        params = model.init_params(jax.random.key(0))
        batch = model.make_batch(jax.random.key(1), 4, 16)

        def grad_fn(p, b, ctx):
            return jax.value_and_grad(lambda q: model.loss(q, b, ctx))(p)

        # single-device reference (loss + grads: the distributed compute)
        l_ref, g_ref = jax.jit(lambda p, b: grad_fn(p, b, None))(params,
                                                                 batch)

        # 2x4 DP x TP
        mesh = make_local_mesh(2, 4)
        ctx, sh = assemble(model, mesh, "train", 4, 16)
        l_d, g_d = jax.jit(
            lambda p, b: grad_fn(p, b, ctx),
            in_shardings=(sh["opt_params"], sh["batch"]),
            out_shardings=(None, sh["opt_params"]))(params, batch)
        np.testing.assert_allclose(float(l_ref), float(l_d), rtol=2e-4)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_d)):
            a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
            denom = max(np.abs(a).max(), 1e-6)
            assert np.abs(a - b).max() / denom < 2e-2, np.abs(a - b).max()

        # and the full train step must at least run sharded + finite
        opt_sh = opt_state_shardings(sh["opt_params"], mesh)
        state = opt.init(params)
        step = jax.jit(build_train_step(model, opt, ctx, 1),
                       in_shardings=(sh["opt_params"], opt_sh, sh["batch"]),
                       out_shardings=(sh["opt_params"], opt_sh, None))
        p_d, s_d, m_d = step(params, state, batch)
        assert np.isfinite(float(m_d["loss"]))
        print("DP+TP OK")
    """)


@pytest.mark.slow
def test_moe_ep_matches_dense():
    run_sub("""
        import dataclasses
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_local_mesh
        from repro.models.sharding import ModelContext, default_rules
        from repro.models.moe import moe_block
        from repro.models.zoo import build_model

        cfg = get_smoke_config("qwen3-moe-30b-a3b")
        mesh = make_local_mesh(2, 4)          # EP over model=4 (8 experts)
        rules = default_rules()
        ctx_ep = ModelContext(mesh=mesh, rules=rules, moe_impl="ep")
        k = jax.random.key(0)
        D, E, F = 32, 8, 16
        params = {
            "router": jax.random.normal(k, (D, E)) * 0.5,
            "wi": jax.random.normal(jax.random.key(1), (E, D, 2 * F)) * 0.1,
            "wo": jax.random.normal(jax.random.key(2), (E, F, D)) * 0.1,
        }
        x = jax.random.normal(jax.random.key(3), (8, 16, D), jnp.float32)
        y_dense = moe_block(x, params, k=2, n_experts=E, n_shared=0,
                            capacity_factor=8.0, ctx=None)
        y_ep = moe_block(x, params, k=2, n_experts=E, n_shared=0,
                         capacity_factor=8.0, ctx=ctx_ep)
        # capacity_factor 8 => no drops; EP must equal dense combine
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                                   rtol=2e-4, atol=2e-5)
        print("MoE EP == dense OK")
    """)


@pytest.mark.slow
def test_seq_sharded_decode_matches_unsharded():
    run_sub("""
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_local_mesh
        from repro.launch.shardings import assemble
        from repro.launch.steps import build_serve_step
        from repro.models.zoo import build_model

        cfg = get_smoke_config("granite-34b")     # MQA decode
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        B, T = 4, 32
        cache = model.init_cache(B, T)
        toks = jax.random.randint(jax.random.key(1), (B,), 0,
                                  cfg.vocab_size, jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)

        ref_logits, _ = model.decode_step(params, cache, toks, pos)

        mesh = make_local_mesh(2, 4)              # kv_seq sharded over model
        ctx, sh = assemble(model, mesh, "decode", B, T)
        assert ctx.rules["kv_seq"] == ("model",)
        step = jax.jit(build_serve_step(model, ctx),
                       in_shardings=(sh["params"], sh["cache"],
                                     sh["tokens"], sh["tokens"]),
                       out_shardings=(None, sh["cache"]))
        d_logits, new_cache = step(params, cache, toks, pos)
        np.testing.assert_allclose(np.asarray(ref_logits),
                                   np.asarray(d_logits),
                                   rtol=2e-2, atol=2e-2)
        print("seq-sharded decode OK")
    """)


@pytest.mark.slow
def test_ring_seq_parallel_mlstm_matches_baseline():
    """The affine-state-exchange sequence-parallel mLSTM (§Perf iter 12)
    must match the single-device chunked scan across a 4-way seq shard."""
    run_sub("""
        from repro.configs import get_smoke_config
        from repro.models.zoo import build_model
        from repro.models.sharding import ModelContext
        from repro.launch.shardings import make_rules
        from repro.launch.mesh import make_local_mesh

        cfg = get_smoke_config("xlstm-1.3b")
        m = build_model(cfg)
        p = m.init_params(jax.random.key(0))
        b = m.make_batch(jax.random.key(1), 2, 64)
        ref = m.forward(p, b)
        mesh = make_local_mesh(2, 4)
        rules = make_rules(cfg, mesh, "prefill", 2, parallelism="ring")
        ctx = ModelContext(mesh=mesh, rules=rules)
        out = jax.jit(lambda pp, bb: m.forward(pp, bb, ctx))(p, b)
        err = float(jnp.abs(ref.astype(jnp.float32)
                            - out.astype(jnp.float32)).max())
        assert err < 0.05, err
        print("ring seq-parallel OK", err)
    """)


@pytest.mark.slow
def test_mini_multipod_dryrun_compiles():
    """A (2,2,2) pod mesh version of the dry-run on a reduced config —
    proves the pod axis shards end-to-end inside CI."""
    run_sub("""
        import dataclasses
        from repro.compat import AxisType, make_mesh
        from repro.configs import get_smoke_config
        from repro.launch.shardings import assemble, opt_state_shardings
        from repro.launch.steps import build_train_step
        from repro.models.zoo import build_model
        from repro.optim import AdamW

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)
        cfg = dataclasses.replace(get_smoke_config("granite-8b"),
                                  microbatches=2)
        model = build_model(cfg)
        opt = AdamW()
        ctx, sh = assemble(model, mesh, "train", 8, 32)
        assert ctx.rules["batch"] == ("pod", "data")
        opt_sh = opt_state_shardings(sh["opt_params"], mesh)
        params = model.abstract_params()
        state = jax.eval_shape(opt.init, params)
        batch = model.batch_shapes(8, 32)
        step = build_train_step(model, opt, ctx)
        compiled = jax.jit(step, in_shardings=(sh["opt_params"], opt_sh,
                                               sh["batch"]),
                           out_shardings=(sh["opt_params"], opt_sh, None)
                           ).lower(params, state, batch).compile()
        assert compiled.cost_analysis() is not None
        txt = compiled.as_text()
        assert "all-reduce" in txt or "all-gather" in txt
        print("mini multi-pod dry-run OK")
    """)
