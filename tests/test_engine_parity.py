"""Heap-engine vs vectorized-engine parity on the paper's Fig 4/6/7
metrics: throughput (work sharing), median RTT (feedback), broadcast
throughput + gather RTT — all three architectures at 8 consumers.

Most cells agree within ~1%; two documented residuals (DTS work-sharing
throughput, DTS/PRS gather-leg RTTs) sit within a few percent — see the
Fidelity note in repro/core/vectorized.py.  Bounds here carry margin over
the measured deviations so the suite stays robust across platforms.
"""

import numpy as np
import pytest

from repro.core.metrics import overhead_vs_baseline, summarize
from repro.core.patterns import run_pattern
from repro.core.simulator import ENGINES, SimConfig, SimParams, get_engine

ARCHS = ("dts", "prs-haproxy", "mss")
NC = 8

#: per-cell relative tolerance; the two DTS/PRS outliers are second-order
#: FIFO-interleaving residuals documented in repro.core.vectorized
THR_TOL = {"dts": 0.07, "prs-haproxy": 0.02, "mss": 0.02}
RTT_TOL = {"dts": 0.06, "prs-haproxy": 0.02, "mss": 0.02}
GATHER_RTT_TOL = {"dts": 0.02, "prs-haproxy": 0.07, "mss": 0.02}


def _cell(pattern, arch, wl, msgs, engine, **kw):
    r = run_pattern(pattern, arch, wl, NC, total_messages=msgs, n_runs=1,
                    seed=0, jitter=0.0, engine=engine, **kw)[0]
    assert r.feasible
    return summarize(r)


def _rel(a, b):
    return abs(b - a) / a


@pytest.mark.parametrize("arch", ARCHS)
def test_work_sharing_throughput_parity(arch):
    """Fig 4: aggregate work-sharing throughput."""
    h = _cell("work_sharing", arch, "dstream", 4096, "heap")
    v = _cell("work_sharing", arch, "dstream", 4096, "vectorized")
    assert v.n_messages == h.n_messages == 4096
    assert _rel(h.throughput_msgs_s, v.throughput_msgs_s) < THR_TOL[arch]


@pytest.mark.parametrize("arch", ARCHS)
def test_feedback_rtt_parity(arch):
    """Fig 6: feedback median RTT (and throughput rides along)."""
    h = _cell("feedback", arch, "dstream", 4096, "heap")
    v = _cell("feedback", arch, "dstream", 4096, "vectorized")
    assert _rel(h.median_rtt_s, v.median_rtt_s) < RTT_TOL[arch]
    assert _rel(h.throughput_msgs_s, v.throughput_msgs_s) < 0.02


@pytest.mark.parametrize("arch", ARCHS)
def test_broadcast_gather_parity(arch):
    """Fig 7: broadcast throughput + gather RTT."""
    h = _cell("broadcast_gather", arch, "generic", 400, "heap")
    v = _cell("broadcast_gather", arch, "generic", 400, "vectorized")
    assert v.n_messages == h.n_messages == 400 * NC
    assert _rel(h.throughput_msgs_s, v.throughput_msgs_s) < 0.02
    assert _rel(h.median_rtt_s, v.median_rtt_s) < GATHER_RTT_TOL[arch]


def test_overhead_ratios_preserved():
    """The paper's §5.2 overhead-vs-DTS ratios survive the engine swap."""
    thr = {}
    for eng in ("heap", "vectorized"):
        for arch in ARCHS:
            thr[eng, arch] = _cell(
                "work_sharing", arch, "dstream", 4096, eng).throughput_msgs_s
    for eng in ("heap", "vectorized"):
        ov_mss = overhead_vs_baseline(thr[eng, "mss"], thr[eng, "dts"],
                                      higher_is_better=True)
        ov_prs = overhead_vs_baseline(thr[eng, "prs-haproxy"],
                                      thr[eng, "dts"], higher_is_better=True)
        # paper: MSS pays a clear work-sharing throughput overhead; PRS
        # sits between DTS and MSS
        assert ov_mss > ov_prs > 1.0


def test_vectorized_deterministic_and_seed_sensitive():
    kw = dict(total_messages=2048, n_runs=1, engine="vectorized")
    r1 = run_pattern("work_sharing", "dts", "dstream", NC, seed=3, **kw)[0]
    r2 = run_pattern("work_sharing", "dts", "dstream", NC, seed=3, **kw)[0]
    r3 = run_pattern("work_sharing", "dts", "dstream", NC, seed=4, **kw)[0]
    assert np.array_equal(r1.consume_times, r2.consume_times)
    assert not np.array_equal(r1.consume_times, r3.consume_times)


def test_vectorized_respects_feasibility_gates():
    r = run_pattern("work_sharing", "prs-stunnel", "dstream", 32,
                    total_messages=512, n_runs=1, engine="vectorized")[0]
    assert not r.feasible and "connection limit" in r.infeasible_reason


def test_engine_registry_and_config_alias():
    assert SimConfig is SimParams
    assert SimConfig().engine == "heap"
    assert get_engine("heap") is ENGINES["heap"]
    assert get_engine("vectorized") is ENGINES["vectorized"]
    with pytest.raises(ValueError):
        get_engine("quantum")


def test_vectorized_conserves_messages_across_patterns():
    for pattern, wl, msgs, expect in (
            ("work_sharing", "dstream", 1024, 1024),
            ("feedback", "dstream", 1024, 1024),
            ("broadcast", "generic", 64, 64 * NC),
            ("broadcast_gather", "generic", 64, 64 * NC)):
        r = run_pattern(pattern, "dts", wl, NC, total_messages=msgs,
                        n_runs=1, engine="vectorized")[0]
        assert r.n_consumed == expect, pattern
        if pattern in ("feedback", "broadcast_gather"):
            assert r.rtts.size == expect and (r.rtts > 0).all()
