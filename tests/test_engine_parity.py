"""Heap-engine vs vectorized-engine parity on the paper's Fig 4/6/7
metrics: throughput (work sharing), median RTT (feedback), broadcast
throughput + gather RTT — all three architectures at 8 consumers — plus
an overflow-regime block (reject-publish + credit-flow blocking active)
and property tests of the FIFO-scan carry math.

The previously-documented outliers (DTS work-sharing throughput, DTS/PRS
gather RTTs at ~5-7%) are closed to <=3% by the vectorized engine's
utilization-triggered finer interleaving and its virtual-time window
assignment — see repro/core/vectorized.py.  Bounds here carry margin
over the measured deviations so the suite stays robust across platforms.
"""

import functools

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.metrics import overhead_vs_baseline, summarize
from repro.core.parity import band, factor_band
from repro.core.patterns import (
    OVERFLOW_STRESS_DEFAULTS, average_summaries, overflow_stress,
    run_pattern)
from repro.core.simulator import (
    ENGINES, SimConfig, SimParams, get_engine)
from repro.core.vectorized import _fifo_scan

ARCHS = ("dts", "prs-haproxy", "mss")
NC = 8

#: every engine held to the heap reference's parity bands; the jax
#: engine inherits the vectorized tolerances (same float64 recurrences,
#: re-associated at worst at the 1e-16 level — see docs/engines.md).
#: Without jax importable the jax column drops out (run_many would fall
#: back to vectorized anyway, making the rows redundant).
from repro.core.jax_engine import jax_available  # noqa: E402

VEC_ENGINES = (("vectorized", "jax") if jax_available()
               else ("vectorized",))

#: per-cell relative tolerances, read from the single source of truth
#: in repro.core.parity (the docs table and the streamlint docs-drift
#: rule read the same constants); the residuals that sat at 5-7% (DTS
#: work-sharing throughput, DTS feedback RTT, PRS gather RTT) are closed
#: to <=3% by saturation-triggered fine interleaving + virtual-time
#: window assignment in the batched pump
THR_TOL = {a: band(f"work_sharing.{a}.throughput") for a in ARCHS}
RTT_TOL = {a: band(f"feedback.{a}.median_rtt") for a in ARCHS}
GATHER_RTT_TOL = {a: band(f"broadcast_gather.{a}.gather_rtt")
                  for a in ARCHS}


@functools.lru_cache(maxsize=None)
def _cell(pattern, arch, wl, msgs, engine):
    # cached: the heap reference cells are shared across every
    # parameterized engine comparing against them
    r = run_pattern(pattern, arch, wl, NC, total_messages=msgs, n_runs=1,
                    seed=0, jitter=0.0, engine=engine)[0]
    assert r.feasible
    return summarize(r)


def _rel(a, b):
    return abs(b - a) / a


@pytest.mark.parametrize("engine", VEC_ENGINES)
@pytest.mark.parametrize("arch", ARCHS)
def test_work_sharing_throughput_parity(arch, engine):
    """Fig 4: aggregate work-sharing throughput."""
    h = _cell("work_sharing", arch, "dstream", 4096, "heap")
    v = _cell("work_sharing", arch, "dstream", 4096, engine)
    assert v.n_messages == h.n_messages == 4096
    assert _rel(h.throughput_msgs_s, v.throughput_msgs_s) < THR_TOL[arch]


@pytest.mark.parametrize("engine", VEC_ENGINES)
@pytest.mark.parametrize("arch", ARCHS)
def test_feedback_rtt_parity(arch, engine):
    """Fig 6: feedback median RTT (and throughput rides along)."""
    h = _cell("feedback", arch, "dstream", 4096, "heap")
    v = _cell("feedback", arch, "dstream", 4096, engine)
    assert _rel(h.median_rtt_s, v.median_rtt_s) < RTT_TOL[arch]
    assert _rel(h.throughput_msgs_s,
                v.throughput_msgs_s) < band("feedback.all.throughput")


@pytest.mark.parametrize("engine", VEC_ENGINES)
@pytest.mark.parametrize("arch", ARCHS)
def test_broadcast_gather_parity(arch, engine):
    """Fig 7: broadcast throughput + gather RTT."""
    h = _cell("broadcast_gather", arch, "generic", 400, "heap")
    v = _cell("broadcast_gather", arch, "generic", 400, engine)
    assert v.n_messages == h.n_messages == 400 * NC
    assert _rel(h.throughput_msgs_s, v.throughput_msgs_s) < band(
        "broadcast_gather.all.throughput")
    assert _rel(h.median_rtt_s, v.median_rtt_s) < GATHER_RTT_TOL[arch]


def test_overhead_ratios_preserved():
    """The paper's §5.2 overhead-vs-DTS ratios survive the engine swap."""
    thr = {}
    for eng in ("heap",) + VEC_ENGINES:
        for arch in ARCHS:
            thr[eng, arch] = _cell(
                "work_sharing", arch, "dstream", 4096, eng).throughput_msgs_s
    for eng in ("heap",) + VEC_ENGINES:
        ov_mss = overhead_vs_baseline(thr[eng, "mss"], thr[eng, "dts"],
                                      higher_is_better=True)
        ov_prs = overhead_vs_baseline(thr[eng, "prs-haproxy"],
                                      thr[eng, "dts"], higher_is_better=True)
        # paper: MSS pays a clear work-sharing throughput overhead; PRS
        # sits between DTS and MSS
        assert ov_mss > ov_prs > 1.0


# -- overflow regime: reject-publish + credit-flow blocking ----------------


@functools.lru_cache(maxsize=None)
def _overflow_heap():
    return overflow_stress("dts", 4, jitter=0.0, engine="heap")[0]


#: seed -> solo heap RunResult for the stacked-overflow test below
_STACKED_OVERFLOW_HEAP_CACHE: dict = {}


@pytest.mark.parametrize("engine", VEC_ENGINES)
def test_overflow_regime_parity(engine):
    """A regime the paper's configs never trigger: tight queue caps, a
    small confirm window and slow consumers force reject-publish overflow
    AND credit-flow confirm withholding in the heap engine; the batched
    engines must reproduce throughput and median RTT within 5%
    and the rejected/blocked counters within a small tolerance."""
    h = _overflow_heap()
    v = overflow_stress("dts", 4, jitter=0.0, engine=engine)[0]
    # the heap engine actually exercises both mechanisms
    assert h.rejected_publishes > 0
    assert h.blocked_confirms > 0
    assert v.n_consumed == h.n_consumed
    hs, vs = summarize(h), summarize(v)
    summary_tol = band("overflow.dts.summary")
    counter_tol = band("overflow.dts.counters")
    assert _rel(hs.throughput_msgs_s, vs.throughput_msgs_s) < summary_tol
    assert _rel(hs.median_rtt_s, vs.median_rtt_s) < summary_tol
    # counter parity: both mechanisms fire, with closely matching volume
    assert v.rejected_publishes > 0
    assert v.blocked_confirms > 0
    assert _rel(h.rejected_publishes, v.rejected_publishes) < counter_tol
    assert _rel(h.blocked_confirms, v.blocked_confirms) < counter_tol


@pytest.mark.parametrize("engine", VEC_ENGINES)
def test_stacked_overflow_lanes_match_solo_heap(engine):
    """Stacked execution of an overflow-regime cell is lane-resolved:
    every lane — not just the pilot — must land within tolerance of its
    own solo *heap* run.  Summaries are tight (<=5%); the reject/block
    counters are knife-edge threshold counts that swing with the jitter
    realization in both engines, so they get a factor band plus a
    hard nonzero requirement (both mechanisms must fire in every
    lane)."""
    from repro.core.simulator import ExperimentSpec, run_experiment
    from repro.core.vectorized import run_many
    from repro.core.workloads import get_workload
    from repro.core.broker import ClassicQueue
    wl = get_workload("dstream")
    cap = int(ClassicQueue.FLOW_CREDIT * 4 * 1.06) * wl.payload_bytes
    seeds = (0, 1000, 2000)

    def spec(s, eng):
        return ExperimentSpec(
            pattern="feedback", workload=wl, arch="dts", n_producers=4,
            n_consumers=4, total_messages=8192,
            params=SimParams(seed=s, engine=eng, queue_max_bytes=cap,
                             **OVERFLOW_STRESS_DEFAULTS))

    # the per-seed heap references are shared across the engine params
    cache = _STACKED_OVERFLOW_HEAP_CACHE

    stacked = run_many([spec(s, engine) for s in seeds])
    assert len({id(r) for r in stacked}) == 3
    summary_tol = band("stacked_overflow.lanes.summary")
    rej_lo, rej_hi = factor_band("stacked_overflow.lanes.rejected")
    blk_lo, blk_hi = factor_band("stacked_overflow.lanes.blocked")
    for s, v in zip(seeds, stacked):
        if s not in cache:
            cache[s] = run_experiment(spec(s, "heap"))
        h = cache[s]
        assert h.rejected_publishes > 0 and h.blocked_confirms > 0
        assert v.n_consumed == h.n_consumed == 8192
        hs, vs = summarize(h), summarize(v)
        assert _rel(hs.throughput_msgs_s,
                    vs.throughput_msgs_s) < summary_tol, s
        assert _rel(hs.median_rtt_s, vs.median_rtt_s) < summary_tol, s
        # lane-resolved counters: nonzero in every lane, same order of
        # magnitude as the lane's own heap realization
        assert v.rejected_publishes > 0 and v.blocked_confirms > 0
        assert (rej_lo < v.rejected_publishes / h.rejected_publishes
                < rej_hi), s
        assert (blk_lo < v.blocked_confirms / h.blocked_confirms
                < blk_hi), s


def test_overflow_guaranteed_delivery_both_engines():
    """Rejected publishes are retried until accepted: every message is
    still consumed exactly once (paper §6 guaranteed delivery)."""
    for eng in ("heap",) + VEC_ENGINES:
        r = overflow_stress("dts", 2, total_messages=4096, engine=eng)[0]
        assert r.rejected_publishes > 0, eng
        assert r.n_consumed == 4096, eng


def test_queue_cap_below_one_message_is_infeasible():
    """A cap that cannot hold a single message would otherwise spin on
    reject-retry until max_sim_time and report an empty feasible run."""
    for eng in ("heap",) + VEC_ENGINES:
        r = run_pattern("work_sharing", "dts", "dstream", 2,
                        total_messages=8, n_runs=1, engine=eng,
                        queue_max_bytes=1)[0]
        assert not r.feasible, eng
        assert "queue_max_bytes" in r.infeasible_reason


def test_overflow_regime_scales_on_vectorized():
    """The stress scenario stays exercisable at consumer counts far past
    the paper sweep (vectorized only; the heap engine would need minutes)."""
    r = overflow_stress("dts", 64, queue_cap_msgs=512,
                        total_messages=4096, consumer_proc_s=16e-3,
                        engine="vectorized")[0]
    assert r.feasible and r.n_consumed == 4096
    assert r.rejected_publishes > 0


# -- FIFO-scan carry math (property-tested) --------------------------------


def _fifo_ref(a, h, carry):
    """Sequential reference: e_j = max(a_j, e_{j-1}) + h_j."""
    e = carry
    out = []
    for ai, hi in zip(a, h):
        e = max(ai, e) + hi
        out.append(e)
    return np.array(out)


@settings(max_examples=50)
@given(holds=st.lists(st.floats(min_value=0.0, max_value=5.0),
                      min_size=1, max_size=40),
       gaps=st.lists(st.floats(min_value=0.0, max_value=3.0),
                     min_size=1, max_size=40),
       carry=st.floats(min_value=0.0, max_value=20.0))
def test_fifo_scan_matches_sequential_reference(holds, gaps, carry):
    n = min(len(holds), len(gaps))
    a = np.cumsum(np.asarray(gaps[:n]))          # sorted arrivals
    h = np.asarray(holds[:n])
    got = _fifo_scan(a, h, carry)
    want = _fifo_ref(a, h, carry)
    assert np.allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=50)
@given(holds=st.lists(st.floats(min_value=0.0, max_value=5.0),
                      min_size=2, max_size=40),
       gaps=st.lists(st.floats(min_value=0.0, max_value=3.0),
                     min_size=2, max_size=40),
       cut_frac=st.floats(min_value=0.0, max_value=1.0))
def test_fifo_scan_carry_composes_across_batches(holds, gaps, cut_frac):
    """Serving a FIFO batch in two chunks with the carry threaded through
    equals serving it at once — the invariant the batched engine relies
    on every time a cohort is split at the event horizon."""
    n = min(len(holds), len(gaps))
    a = np.cumsum(np.asarray(gaps[:n]))
    h = np.asarray(holds[:n])
    whole = _fifo_scan(a, h, 0.0)
    k = min(n - 1, max(1, int(n * cut_frac)))
    first = _fifo_scan(a[:k], h[:k], 0.0)
    second = _fifo_scan(a[k:], h[k:], float(first[-1]))
    assert np.allclose(np.concatenate([first, second]), whole,
                       rtol=1e-12, atol=1e-12)


# -- engine selection / config validation ----------------------------------


def test_vectorized_deterministic_and_seed_sensitive():
    kw = dict(total_messages=2048, n_runs=1, engine="vectorized")
    r1 = run_pattern("work_sharing", "dts", "dstream", NC, seed=3, **kw)[0]
    r2 = run_pattern("work_sharing", "dts", "dstream", NC, seed=3, **kw)[0]
    r3 = run_pattern("work_sharing", "dts", "dstream", NC, seed=4, **kw)[0]
    assert np.array_equal(r1.consume_times, r2.consume_times)
    assert not np.array_equal(r1.consume_times, r3.consume_times)


def test_vectorized_respects_feasibility_gates():
    r = run_pattern("work_sharing", "prs-stunnel", "dstream", 32,
                    total_messages=512, n_runs=1, engine="vectorized")[0]
    assert not r.feasible and "connection limit" in r.infeasible_reason


def test_engine_registry_and_vectorized_default():
    assert SimConfig is SimParams
    assert SimConfig().engine == "vectorized"      # the default engine
    assert get_engine("heap") is ENGINES["heap"]
    assert get_engine("vectorized") is ENGINES["vectorized"]
    assert get_engine("jax") is ENGINES["jax"]   # registers without jax
    with pytest.raises(ValueError):
        get_engine("quantum")


def test_simparams_validation():
    with pytest.raises(ValueError, match="unknown engine"):
        SimParams(engine="quantum")
    with pytest.raises(ValueError, match="vec_round"):
        SimParams(vec_round=0)
    with pytest.raises(ValueError, match="exceeds the confirm window"):
        SimParams(vec_round=256, confirm_window=128)
    with pytest.raises(ValueError, match="sub-multiple"):
        SimParams(vec_round=7, confirm_window=128)
    with pytest.raises(ValueError, match="queue_max_bytes"):
        SimParams(queue_max_bytes=0)
    with pytest.raises(ValueError, match="vec_horizon_s"):
        SimParams(vec_horizon_s=-1.0)
    with pytest.raises(ValueError, match="confirm_window"):
        SimParams(confirm_window=1)
    # valid configs construct, including the auto (None) knobs
    assert SimParams().vec_round is None
    assert SimParams(vec_round=8, confirm_window=64).vec_round == 8


def test_run_pattern_validates_overrides():
    with pytest.raises(ValueError):
        run_pattern("work_sharing", "dts", "dstream", 2,
                    total_messages=64, n_runs=1, engine="quantum")
    with pytest.raises(ValueError):
        run_pattern("work_sharing", "dts", "dstream", 2,
                    total_messages=64, n_runs=1, vec_round=0)


def test_average_summaries_mixed_feasibility():
    """A mixed-feasibility cell must not report a single seed's metrics
    as a multi-run mean: average the feasible subset and record n_runs."""
    ok = _cell("work_sharing", "dts", "dstream", 256, "vectorized")
    bad = summarize(run_pattern("work_sharing", "prs-stunnel", "dstream", 32,
                                total_messages=256, n_runs=1,
                                engine="vectorized")[0])
    mixed = average_summaries([ok, bad, ok])
    assert mixed.feasible and mixed.n_runs == 2
    assert np.isclose(mixed.throughput_msgs_s, ok.throughput_msgs_s)
    none = average_summaries([bad, bad])
    assert not none.feasible and none.n_runs == 0


def test_vectorized_conserves_messages_across_patterns():
    for pattern, wl, msgs, expect in (
            ("work_sharing", "dstream", 1024, 1024),
            ("feedback", "dstream", 1024, 1024),
            ("broadcast", "generic", 64, 64 * NC),
            ("broadcast_gather", "generic", 64, 64 * NC)):
        r = run_pattern(pattern, "dts", wl, NC, total_messages=msgs,
                        n_runs=1, engine="vectorized")[0]
        assert r.n_consumed == expect, pattern
        if pattern in ("feedback", "broadcast_gather"):
            assert r.rtts.size == expect and (r.rtts > 0).all()
