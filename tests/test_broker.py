"""Broker semantics: the RabbitMQ behaviors the paper's evaluation relies
on (§4.2/§5.2)."""

from _hypothesis_compat import given, settings, st

from repro.core.broker import BrokerCluster, Message


def mk(n_nodes=3, prefetch=4):
    b = BrokerCluster(n_nodes=n_nodes, default_prefetch=prefetch)
    return b


def test_fifo_single_consumer():
    b = mk()
    b.declare_queue("q")
    b.register_consumer("c0", "q", prefetch=100)
    for i in range(10):
        ok, _ = b.publish(Message("q", 100, headers={"i": i}))
        assert ok
    seen = []
    while (d := b.next_delivery("q")) is not None:
        seen.append(d.message.headers["i"])
    assert seen == list(range(10))


def test_round_robin_across_consumers():
    b = mk()
    b.declare_queue("q")
    for c in range(3):
        b.register_consumer(f"c{c}", "q", prefetch=100)
    for i in range(9):
        b.publish(Message("q", 10))
    got = [b.next_delivery("q").consumer_id for _ in range(9)]
    assert got.count("c0") == got.count("c1") == got.count("c2") == 3


def test_prefetch_window_blocks_delivery():
    b = mk()
    b.declare_queue("q")
    b.register_consumer("c0", "q", prefetch=2)
    for _ in range(5):
        b.publish(Message("q", 10))
    d1 = b.next_delivery("q")
    d2 = b.next_delivery("q")
    assert d1 and d2
    assert b.next_delivery("q") is None          # window full
    b.ack("c0", d1.delivery_tag)
    assert b.next_delivery("q") is not None      # window reopened


def test_ack_multiple():
    b = mk()
    b.declare_queue("q")
    ch = b.register_consumer("c0", "q", prefetch=10)
    for _ in range(6):
        b.publish(Message("q", 10))
    tags = [b.next_delivery("q").delivery_tag for _ in range(6)]
    n = b.ack("c0", tags[3], multiple=True)
    assert n == 4
    assert len(ch.unacked) == 2


def test_reject_publish_overflow_and_recovery():
    b = mk()
    b.declare_queue("q", max_bytes=250)
    b.register_consumer("c0", "q", prefetch=10)
    assert b.publish(Message("q", 100))[0]
    assert b.publish(Message("q", 100))[0]
    ok, _ = b.publish(Message("q", 100))          # 300 > 250
    assert not ok
    assert b.queues["q"].stats.rejected == 1
    d = b.next_delivery("q")
    b.ack("c0", d.delivery_tag)
    assert b.publish(Message("q", 100))[0]        # space again


def test_consumer_crash_redelivers_in_order():
    b = mk()
    b.declare_queue("q")
    b.register_consumer("c0", "q", prefetch=10)
    for i in range(4):
        b.publish(Message("q", 10, headers={"i": i}))
    for _ in range(4):
        b.next_delivery("q")
    n = b.consumer_crash("c0")
    assert n == 4
    b.register_consumer("c1", "q", prefetch=10)
    redelivered = [b.next_delivery("q") for _ in range(4)]
    assert [d.message.headers["i"] for d in redelivered] == [0, 1, 2, 3]
    assert all(d.message.redelivered for d in redelivered)


def test_fanout_atomic_and_copies():
    b = mk()
    for c in range(3):
        b.declare_queue(f"bq{c}")
        b.register_consumer(f"c{c}", f"bq{c}", prefetch=10)
    b.declare_fanout("x", [f"bq{c}" for c in range(3)])
    ok, queues = b.publish(Message("fanout:x", 10))
    assert ok and len(queues) == 3
    ids = {b.next_delivery(f"bq{c}").message.msg_id for c in range(3)}
    assert len(ids) == 3                         # distinct copies


def test_fanout_rejects_when_any_queue_full():
    b = mk()
    b.declare_queue("a", max_bytes=1000)
    b.declare_queue("tiny", max_bytes=5)
    b.declare_fanout("x", ["a", "tiny"])
    ok, _ = b.publish(Message("fanout:x", 10))
    assert not ok
    assert len(b.queues["a"]) == 0               # atomic: nothing enqueued


def test_flow_control_thresholds():
    b = mk()
    q = b.declare_queue("q")
    q.FLOW_CREDIT = 400
    b.publish(Message("q", 1, producer_id="p0"))
    assert not q.flow_blocked
    for _ in range(450):
        b.publish(Message("q", 1, producer_id="p0"))
    assert q.flow_blocked
    assert q.flow_threshold == 400               # one publisher


def test_node_failure_and_rehome():
    b = mk()
    b.declare_queue("q0", home_node=0)
    b.declare_queue("q1", home_node=1)
    lost = b.node_failure(0)
    assert lost == ["q0"]
    b.rehome_queue("q0", 2)
    assert b.queues["q0"].home_node == 2


@settings(max_examples=50, deadline=None)
@given(sizes=st.lists(st.integers(1, 1000), min_size=1, max_size=60),
       prefetch=st.integers(1, 8), n_consumers=st.integers(1, 4))
def test_property_conservation_no_loss(sizes, prefetch, n_consumers):
    """Every accepted message is delivered exactly once and acked —
    conservation under arbitrary publish sizes/consumer counts."""
    b = BrokerCluster(default_prefetch=prefetch)
    b.declare_queue("q", max_bytes=10**9)
    for c in range(n_consumers):
        b.register_consumer(f"c{c}", "q", prefetch=prefetch)
    accepted = 0
    for s in sizes:
        ok, _ = b.publish(Message("q", s))
        accepted += int(ok)
    delivered = 0
    while True:
        d = b.next_delivery("q")
        if d is None:
            progressed = False
            for c in range(n_consumers):
                ch = b.channels[f"c{c}"]
                if ch.unacked:
                    tag = max(ch.unacked)
                    b.ack(f"c{c}", tag, multiple=True)
                    progressed = True
            if not progressed:
                break
            continue
        delivered += 1
    assert delivered == accepted
    assert b.total_ready() == 0 and b.total_unacked() == 0
