"""Jit-boundary unit tests for the JAX engine (``engine="jax"``):

* ``_fifo_scan`` NumPy-vs-JAX elementwise equality on hypothesis
  inputs, solo and lane-stacked — the scan is the same float64 closed
  form (cumsum + running max), so the two engines may differ only by
  re-association noise;
* the **pad-and-mask contract**: pow2 padding with inert values (+inf
  arrivals, zero holds, consumed depart rows) never perturbs a real
  lane — at the kernel level and for whole stacked runs (adding a
  seed-lane leaves the existing lanes bit-identical);
* **scoped x64**: engine kernels compute in float64 with full
  time-arithmetic resolution (a 1e-4 s hold survives a 1e3 s clock)
  while the process-global JAX default stays x32 for the model stack;
* ``run_many``'s per-cell fallback when jax is unavailable, recorded
  on the result (``Summary.engine``).
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import jax_engine
from repro.core.jax_engine import _pow2, jax_available, jax_supported
from repro.core.metrics import summarize
from repro.core.simulator import ExperimentSpec, SimParams
from repro.core.vectorized import _fifo_scan, run_many
from repro.core.workloads import get_workload

requires_jax = pytest.mark.skipif(not jax_available(),
                                  reason="jax not installed")


def _spec(seed, engine="jax", msgs=256, nc=2):
    return ExperimentSpec(
        pattern="feedback", workload=get_workload("dstream"), arch="dts",
        n_producers=nc, n_consumers=nc, total_messages=msgs,
        params=SimParams(seed=seed, engine=engine))


# -- shape bucketing --------------------------------------------------------


def test_pow2_buckets():
    assert [_pow2(n) for n in (0, 1, 2, 3, 4, 5, 17, 64)] == \
        [1, 1, 2, 4, 4, 8, 32, 64]


# -- _fifo_scan: numpy vs jax elementwise ----------------------------------


@requires_jax
@settings(max_examples=40)
@given(holds=st.lists(st.floats(min_value=0.0, max_value=5.0),
                      min_size=1, max_size=33),
       gaps=st.lists(st.floats(min_value=0.0, max_value=3.0),
                     min_size=1, max_size=33),
       carry=st.floats(min_value=0.0, max_value=20.0))
def test_jax_fifo_scan_matches_numpy_1d(holds, gaps, carry):
    """Sizes 1..33 sweep across pow2 pad boundaries, so this is also
    the kernel-level pad-and-mask invariance check."""
    n = min(len(holds), len(gaps))
    a = np.cumsum(np.asarray(gaps[:n]))
    h = np.asarray(holds[:n])
    got = jax_engine._jax_fifo_scan(a, h, carry)
    want = _fifo_scan(a, h, carry)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@requires_jax
@settings(max_examples=25)
@given(holds=st.lists(st.floats(min_value=0.0, max_value=5.0),
                      min_size=1, max_size=20),
       gaps=st.lists(st.floats(min_value=0.0, max_value=3.0),
                     min_size=1, max_size=20),
       scales=st.lists(st.floats(min_value=0.5, max_value=2.0),
                       min_size=2, max_size=5),
       carry=st.floats(min_value=0.0, max_value=10.0))
def test_jax_fifo_scan_matches_numpy_lane_axis(holds, gaps, scales, carry):
    n = min(len(holds), len(gaps))
    sc = np.asarray(scales)
    a = np.cumsum(np.asarray(gaps[:n]))[:, None] * sc[None, :]
    h = np.asarray(holds[:n])[:, None] * sc[None, :]
    got = jax_engine._jax_fifo_scan(a, h, carry * sc)
    want = _fifo_scan(a, h, carry * sc)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@requires_jax
def test_jax_fifo_scan_broadcasts_scalar_hold_and_carry():
    a = np.array([[0.0, 0.0], [1.0, 2.0], [1.5, 4.0]])
    got = jax_engine._jax_fifo_scan(a, 0.5, 0.0)
    want = _fifo_scan(a, np.full_like(a, 0.5), np.zeros(2))
    np.testing.assert_allclose(got, want, rtol=1e-12)


# -- pad-and-mask invariance -----------------------------------------------


@requires_jax
def test_kernel_pads_are_inert():
    """Explicitly widening a kernel call with its documented pad values
    leaves the real prefix bit-identical."""
    K = jax_engine._kernels()
    rng = np.random.default_rng(1)
    a = np.sort(rng.uniform(0, 10, (8, 3)), axis=0)
    h = rng.uniform(0, 1e-3, (8, 3))
    c = np.zeros(3)
    base = np.asarray(K.fifo_scan_lanes(a, h, c))
    ap = np.vstack([a, np.full((8, 3), np.inf)])
    hp = np.vstack([h, np.zeros((8, 3))])
    wide = np.asarray(K.fifo_scan_lanes(ap, hp, c))[:8]
    assert np.array_equal(base, wide)
    # masked depart pops: consumed +inf pad rows never count
    t = np.array([1.0, 3.0, 5.0, np.inf])
    used = np.array([False, False, False, True])
    cnt, last, used2 = K.pop_until(t, used, 4.0)
    assert int(cnt) == 2 and float(last) == 3.0
    assert np.asarray(used2).tolist() == [True, True, False, True]
    t2 = np.concatenate([t, np.full(4, np.inf)])
    u2 = np.concatenate([used, np.ones(4, dtype=bool)])
    cnt2, last2, _ = K.pop_until(t2, u2, 4.0)
    assert int(cnt2) == 2 and float(last2) == 3.0
    assert float(K.next_drain(t, used)) == 1.0


@requires_jax
def test_added_seed_lane_never_perturbs_existing_lanes():
    """Whole-run pad-and-mask invariance: stacking one more seed-lane
    leaves the existing lanes' trajectories bit-identical (overflow
    regime included, so the masked depart store and the admission scan
    both face real flow-control traffic)."""
    from repro.core.patterns import OVERFLOW_STRESS_DEFAULTS
    wl = get_workload("dstream")
    spec = ExperimentSpec(
        pattern="feedback", workload=wl, arch="dts", n_producers=2,
        n_consumers=2, total_messages=512,
        params=SimParams(seed=0, engine="jax",
                         queue_max_bytes=64 * wl.payload_bytes,
                         **OVERFLOW_STRESS_DEFAULTS))
    two = jax_engine.JaxStreamSim(spec, stack_seeds=[0, 7]).run_stacked()
    three = jax_engine.JaxStreamSim(
        spec, stack_seeds=[0, 7, 99]).run_stacked()
    for i in range(2):
        assert np.array_equal(two[i].consume_times,
                              three[i].consume_times), i
        assert two[i].rejected_publishes == three[i].rejected_publishes
        assert two[i].blocked_confirms == three[i].blocked_confirms


# -- scoped x64 -------------------------------------------------------------


@requires_jax
def test_x64_time_arithmetic_roundtrip_without_global_flip():
    import jax
    import jax.numpy as jnp
    global_x64 = jax.config.jax_enable_x64
    # large-magnitude clocks: a 1e-4 s hold on a 1e3 s base survives
    # only in float64 (f32 resolution at 1e3 is ~6e-5 and accumulates)
    a = 1e3 + np.cumsum(np.full(32, 1e-4))
    h = np.full(32, 1e-4)
    got = jax_engine._jax_fifo_scan(a, h, 1e3)
    assert got.dtype == np.float64
    np.testing.assert_allclose(got, _fifo_scan(a, h, 1e3), rtol=0,
                               atol=1e-12)
    assert np.all(np.diff(got) > 0)          # holds never vanish
    # the engine's x64 is scoped per call: the process-global default
    # (the model/kernel stack's x32) is untouched
    assert jax.config.jax_enable_x64 == global_x64
    if not global_x64:
        assert jnp.asarray(1.0).dtype == jnp.float32


# -- engine selection, fallback recording ----------------------------------


@requires_jax
def test_jax_engine_runs_and_records_engine():
    rs = run_many([_spec(0), _spec(1)])
    for r, seed in zip(rs, (0, 1)):
        assert r.feasible and r.n_consumed == 256
        s = summarize(r)
        assert s.engine == "jax", seed


def test_run_many_falls_back_and_records_vectorized(monkeypatch):
    """Without importable jax, run_many reroutes jax cells to the
    vectorized engine and the results say so."""
    monkeypatch.setattr(jax_engine, "jax_available", lambda: False)
    ok, why = jax_supported(_spec(0))
    assert not ok and "jax" in why
    rs = run_many([_spec(0)])
    assert rs[0].feasible
    assert rs[0].spec.params.engine == "vectorized"
    assert summarize(rs[0]).engine == "vectorized"


@requires_jax
def test_jax_matches_vectorized_bitwise_on_smoke_cell():
    """The jax engine is a kernel-layer port of the same arithmetic:
    on a smoke cell the two engines agree to the last bit."""
    j = run_many([_spec(0, "jax")])[0]
    v = run_many([_spec(0, "vectorized")])[0]
    np.testing.assert_allclose(j.consume_times, v.consume_times,
                               rtol=1e-9)
    np.testing.assert_allclose(j.rtts, v.rtts, rtol=1e-9)
    assert j.rejected_publishes == v.rejected_publishes
    assert j.blocked_confirms == v.blocked_confirms


# -- whole-run device loop --------------------------------------------------
# The wave device program (repro.core.jax_device_loop): one lax.scan
# over message generations replaces the per-cohort Python event loop.
# Contracts under test: the jit program computes exactly what its
# NumPy-mirror step loop computes; the pow2 cell-axis padding is inert;
# lane 0 of a stacked run is bit-identical to the solo device run; and
# end-to-end throughput/RTT stay inside the device_loop.* parity bands
# vs the vectorized engine.


def _dl_spec(seed, pattern="feedback", arch="dts", msgs=256, npr=4,
             nc=2, engine="jax", device=True, **ov):
    # confirm_window=32 puts the default feedback cell inside the wave
    # model's validated corridor (2G < W < msgs/producer <= 2W; see
    # _device_loop_ok) so the dispatch-path tests exercise the device
    # program rather than silently falling back to the cohort loop
    ov.setdefault("confirm_window", 32)
    return ExperimentSpec(
        pattern=pattern, workload=get_workload("dstream"), arch=arch,
        n_producers=npr, n_consumers=nc, total_messages=msgs,
        params=SimParams(seed=seed, engine=engine,
                         jax_device_loop=device, **ov))


def _dl_sim(seed, **kw):
    from repro.core.vectorized import VectorizedStreamSim
    kw.setdefault("engine", "vectorized")
    kw.setdefault("device", None)
    return VectorizedStreamSim(_dl_spec(seed, **kw))


@requires_jax
@pytest.mark.parametrize("pattern", [
    # the feedback trace needs a larger (corridor) cell — jit-compile
    # heavy, so it rides the nightly/jax-engine jobs only
    pytest.param("feedback", marks=pytest.mark.slow),
    "work_sharing"])
def test_device_loop_trace_jax_matches_numpy_mirror(pattern):
    """The jit device program and the same step run as a Python loop
    (backend="numpy") produce identical per-step traces — any
    divergence is a jit/vmap artifact, never modeling noise."""
    from repro.core import jax_device_loop as dl
    # feedback needs a corridor cell to pass the regime gate; the
    # work_sharing trace stays tiny for compile time
    sim = _dl_sim(0, pattern=pattern, jitter=0.02,
                  msgs=256 if pattern == "feedback" else 64)
    ok, why = dl._device_loop_ok(sim)
    assert ok, why
    ws = dl.build_static(sim)
    jit = dl.draw_jitter(sim, ws)
    yn = dl.run_wave_trace(ws, jit, backend="numpy")
    yj = dl.run_wave_trace(ws, jit, backend="jax")
    assert set(yn) == set(yj)
    for k in yn:
        np.testing.assert_allclose(yj[k], yn[k], rtol=1e-12, atol=1e-12,
                                   err_msg=k)


@requires_jax
@pytest.mark.slow
def test_device_loop_cell_axis_pads_are_inert():
    """run_wave_cells pads a 3-cell group to 4 by replicating cell 0;
    every real cell's results are bit-identical to its solo device
    run."""
    from repro.core import jax_device_loop as dl
    seeds = (0, 1, 2)
    batched = dl.run_wave_cells(
        [_dl_sim(s, msgs=64, jitter=0.02) for s in seeds])
    for s, rs in zip(seeds, batched):
        solo = dl.run_wave_results(_dl_sim(s, msgs=64, jitter=0.02))
        assert len(rs) == len(solo) == 1
        np.testing.assert_array_equal(rs[0].consume_times,
                                      solo[0].consume_times)
        np.testing.assert_array_equal(rs[0].rtts, solo[0].rtts)


@requires_jax
@pytest.mark.slow
def test_device_loop_stacked_pilot_bit_identical():
    """Lane 0 of a seed-stacked device run equals the solo device run
    bit-for-bit (each lane draws jitter from its own seed stream)."""
    stacked = run_many([_dl_spec(s, jitter=0.02)
                        for s in (0, 1000, 2000)])
    solo = run_many([_dl_spec(0, jitter=0.02)])[0]
    assert all(summarize(r).engine == "jax" for r in stacked)
    np.testing.assert_array_equal(stacked[0].consume_times,
                                  solo.consume_times)
    np.testing.assert_array_equal(stacked[0].rtts, solo.rtts)


@requires_jax
@pytest.mark.slow
@pytest.mark.parametrize("pattern,arch", [
    # feedback rides the device loop only inside its validated
    # corridor, and only on the multi-broker archs (mss feedback is
    # regime-gated; see test_device_loop_regime_gate)
    ("feedback", "dts"), ("work_sharing", "prs-haproxy"),
    ("work_sharing", "dts"), ("work_sharing", "mss")])
def test_device_loop_parity_vs_vectorized(pattern, arch):
    """End-to-end parity of the whole-run device program against the
    vectorized cohort loop, inside the device_loop.* bands."""
    from repro.core import jax_device_loop as dl
    from repro.core.parity import band
    ok, why = dl._device_loop_ok(
        _dl_sim(0, pattern=pattern, arch=arch))
    assert ok, f"cell unexpectedly regime-gated: {why}"
    v = run_many([_dl_spec(0, pattern=pattern, arch=arch,
                           engine="vectorized", device=None)])[0]
    j = run_many([_dl_spec(0, pattern=pattern, arch=arch)])[0]
    assert summarize(j).engine == "jax"
    sv, sj = summarize(v), summarize(j)
    thr_dev = (abs(sj.throughput_msgs_s - sv.throughput_msgs_s)
               / sv.throughput_msgs_s)
    assert thr_dev <= band("device_loop.all.throughput"), (
        f"{pattern}/{arch}: thr dev {thr_dev:.4f}")
    if pattern == "feedback":
        rv, rj = np.median(v.rtts), np.median(j.rtts)
        rtt_dev = abs(rj - rv) / rv
        assert rtt_dev <= band("device_loop.all.median_rtt"), (
            f"{pattern}/{arch}: rtt dev {rtt_dev:.4f}")


@requires_jax
def test_device_loop_dispatch_requires_opt_in():
    """jax_device_loop=None (the default) keeps the cohort-loop jax
    engine; only the explicit True flag dispatches the wave program."""
    from repro.core import jax_device_loop as dl
    sim = _dl_sim(0)
    ok, why = dl._device_loop_ok(sim)
    assert ok, why
    j_default = run_many([_dl_spec(0, device=None)])[0]
    v = run_many([_dl_spec(0, engine="vectorized", device=None)])[0]
    # the cohort jax engine is a kernel port: bitwise-close to
    # vectorized, which the wave program (different schedule) is not
    np.testing.assert_allclose(j_default.consume_times,
                               v.consume_times, rtol=1e-9)


@requires_jax
def test_pallas_pump_kernel_interpret_matches_oracle(monkeypatch):
    """``REPRO_PALLAS=interpret`` routes the pump window assignment
    through the Pallas kernel (interpreter mode on CPU hosts); the full
    device trace must still match the numpy oracle exactly.  Uses a
    shape no other test compiles, so the jit cache cannot serve a
    non-pallas executable for this signature."""
    from repro.core import jax_device_loop as dl
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    if dl.pallas_enabled() != "interpret":
        pytest.skip("jax.experimental.pallas not importable")
    sim = _dl_sim(0, pattern="work_sharing", msgs=96, jitter=0.01)
    ws = dl.build_static(sim)
    jit = dl.draw_jitter(sim, ws)
    yn = dl.run_wave_trace(ws, jit, backend="numpy")
    yj = dl.run_wave_trace(ws, jit, backend="jax")
    for k in sorted(yn):
        np.testing.assert_allclose(yj[k], yn[k], rtol=1e-12,
                                   atol=1e-12, err_msg=k)


def test_device_loop_regime_gate():
    """The regime gate rejects every shape class whose static
    wave schedule measurably diverges from the cohort loop, each with
    a reason naming the offending quantity (gated cells dispatch to
    the per-cohort path; see test_device_loop_dispatch_requires_opt_in
    for the dispatch side)."""
    from repro.core import jax_device_loop as dl

    def why_of(**kw):
        ok, why = dl._device_loop_ok(_dl_sim(0, **kw))
        assert not ok
        return why

    # single-broker mss feedback: structural residuals everywhere
    assert "mss" in why_of(arch="mss")
    # fine generations (G < 4): p16c16 picks G=2
    assert "too fine" in why_of(npr=16, nc=16, msgs=2048,
                                confirm_window=64)
    # hard window stall: W <= 2G
    assert "window-stall" in why_of(confirm_window=16)
    # burst regime: the window never binds (W >= msgs/producer)
    assert "never binds" in why_of(confirm_window=128)
    # reply-lag drift: run much longer than the window (M > 2W)
    assert "drifts" in why_of(msgs=1024)
    # universal run-length clause (any pattern): generation-barrier
    # drift accumulates past 256 msgs/producer
    assert "generation-barrier drift" in why_of(
        pattern="work_sharing", npr=8, nc=8, msgs=4096)
    # work_sharing carries only the run-length gate, no feedback gates
    ok, why = dl._device_loop_ok(
        _dl_sim(0, pattern="work_sharing", npr=16, nc=16, msgs=2048))
    assert ok, why
