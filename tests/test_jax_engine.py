"""Jit-boundary unit tests for the JAX engine (``engine="jax"``):

* ``_fifo_scan`` NumPy-vs-JAX elementwise equality on hypothesis
  inputs, solo and lane-stacked — the scan is the same float64 closed
  form (cumsum + running max), so the two engines may differ only by
  re-association noise;
* the **pad-and-mask contract**: pow2 padding with inert values (+inf
  arrivals, zero holds, consumed depart rows) never perturbs a real
  lane — at the kernel level and for whole stacked runs (adding a
  seed-lane leaves the existing lanes bit-identical);
* **scoped x64**: engine kernels compute in float64 with full
  time-arithmetic resolution (a 1e-4 s hold survives a 1e3 s clock)
  while the process-global JAX default stays x32 for the model stack;
* ``run_many``'s per-cell fallback when jax is unavailable, recorded
  on the result (``Summary.engine``).
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import jax_engine
from repro.core.jax_engine import _pow2, jax_available, jax_supported
from repro.core.metrics import summarize
from repro.core.simulator import ExperimentSpec, SimParams
from repro.core.vectorized import _fifo_scan, run_many
from repro.core.workloads import get_workload

requires_jax = pytest.mark.skipif(not jax_available(),
                                  reason="jax not installed")


def _spec(seed, engine="jax", msgs=256, nc=2):
    return ExperimentSpec(
        pattern="feedback", workload=get_workload("dstream"), arch="dts",
        n_producers=nc, n_consumers=nc, total_messages=msgs,
        params=SimParams(seed=seed, engine=engine))


# -- shape bucketing --------------------------------------------------------


def test_pow2_buckets():
    assert [_pow2(n) for n in (0, 1, 2, 3, 4, 5, 17, 64)] == \
        [1, 1, 2, 4, 4, 8, 32, 64]


# -- _fifo_scan: numpy vs jax elementwise ----------------------------------


@requires_jax
@settings(max_examples=40)
@given(holds=st.lists(st.floats(min_value=0.0, max_value=5.0),
                      min_size=1, max_size=33),
       gaps=st.lists(st.floats(min_value=0.0, max_value=3.0),
                     min_size=1, max_size=33),
       carry=st.floats(min_value=0.0, max_value=20.0))
def test_jax_fifo_scan_matches_numpy_1d(holds, gaps, carry):
    """Sizes 1..33 sweep across pow2 pad boundaries, so this is also
    the kernel-level pad-and-mask invariance check."""
    n = min(len(holds), len(gaps))
    a = np.cumsum(np.asarray(gaps[:n]))
    h = np.asarray(holds[:n])
    got = jax_engine._jax_fifo_scan(a, h, carry)
    want = _fifo_scan(a, h, carry)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@requires_jax
@settings(max_examples=25)
@given(holds=st.lists(st.floats(min_value=0.0, max_value=5.0),
                      min_size=1, max_size=20),
       gaps=st.lists(st.floats(min_value=0.0, max_value=3.0),
                     min_size=1, max_size=20),
       scales=st.lists(st.floats(min_value=0.5, max_value=2.0),
                       min_size=2, max_size=5),
       carry=st.floats(min_value=0.0, max_value=10.0))
def test_jax_fifo_scan_matches_numpy_lane_axis(holds, gaps, scales, carry):
    n = min(len(holds), len(gaps))
    sc = np.asarray(scales)
    a = np.cumsum(np.asarray(gaps[:n]))[:, None] * sc[None, :]
    h = np.asarray(holds[:n])[:, None] * sc[None, :]
    got = jax_engine._jax_fifo_scan(a, h, carry * sc)
    want = _fifo_scan(a, h, carry * sc)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@requires_jax
def test_jax_fifo_scan_broadcasts_scalar_hold_and_carry():
    a = np.array([[0.0, 0.0], [1.0, 2.0], [1.5, 4.0]])
    got = jax_engine._jax_fifo_scan(a, 0.5, 0.0)
    want = _fifo_scan(a, np.full_like(a, 0.5), np.zeros(2))
    np.testing.assert_allclose(got, want, rtol=1e-12)


# -- pad-and-mask invariance -----------------------------------------------


@requires_jax
def test_kernel_pads_are_inert():
    """Explicitly widening a kernel call with its documented pad values
    leaves the real prefix bit-identical."""
    K = jax_engine._kernels()
    rng = np.random.default_rng(1)
    a = np.sort(rng.uniform(0, 10, (8, 3)), axis=0)
    h = rng.uniform(0, 1e-3, (8, 3))
    c = np.zeros(3)
    base = np.asarray(K.fifo_scan_lanes(a, h, c))
    ap = np.vstack([a, np.full((8, 3), np.inf)])
    hp = np.vstack([h, np.zeros((8, 3))])
    wide = np.asarray(K.fifo_scan_lanes(ap, hp, c))[:8]
    assert np.array_equal(base, wide)
    # masked depart pops: consumed +inf pad rows never count
    t = np.array([1.0, 3.0, 5.0, np.inf])
    used = np.array([False, False, False, True])
    cnt, last, used2 = K.pop_until(t, used, 4.0)
    assert int(cnt) == 2 and float(last) == 3.0
    assert np.asarray(used2).tolist() == [True, True, False, True]
    t2 = np.concatenate([t, np.full(4, np.inf)])
    u2 = np.concatenate([used, np.ones(4, dtype=bool)])
    cnt2, last2, _ = K.pop_until(t2, u2, 4.0)
    assert int(cnt2) == 2 and float(last2) == 3.0
    assert float(K.next_drain(t, used)) == 1.0


@requires_jax
def test_added_seed_lane_never_perturbs_existing_lanes():
    """Whole-run pad-and-mask invariance: stacking one more seed-lane
    leaves the existing lanes' trajectories bit-identical (overflow
    regime included, so the masked depart store and the admission scan
    both face real flow-control traffic)."""
    from repro.core.patterns import OVERFLOW_STRESS_DEFAULTS
    wl = get_workload("dstream")
    spec = ExperimentSpec(
        pattern="feedback", workload=wl, arch="dts", n_producers=2,
        n_consumers=2, total_messages=512,
        params=SimParams(seed=0, engine="jax",
                         queue_max_bytes=64 * wl.payload_bytes,
                         **OVERFLOW_STRESS_DEFAULTS))
    two = jax_engine.JaxStreamSim(spec, stack_seeds=[0, 7]).run_stacked()
    three = jax_engine.JaxStreamSim(
        spec, stack_seeds=[0, 7, 99]).run_stacked()
    for i in range(2):
        assert np.array_equal(two[i].consume_times,
                              three[i].consume_times), i
        assert two[i].rejected_publishes == three[i].rejected_publishes
        assert two[i].blocked_confirms == three[i].blocked_confirms


# -- scoped x64 -------------------------------------------------------------


@requires_jax
def test_x64_time_arithmetic_roundtrip_without_global_flip():
    import jax
    import jax.numpy as jnp
    global_x64 = jax.config.jax_enable_x64
    # large-magnitude clocks: a 1e-4 s hold on a 1e3 s base survives
    # only in float64 (f32 resolution at 1e3 is ~6e-5 and accumulates)
    a = 1e3 + np.cumsum(np.full(32, 1e-4))
    h = np.full(32, 1e-4)
    got = jax_engine._jax_fifo_scan(a, h, 1e3)
    assert got.dtype == np.float64
    np.testing.assert_allclose(got, _fifo_scan(a, h, 1e3), rtol=0,
                               atol=1e-12)
    assert np.all(np.diff(got) > 0)          # holds never vanish
    # the engine's x64 is scoped per call: the process-global default
    # (the model/kernel stack's x32) is untouched
    assert jax.config.jax_enable_x64 == global_x64
    if not global_x64:
        assert jnp.asarray(1.0).dtype == jnp.float32


# -- engine selection, fallback recording ----------------------------------


@requires_jax
def test_jax_engine_runs_and_records_engine():
    rs = run_many([_spec(0), _spec(1)])
    for r, seed in zip(rs, (0, 1)):
        assert r.feasible and r.n_consumed == 256
        s = summarize(r)
        assert s.engine == "jax", seed


def test_run_many_falls_back_and_records_vectorized(monkeypatch):
    """Without importable jax, run_many reroutes jax cells to the
    vectorized engine and the results say so."""
    monkeypatch.setattr(jax_engine, "jax_available", lambda: False)
    ok, why = jax_supported(_spec(0))
    assert not ok and "jax" in why
    rs = run_many([_spec(0)])
    assert rs[0].feasible
    assert rs[0].spec.params.engine == "vectorized"
    assert summarize(rs[0]).engine == "vectorized"


@requires_jax
def test_jax_matches_vectorized_bitwise_on_smoke_cell():
    """The jax engine is a kernel-layer port of the same arithmetic:
    on a smoke cell the two engines agree to the last bit."""
    j = run_many([_spec(0, "jax")])[0]
    v = run_many([_spec(0, "vectorized")])[0]
    np.testing.assert_allclose(j.consume_times, v.consume_times,
                               rtol=1e-9)
    np.testing.assert_allclose(j.rtts, v.rtts, rtol=1e-9)
    assert j.rejected_publishes == v.rejected_publishes
    assert j.blocked_confirms == v.blocked_confirms
