"""Edge→HPC streaming data plane: loader assembly, fault tolerance
(consumer crash → redelivery, no event loss), elastic consumers,
backpressure, and the steering feedback loop."""

import time


from repro.core.workloads import DSTREAM, tokens_from_payload
from repro.streaming import (
    EdgeProducer, RealtimeBroker, SteeringFeedback, StreamingDataLoader)


def _producers(broker, n, msgs, rate=2000.0, reply=None):
    ps = []
    for i in range(n):
        pid = f"p{i}"
        p = EdgeProducer(broker, DSTREAM,
                         lambda j, i=i: f"work:{(i + j) % 2}",
                         rate_msgs_s=rate, n_messages=msgs,
                         producer_id=pid,
                         reply_queue=reply(pid) if reply else None)
        ps.append(p.start())
    return ps


def test_loader_batch_assembly_and_determinism():
    broker = RealtimeBroker()
    loader = StreamingDataLoader(broker, DSTREAM, vocab_size=256,
                                 seq_len=16, batch_size=4, n_consumers=2)
    ps = _producers(broker, 2, msgs=20)
    b = loader.next_batch(timeout=15)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 256
    for p in ps:
        p.stop(join=False)
    loader.close()


def test_crash_recovery_no_event_loss():
    """Kill a consumer mid-stream: unacked messages are redelivered and all
    payload content still reaches batches exactly (dedup not needed at the
    ack granularity we use; content-level integrity checked by digest)."""
    broker = RealtimeBroker()
    loader = StreamingDataLoader(broker, DSTREAM, vocab_size=64,
                                 seq_len=8, batch_size=2, n_consumers=2,
                                 ack_batch=4)
    ps = _producers(broker, 2, msgs=30)
    loader.next_batch(timeout=15)
    n_re = loader.crash_consumer("ingest-0")
    loader.add_consumer()
    got = 0
    deadline = time.time() + 20
    while loader.messages_consumed < 40 and time.time() < deadline:
        loader.next_batch(timeout=10)
        got += 1
    assert loader.messages_consumed >= 40
    if n_re:
        assert loader.redeliveries_seen >= 1
    for p in ps:
        p.stop(join=False)
    loader.close()


def test_backpressure_chain():
    """Training stalls (nobody drains batches) -> staging fills -> consumer
    acks stop -> broker queues hold the burst (bounded by prefetch+staging,
    messages are NOT dropped)."""
    broker = RealtimeBroker()
    loader = StreamingDataLoader(broker, DSTREAM, vocab_size=64, seq_len=8,
                                 batch_size=2, n_consumers=1,
                                 prefetch_batches=1)
    ps = _producers(broker, 1, msgs=300, rate=5000.0)
    time.sleep(3.0)
    depth = broker.queue_depth("work:0") + broker.queue_depth("work:1")
    consumed = loader.messages_consumed
    assert depth > 0                      # broker absorbing the burst
    assert consumed < 300                 # loader throttled, not racing
    st = broker.stats("work:0")
    assert st.published > 0
    for p in ps:
        p.stop(join=False)
    loader.close()


def test_feedback_steering_adjusts_rate():
    broker = RealtimeBroker()
    broker.declare_queue("work:0")
    fb = SteeringFeedback(broker, ["p0"])
    p = EdgeProducer(broker, DSTREAM, lambda i: "work:0", rate_msgs_s=200.0,
                     n_messages=0, producer_id="p0",
                     reply_queue=fb.reply_queue("p0"))
    fb.publish_step(1, 2.5, backpressure=True)
    r = p.poll_feedback(timeout=3.0)
    assert r is not None and r["loss"] == 2.5
    assert p.rate == 100.0                # halved by slow_down
    fb.publish_step(2, 2.0, backpressure=False)
    p.poll_feedback(timeout=3.0)
    assert p.rate == 125.0                # sped back up


def test_redelivered_payload_token_identity():
    """A redelivered message maps to identical training tokens — the
    determinism the fault-tolerance story depends on."""
    pay = DSTREAM.payload(seed=5)
    t1 = tokens_from_payload(pay, 512, 64)
    t2 = tokens_from_payload(DSTREAM.payload(seed=5), 512, 64)
    assert (t1 == t2).all()


def test_elastic_consumer_group_controller():
    """FT façade: crash -> redeliver -> respawn -> scale, all logged."""
    from repro.streaming.fault_tolerance import ElasticConsumerGroup
    broker = RealtimeBroker()
    loader = StreamingDataLoader(broker, DSTREAM, vocab_size=64, seq_len=8,
                                 batch_size=2, n_consumers=2)
    ps = _producers(broker, 2, msgs=20)
    group = ElasticConsumerGroup(loader)
    loader.next_batch(timeout=15)
    group.crash("ingest-0")
    group.respawn()
    group.scale_to(4)
    assert group.size == 4
    kinds = [e.kind for e in group.log]
    assert kinds.count("consumer-crash") == 1
    assert kinds.count("consumer-respawn") >= 2
    loader.next_batch(timeout=15)        # still flowing after churn
    for p in ps:
        p.stop(join=False)
    loader.close()
