"""Benchmark cache keying: engine/params-aware keys, loud failure on
legacy-format entries (the bug where an engine switch silently served
stale heap-engine numbers from results/bench_cache.json)."""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.common import (  # noqa: E402
    Cache, LegacyCacheError, cache_key, params_fingerprint, plain_key,
    resolve_engine)
from repro.core.simulator import SimParams  # noqa: E402


def test_cache_key_includes_engine_and_fingerprint():
    kh = cache_key("work_sharing|dts|dstream|8|4096|1", engine="heap")
    kv = cache_key("work_sharing|dts|dstream|8|4096|1", engine="vectorized")
    assert kh != kv
    assert "engine=heap" in kh and "engine=vectorized" in kv
    assert kh.startswith("v2|") and kv.startswith("v2|")


def test_fingerprint_tracks_param_overrides_and_defaults():
    base = params_fingerprint("vectorized")
    assert params_fingerprint("vectorized", prefetch=16) != base
    assert params_fingerprint("vectorized", jitter=0.0) != base
    # stable for identical input
    assert params_fingerprint("vectorized") == base


def test_resolve_engine_defaults_to_simparams_default():
    assert resolve_engine(None) == SimParams().engine == "vectorized"
    assert resolve_engine("heap") == "heap"


def test_legacy_cache_fails_loudly(tmp_path):
    p = tmp_path / "bench_cache.json"
    p.write_text(json.dumps({
        "work_sharing|dts|dstream|8|4096|1|": {"throughput": 1.0}}))
    with pytest.raises(LegacyCacheError, match="legacy-format"):
        Cache(str(p))


def test_versioned_cache_roundtrip_and_key_guard(tmp_path):
    p = tmp_path / "bench_cache.json"
    c = Cache(str(p))
    k = cache_key("cell", engine="vectorized")
    assert c.get_or(k, lambda: {"v": 1}) == {"v": 1}
    # served from disk on reload, no recompute
    c2 = Cache(str(p))
    assert c2.get_or(k, lambda: {"v": 2}) == {"v": 1}
    # unversioned keys are rejected at write time too
    with pytest.raises(LegacyCacheError, match="version prefix"):
        c2.get_or("raw-key", lambda: {})
    assert plain_key("kernels/micro").startswith("v2|")


# ---------------------------------------------------------------------------
# Fallback-engine resolution (the cache-poisoning regression): a jax
# cell the run_many fallback downgrades to vectorized must be keyed as
# vectorized — sharing the entry with the identical genuine-vectorized
# cell — and must never occupy the jax namespace.
# ---------------------------------------------------------------------------


def _force_fallback(monkeypatch):
    from repro.core import jax_engine
    monkeypatch.setattr(jax_engine, "jax_supported",
                        lambda spec: (False, "forced for test"))


def test_cache_key_resolves_fallback_engine(monkeypatch):
    from repro.core.patterns import pattern_spec
    spec = pattern_spec("work_sharing", "dts", "dstream", 2,
                        total_messages=8, engine="jax")
    assert "engine=jax" in cache_key("cell", engine="jax", spec=spec)
    _force_fallback(monkeypatch)
    kf = cache_key("cell", engine="jax", spec=spec)
    assert "engine=jax" not in kf
    # key AND fingerprint match the identical genuine-vectorized cell:
    # same computation, one cache entry
    assert kf == cache_key("cell", engine="vectorized")


def test_cell_key_resolves_fallback_engine(monkeypatch):
    from repro.core.campaign import CellSpec, cell_key
    cj = CellSpec(pattern="work_sharing", arch="dts", workload="dstream",
                  n_consumers=2, total_messages=8, seed=0,
                  overrides=(("engine", "jax"),))
    cv = CellSpec(pattern="work_sharing", arch="dts", workload="dstream",
                  n_consumers=2, total_messages=8, seed=0,
                  overrides=(("engine", "vectorized"),))
    assert "engine=jax" in cell_key(cj)
    _force_fallback(monkeypatch)
    assert cell_key(cj) == cell_key(cv)


def test_fallback_cells_never_poison_jax_namespace(tmp_path, monkeypatch):
    from benchmarks.common import sim_cell
    c = Cache(str(tmp_path / "cache.json"))
    _force_fallback(monkeypatch)
    cell = sim_cell(c, "work_sharing", "dts", "dstream", 2, 64,
                    engine="jax")
    assert cell["feasible"]
    assert all("engine=jax" not in k for k in c.data)
    assert any("engine=vectorized" in k for k in c.data)
    # the identical vectorized cell is a HIT on the fallback's entry
    assert sim_cell(c, "work_sharing", "dts", "dstream", 2, 64,
                    engine="vectorized") == cell
    assert len(c.data) == 1
    # once jax is genuinely available, the jax cell's key lands in the
    # jax namespace — a cache MISS, never served the vectorized numbers
    monkeypatch.undo()
    from repro.core.patterns import pattern_spec
    spec = pattern_spec("work_sharing", "dts", "dstream", 2,
                        total_messages=64, engine="jax")
    kj = cache_key("work_sharing|dts|dstream|2|64|1", engine="jax",
                   spec=spec)
    assert "engine=jax" in kj and kj not in c.data
