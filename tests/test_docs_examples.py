"""Documentation can't drift: every fenced ``python`` code block in
README.md and docs/*.md is extracted and executed (so documented APIs —
``SimConfig``, ``run_pattern``, ``run_many``, the campaign layer — keep
working exactly as written), and every relative markdown link must
resolve to a real file.

A block is skipped only when the line immediately above its fence is
the HTML comment ``<!-- docs-test: skip -->`` (for illustrative
snippets too expensive to run in CI); there are currently none.
"""

import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = sorted([ROOT / "README.md", *(ROOT / "docs").glob("*.md")])

_FENCE = re.compile(
    r"(?P<prefix>^|\n)(?P<skip><!-- docs-test: skip -->\n)?"
    r"```python\n(?P<body>.*?)```", re.DOTALL)


def _blocks():
    out = []
    for path in DOC_FILES:
        text = path.read_text()
        for i, m in enumerate(_FENCE.finditer(text)):
            if m.group("skip"):
                continue
            out.append(pytest.param(
                path, m.group("body"),
                id=f"{path.relative_to(ROOT)}#{i}"))
    return out


def test_docs_exist_and_have_examples():
    assert (ROOT / "docs" / "engines.md").exists()
    assert (ROOT / "docs" / "figures.md").exists()
    assert len(_blocks()) >= 4       # README + both guides carry runnable code


@pytest.mark.parametrize("path,body", _blocks())
def test_docs_python_blocks_execute(path, body):
    """The fenced block must run as-is in a fresh namespace (each block
    is self-contained by construction)."""
    exec(compile(body, f"{path.name}<block>", "exec"), {"__name__": "docs"})


_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)]*)?\)")


@pytest.mark.parametrize("path", DOC_FILES,
                         ids=[str(p.relative_to(ROOT)) for p in DOC_FILES])
def test_docs_relative_links_resolve(path):
    broken = []
    for m in _LINK.finditer(path.read_text()):
        target = m.group(1)
        if "://" in target or target.startswith("mailto:"):
            continue
        if not (path.parent / target).exists():
            broken.append(target)
    assert not broken, f"{path}: broken relative link(s): {broken}"
