"""Multi-tenant deployment models (paper §6's deployment-feasibility
argument made quantitative): per-tenant vhost queue namespacing in the
broker, tenancy topology in both engines across all three
architectures (per-tenant DTS tunnels / PRS shared proxy / MSS managed
broker), producer attribution, fairness metrics, the
patterns.multi_tenant degradation sweep and the cross-architecture
deployment_feasibility study."""

import numpy as np
import pytest

from repro.core.broker import BrokerCluster
from repro.core.jax_engine import jax_available
from repro.core.parity import band
from repro.core.metrics import (
    jain_fairness, summarize, tenant_median_rtts, tenant_throughputs)
from repro.core.patterns import (
    DEPLOYMENT_ARCHS, FeasibilityStudy, TenantPoint, crossover_point,
    deployment_feasibility, multi_tenant)
from repro.core.simulator import (
    ExperimentSpec, SimParams, run_experiment)
from repro.core.workloads import get_workload


#: batched engines held to the heap reference (5% multi-tenant band);
#: the jax column drops out when jax isn't importable
VEC_ENGINES = (("vectorized", "jax") if jax_available()
               else ("vectorized",))


def _mt_spec(T, *, isolation="vhost", arch="mss", ppt=1, cpt=1,
             msgs_per_tenant=128, seed=0, **ov):
    return ExperimentSpec(
        pattern="feedback", workload=get_workload("dstream"), arch=arch,
        n_producers=T * ppt, n_consumers=T * cpt,
        total_messages=T * msgs_per_tenant,
        params=SimParams(seed=seed, **ov),
        tenants=T, tenant_isolation=isolation)


# -- broker vhost namespacing ----------------------------------------------


def test_broker_vhost_namespacing():
    b = BrokerCluster()
    q0 = b.declare_queue("work:0", vhost="t0", max_bytes=1 << 20)
    q1 = b.declare_queue("work:0", vhost="t1", max_bytes=1 << 20)
    plain = b.declare_queue("work:0", max_bytes=1 << 20)
    assert q0.name == "t0/work:0" and q1.name == "t1/work:0"
    assert len({q0.name, q1.name, plain.name}) == 3
    # same base name, independent queues
    b.register_consumer("c0", q0.name)
    from repro.core.broker import Message
    ok, queued = b.publish(Message(routing_key=q0.name, size=64))
    assert ok and queued == [q0.name]
    assert len(q0) == 1 and len(q1) == 0
    assert b.vhost_queues("t0") == ["t0/work:0"]
    # re-declaring in the same vhost returns the same queue
    assert b.declare_queue("work:0", vhost="t0") is q0


# -- spec validation -------------------------------------------------------


def test_tenant_spec_validation():
    with pytest.raises(ValueError, match="evenly divide"):
        _mt_spec(3, cpt=1).__class__(  # 4 producers, 6 consumers, T=4
            pattern="feedback", workload=get_workload("dstream"),
            arch="mss", n_producers=4, n_consumers=6, total_messages=64,
            tenants=4)
    with pytest.raises(ValueError, match="shared.*vhost|vhost.*shared"):
        _mt_spec(2, isolation="partitioned")
    with pytest.raises(ValueError, match="work_sharing/feedback"):
        ExperimentSpec(pattern="broadcast",
                       workload=get_workload("generic"), arch="dts",
                       n_producers=1, n_consumers=4, total_messages=64,
                       tenants=2)
    with pytest.raises(ValueError, match="tenants"):
        _mt_spec(0)


# -- engine support + attribution ------------------------------------------


@pytest.mark.parametrize("engine", ("heap",) + VEC_ENGINES)
@pytest.mark.parametrize("isolation", ["vhost", "shared"])
def test_multi_tenant_conserves_and_attributes(engine, isolation):
    T = 4
    r = run_experiment(_mt_spec(T, isolation=isolation, engine=engine))
    assert r.feasible and r.n_consumed == T * 128
    assert r.consume_producers.size == r.consume_times.size
    assert r.rtt_producers.size == r.rtts.size == T * 128
    # every tenant's requests were consumed and replied exactly
    tenant = r.tenant_of_producer(r.consume_producers)
    assert np.array_equal(np.bincount(tenant, minlength=T),
                          np.full(T, 128))
    thr = tenant_throughputs(r)
    assert thr.shape == (T,) and np.isfinite(thr).all()
    rtt = tenant_median_rtts(r)
    assert (rtt > 0).all()


def test_vhost_isolation_keeps_tenant_work_private():
    """With vhost isolation a tenant's consumer only processes its own
    tenant's messages (heap engine exposes the broker state to check)."""
    from repro.core.simulator import StreamSim
    spec = _mt_spec(4, isolation="vhost", cpt=2, msgs_per_tenant=64,
                    engine="heap")
    sim = StreamSim(spec)
    assert sorted(sim.broker.vhost_queues("t0")) == \
        ["t0/reply:0", "t0/work:0", "t0/work:1"]
    r = sim.run()
    assert r.n_consumed == 4 * 64


#: (arch, isolation) -> solo heap reference, shared across engine params
_MT_HEAP_CACHE: dict = {}


@pytest.mark.parametrize("engine", VEC_ENGINES)
@pytest.mark.parametrize("arch", DEPLOYMENT_ARCHS)
@pytest.mark.parametrize("isolation", ["vhost", "shared"])
def test_multi_tenant_engine_parity(arch, isolation, engine):
    """Fig-style parity on a multi-tenant cell of every deployment
    model (per-tenant DTS tunnels, PRS shared proxy, MSS managed
    broker): each batched engine reproduces the heap engine's
    aggregate metrics within the 5% multi-tenant band."""
    if (arch, isolation) not in _MT_HEAP_CACHE:
        _MT_HEAP_CACHE[arch, isolation] = run_experiment(
            _mt_spec(4, isolation=isolation, arch=arch,
                     engine="heap", jitter=0.0))
    h = _MT_HEAP_CACHE[arch, isolation]
    v = run_experiment(_mt_spec(4, isolation=isolation, arch=arch,
                                engine=engine, jitter=0.0))
    assert h.n_consumed == v.n_consumed
    hs, vs = summarize(h), summarize(v)
    summary_tol = band("multi_tenant.all.summary")
    assert (abs(vs.throughput_msgs_s - hs.throughput_msgs_s)
            / hs.throughput_msgs_s) < summary_tol
    assert (abs(vs.median_rtt_s - hs.median_rtt_s)
            / hs.median_rtt_s) < summary_tol
    # per-tenant views agree too
    ht, vt = tenant_throughputs(h), tenant_throughputs(v)
    assert np.allclose(ht, vt,
                       rtol=band("multi_tenant.all.tenant_throughput"))


# -- tenant-aware DTS topology (per-tenant tunnels + shared gateway) --------


def test_dts_tenant_tunnel_topology():
    """With tenants > 1, DTS routes each tenant through its own
    dedicated tunnel pair, all terminating on the shared facility
    gateway; single-tenant DTS keeps the plain NodePort hop graph."""
    from repro.core.architectures import make_architecture
    a = make_architecture("dts")
    a.configure(4, 4, tenants=4)
    assert a.tenant_paths
    res = a.resources
    assert {"dts_gw_in", "dts_gw_out"} <= set(res)
    assert {f"ttun:{t}" for t in range(4)} <= set(res)
    for t in (0, 3):
        pub = [e.resource for e in a.publish_path(0, 0, 0, tenant=t)]
        assert f"ttun:{t}" in pub and "dts_gw_in" in pub
        assert not any(r and r.startswith("dsn_in:") for r in pub)
        dlv = [e.resource for e in a.delivery_path(0, 0, 0, tenant=t)]
        assert f"ttun:{t}" in dlv and "dts_gw_out" in dlv
    # reply legs ride the replying/receiving client's own tunnel
    rep = [e.resource for e in a.reply_publish_path(0, 0, 0, tenant=2)]
    assert "ttun:2" in rep
    # single-tenant: plain DTS, no tunnels
    b = make_architecture("dts")
    b.configure(4, 4, tenants=1)
    assert not b.tenant_paths
    pub = [e.resource for e in b.publish_path(0, 0, 0)]
    assert "dsn_in:0" in pub
    assert not any(r and r.startswith(("ttun", "dts_gw")) for r in pub)


def test_dts_gateway_service_inflates_with_tenants():
    """The per-tunnel-process gateway overhead (the mechanism that
    hands the high-tenant regime to MSS) grows past the knee."""
    from repro.core.architectures import make_architecture
    small = make_architecture("dts")
    small.configure(2, 2, tenants=2)
    big = make_architecture("dts")
    big.configure(32, 32, tenants=32)
    assert (big.resources["dts_gw_in"].service_s
            > small.resources["dts_gw_in"].service_s)
    assert (big.resources["ttun:0"].service_s
            > small.resources["ttun:0"].service_s)


def test_provision_tenant_tunnels_control_plane_cap():
    """Per-tenant DTS provisioning is where §6's feasibility argument
    bites in the control plane: each tenant's session takes a gateway
    streaming port, and the §3.2 port range refuses past 11 tenants."""
    from repro.core.scistream import (
        STREAM_PORT_RANGE, SciStreamError, provision_tenant_tunnels)
    sessions = provision_tenant_tunnels(4)
    assert len(sessions) == 4
    assert len({s.uid for s in sessions}) == 4
    assert len({s.consumer_proxy.listen_port for s in sessions}) == 4
    cap = STREAM_PORT_RANGE[1] - STREAM_PORT_RANGE[0] + 1
    with pytest.raises(SciStreamError, match="exhausted"):
        provision_tenant_tunnels(cap + 1)


# -- fairness metrics ------------------------------------------------------


def test_jain_fairness_known_values():
    assert jain_fairness([1.0, 1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_fairness([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
    assert np.isnan(jain_fairness([]))
    assert np.isnan(jain_fairness([0.0, 0.0]))


# -- the degradation sweep -------------------------------------------------


def test_multi_tenant_degradation_curve():
    pts = multi_tenant("mss", (1, 4, 16), messages_per_tenant=64,
                       n_runs=2)
    assert [p.tenants for p in pts] == [1, 4, 16]
    assert all(isinstance(p, TenantPoint) and p.feasible and p.n_runs == 2
               for p in pts)
    # uniform tenants through a FIFO fabric share it evenly...
    assert all(p.fairness > 0.95 for p in pts)
    assert all(p.min_max_ratio > 0.7 for p in pts)
    # ...but the shared LB+ingress+broker fabric saturates: per-tenant
    # throughput degrades and RTT inflates as tenants are added
    assert pts[0].degradation == pytest.approx(1.0)
    assert pts[-1].degradation < 0.5
    assert pts[-1].tenant_median_rtt_s > 2.0 * pts[0].tenant_median_rtt_s
    assert pts[-1].tenant_throughput_msgs_s < \
        pts[0].tenant_throughput_msgs_s


def test_degradation_normalized_against_explicit_baseline():
    """Regression: degradation used to be computed against "the sweep's
    first point", so a sweep starting at tenants > 1 silently reported
    degradation=1.0 for its first point.  It is now normalized against
    an explicit baseline cell (default: the 1-tenant deployment), run
    even when the sweep doesn't include it."""
    pts = multi_tenant("mss", (4, 16), messages_per_tenant=64, n_runs=1)
    full = multi_tenant("mss", (1, 4, 16), messages_per_tenant=64,
                        n_runs=1)
    # the 4-tenant point is *not* "no degradation": it matches what the
    # same point reports inside a sweep that does include the baseline
    assert pts[0].degradation < 0.95
    assert pts[0].degradation == pytest.approx(full[1].degradation,
                                               rel=1e-6)
    # an explicit baseline cell pins the reference instead
    rel = multi_tenant("mss", (4, 16), messages_per_tenant=64, n_runs=1,
                       baseline_tenants=4)
    assert rel[0].degradation == pytest.approx(1.0)
    assert rel[1].degradation < 1.0


def test_multi_tenant_shared_vs_vhost_comparable():
    """Shared-queue and vhost layouts carry the same offered load; at
    small tenant counts their aggregate throughput is comparable (the
    contention is in the fabric, not the queue layout)."""
    sh = multi_tenant("mss", (4,), isolation="shared",
                      messages_per_tenant=64, n_runs=1)[0]
    vh = multi_tenant("mss", (4,), isolation="vhost",
                      messages_per_tenant=64, n_runs=1)[0]
    assert sh.feasible and vh.feasible
    assert (abs(sh.tenant_throughput_msgs_s - vh.tenant_throughput_msgs_s)
            / vh.tenant_throughput_msgs_s) < 0.15


# -- the cross-architecture deployment-feasibility study -------------------


def test_deployment_feasibility_three_arch_study():
    """The §6 story end-to-end: one curve per deployment model, DTS
    ahead while its dedicated tunnels have headroom, MSS's shared
    broker overtaking as the DTS gateway saturates — the crossover
    reported with the DTS ingress utilization at that point."""
    st = deployment_feasibility(tenant_counts=(1, 4, 16, 64),
                                messages_per_tenant=64, n_runs=1)
    assert isinstance(st, FeasibilityStudy)
    assert set(st.curves) == set(DEPLOYMENT_ARCHS)
    for arch, pts in st.curves.items():
        assert [p.tenants for p in pts] == [1, 4, 16, 64]
        assert all(p.feasible and p.arch == arch for p in pts)
        # degradation is against the explicit 1-tenant baseline
        assert pts[0].degradation == pytest.approx(1.0)
        assert pts[-1].degradation < 0.25
        # shared fabrics split capacity fairly at every tenant count
        assert all(p.fairness > 0.9 for p in pts)
        # the shared ingress is saturated deep in the sweep
        assert pts[-1].ingress_utilization > 0.9
    dts = {p.tenants: p for p in st.curves["dts"]}
    mss = {p.tenants: p for p in st.curves["mss"]}
    # DTS's minimal-hop tunnels win the single-tenant deployment...
    assert (dts[1].tenant_throughput_msgs_s
            > mss[1].tenant_throughput_msgs_s)
    # ...and MSS's managed fabric wins the 64-tenant one
    assert (mss[64].tenant_throughput_msgs_s
            > dts[64].tenant_throughput_msgs_s)
    assert 1 < st.crossover_tenants < 64
    assert st.crossover_utilization > 0.9
    assert "overtakes" in st.headline()


def test_crossover_point_interpolation_and_edge_cases():
    def pt(arch, T, thr, util=1.0, feasible=True):
        return TenantPoint(T, "vhost", arch, "dstream", feasible,
                           tenant_throughput_msgs_s=thr,
                           ingress_utilization=util)

    a = [pt("dts", 4, 100.0, 0.5), pt("dts", 16, 10.0, 1.0)]
    b = [pt("mss", 4, 50.0), pt("mss", 16, 20.0)]
    T, u = crossover_point(a, b)
    assert 4 < T < 16 and 0.5 < u <= 1.0
    # already crossed at the first common point
    T, u = crossover_point(b, a)
    assert T == 4.0
    # never crosses inside the sweep
    T, u = crossover_point([pt("dts", 4, 10.0)], [pt("mss", 4, 5.0)])
    assert T != T and u != u
    # infeasible points are ignored
    T, u = crossover_point([pt("dts", 4, 1.0, feasible=False)],
                           [pt("mss", 4, 5.0)])
    assert T != T


def test_prs_stunnel_tenants_hit_connection_cap():
    """prs-stunnel past 16 tenants reproduces the paper's missing data
    points: each tenant's producer is a tunnel connection."""
    pts = multi_tenant("prs-stunnel", (8, 32), messages_per_tenant=32,
                       n_runs=1)
    assert pts[0].feasible
    assert not pts[1].feasible
