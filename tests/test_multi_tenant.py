"""Multi-tenant MSS contention (paper §6's multi-user scalability claim
made quantitative): per-tenant vhost queue namespacing in the broker,
tenancy topology in both engines, producer attribution, fairness
metrics, and the patterns.multi_tenant degradation sweep."""

import numpy as np
import pytest

from repro.core.broker import BrokerCluster
from repro.core.metrics import (
    jain_fairness, summarize, tenant_median_rtts, tenant_throughputs)
from repro.core.patterns import TenantPoint, multi_tenant
from repro.core.simulator import (
    ExperimentSpec, SimParams, run_experiment)
from repro.core.workloads import get_workload


def _mt_spec(T, *, isolation="vhost", arch="mss", ppt=1, cpt=1,
             msgs_per_tenant=128, seed=0, **ov):
    return ExperimentSpec(
        pattern="feedback", workload=get_workload("dstream"), arch=arch,
        n_producers=T * ppt, n_consumers=T * cpt,
        total_messages=T * msgs_per_tenant,
        params=SimParams(seed=seed, **ov),
        tenants=T, tenant_isolation=isolation)


# -- broker vhost namespacing ----------------------------------------------


def test_broker_vhost_namespacing():
    b = BrokerCluster()
    q0 = b.declare_queue("work:0", vhost="t0", max_bytes=1 << 20)
    q1 = b.declare_queue("work:0", vhost="t1", max_bytes=1 << 20)
    plain = b.declare_queue("work:0", max_bytes=1 << 20)
    assert q0.name == "t0/work:0" and q1.name == "t1/work:0"
    assert len({q0.name, q1.name, plain.name}) == 3
    # same base name, independent queues
    b.register_consumer("c0", q0.name)
    from repro.core.broker import Message
    ok, queued = b.publish(Message(routing_key=q0.name, size=64))
    assert ok and queued == [q0.name]
    assert len(q0) == 1 and len(q1) == 0
    assert b.vhost_queues("t0") == ["t0/work:0"]
    # re-declaring in the same vhost returns the same queue
    assert b.declare_queue("work:0", vhost="t0") is q0


# -- spec validation -------------------------------------------------------


def test_tenant_spec_validation():
    with pytest.raises(ValueError, match="evenly divide"):
        _mt_spec(3, cpt=1).__class__(  # 4 producers, 6 consumers, T=4
            pattern="feedback", workload=get_workload("dstream"),
            arch="mss", n_producers=4, n_consumers=6, total_messages=64,
            tenants=4)
    with pytest.raises(ValueError, match="shared.*vhost|vhost.*shared"):
        _mt_spec(2, isolation="partitioned")
    with pytest.raises(ValueError, match="work_sharing/feedback"):
        ExperimentSpec(pattern="broadcast",
                       workload=get_workload("generic"), arch="dts",
                       n_producers=1, n_consumers=4, total_messages=64,
                       tenants=2)
    with pytest.raises(ValueError, match="tenants"):
        _mt_spec(0)


# -- engine support + attribution ------------------------------------------


@pytest.mark.parametrize("engine", ["heap", "vectorized"])
@pytest.mark.parametrize("isolation", ["vhost", "shared"])
def test_multi_tenant_conserves_and_attributes(engine, isolation):
    T = 4
    r = run_experiment(_mt_spec(T, isolation=isolation, engine=engine))
    assert r.feasible and r.n_consumed == T * 128
    assert r.consume_producers.size == r.consume_times.size
    assert r.rtt_producers.size == r.rtts.size == T * 128
    # every tenant's requests were consumed and replied exactly
    tenant = r.tenant_of_producer(r.consume_producers)
    assert np.array_equal(np.bincount(tenant, minlength=T),
                          np.full(T, 128))
    thr = tenant_throughputs(r)
    assert thr.shape == (T,) and np.isfinite(thr).all()
    rtt = tenant_median_rtts(r)
    assert (rtt > 0).all()


def test_vhost_isolation_keeps_tenant_work_private():
    """With vhost isolation a tenant's consumer only processes its own
    tenant's messages (heap engine exposes the broker state to check)."""
    from repro.core.simulator import StreamSim
    spec = _mt_spec(4, isolation="vhost", cpt=2, msgs_per_tenant=64,
                    engine="heap")
    sim = StreamSim(spec)
    assert sorted(sim.broker.vhost_queues("t0")) == \
        ["t0/reply:0", "t0/work:0", "t0/work:1"]
    r = sim.run()
    assert r.n_consumed == 4 * 64


@pytest.mark.parametrize("isolation", ["vhost", "shared"])
def test_multi_tenant_engine_parity(isolation):
    """Fig-style parity on a multi-tenant cell: the vectorized engine
    reproduces the heap engine's aggregate metrics."""
    h = run_experiment(_mt_spec(4, isolation=isolation, engine="heap",
                                jitter=0.0))
    v = run_experiment(_mt_spec(4, isolation=isolation,
                                engine="vectorized", jitter=0.0))
    assert h.n_consumed == v.n_consumed
    hs, vs = summarize(h), summarize(v)
    assert (abs(vs.throughput_msgs_s - hs.throughput_msgs_s)
            / hs.throughput_msgs_s) < 0.05
    assert abs(vs.median_rtt_s - hs.median_rtt_s) / hs.median_rtt_s < 0.05
    # per-tenant views agree too
    ht, vt = tenant_throughputs(h), tenant_throughputs(v)
    assert np.allclose(ht, vt, rtol=0.08)


# -- fairness metrics ------------------------------------------------------


def test_jain_fairness_known_values():
    assert jain_fairness([1.0, 1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_fairness([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
    assert np.isnan(jain_fairness([]))
    assert np.isnan(jain_fairness([0.0, 0.0]))


# -- the degradation sweep -------------------------------------------------


def test_multi_tenant_degradation_curve():
    pts = multi_tenant("mss", (1, 4, 16), messages_per_tenant=64,
                       n_runs=2)
    assert [p.tenants for p in pts] == [1, 4, 16]
    assert all(isinstance(p, TenantPoint) and p.feasible and p.n_runs == 2
               for p in pts)
    # uniform tenants through a FIFO fabric share it evenly...
    assert all(p.fairness > 0.95 for p in pts)
    assert all(p.min_max_ratio > 0.7 for p in pts)
    # ...but the shared LB+ingress+broker fabric saturates: per-tenant
    # throughput degrades and RTT inflates as tenants are added
    assert pts[0].degradation == pytest.approx(1.0)
    assert pts[-1].degradation < 0.5
    assert pts[-1].tenant_median_rtt_s > 2.0 * pts[0].tenant_median_rtt_s
    assert pts[-1].tenant_throughput_msgs_s < \
        pts[0].tenant_throughput_msgs_s


def test_multi_tenant_shared_vs_vhost_comparable():
    """Shared-queue and vhost layouts carry the same offered load; at
    small tenant counts their aggregate throughput is comparable (the
    contention is in the fabric, not the queue layout)."""
    sh = multi_tenant("mss", (4,), isolation="shared",
                      messages_per_tenant=64, n_runs=1)[0]
    vh = multi_tenant("mss", (4,), isolation="vhost",
                      messages_per_tenant=64, n_runs=1)[0]
    assert sh.feasible and vh.feasible
    assert (abs(sh.tenant_throughput_msgs_s - vh.tenant_throughput_msgs_s)
            / vh.tenant_throughput_msgs_s) < 0.15
