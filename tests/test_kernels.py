"""Per-kernel validation: Pallas (interpret mode on CPU) vs the pure-jnp
oracles in repro.kernels.ref, swept over shapes/dtypes per the brief."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.key(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


# ---------------------------- flash attention --------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),      # MHA
    (2, 256, 8, 2, 16, 128, 64),     # GQA 4:1
    (1, 192, 4, 1, 64, 64, 64),      # MQA, ragged S/block
    (2, 64, 2, 2, 128, 64, 32),      # TPU-width head_dim
])
def test_flash_attention_sweep(B, S, H, KV, hd, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    pos = jnp.arange(S)
    if S % bq or S % bk:
        pytest.skip("non-divisible block")
    out = ops.flash_attention(q, k, v, pos, pos, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,cap", [(32, 0.0), (0, 50.0), (64, 30.0)])
def test_flash_attention_window_softcap(window, cap):
    B, S, H, hd = 1, 256, 2, 32
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, H, hd), jnp.float32)
    v = _rand(ks[2], (B, S, H, hd), jnp.float32)
    pos = jnp.arange(S)
    out = ops.flash_attention(q, k, v, pos, pos, window=window,
                              logit_cap=cap, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, pos, pos, window=window,
                                   logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_noncausal():
    B, S, H, hd = 1, 128, 2, 16
    ks = jax.random.split(KEY, 3)
    q, k, v = (_rand(ks[i], (B, S, H, hd), jnp.float32) for i in range(3))
    pos = jnp.arange(S)
    out = ops.flash_attention(q, k, v, pos, pos, causal=False, block_q=64,
                              block_k=64)
    want = ref.flash_attention_ref(q, k, v, pos, pos, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------- flash decode -----------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,KV,hd,bk", [
    (2, 256, 4, 4, 32, 64),
    (3, 512, 8, 2, 64, 128),
    (1, 128, 4, 1, 128, 64),
])
def test_flash_decode_sweep(B, T, H, KV, hd, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, H, hd), dtype)
    kc = _rand(ks[1], (B, T, KV, hd), dtype)
    vc = _rand(ks[2], (B, T, KV, hd), dtype)
    pos = jnp.asarray(
        np.random.default_rng(0).integers(1, T - 1, size=(B,)), jnp.int32)
    out = ops.flash_decode(q, kc, vc, pos, block_k=bk)
    want = ref.flash_decode_ref(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_decode_respects_cache_length():
    """Entries beyond pos must not influence the output."""
    B, T, H, hd = 1, 128, 2, 16
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, H, hd), jnp.float32)
    kc = _rand(ks[1], (B, T, H, hd), jnp.float32)
    vc = _rand(ks[2], (B, T, H, hd), jnp.float32)
    pos = jnp.array([40], jnp.int32)
    out1 = ops.flash_decode(q, kc, vc, pos, block_k=32)
    kc2 = kc.at[:, 60:].set(99.0)
    vc2 = vc.at[:, 60:].set(-99.0)
    out2 = ops.flash_decode(q, kc2, vc2, pos, block_k=32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


# ---------------------------- ssd state scan ----------------------------------

@pytest.mark.parametrize("B,nc,nh,hd,N,Q", [
    (1, 2, 1, 4, 8, 16), (2, 4, 3, 8, 16, 32), (1, 8, 2, 16, 32, 64),
])
def test_ssd_state_scan_sweep(B, nc, nh, hd, N, Q):
    ks = jax.random.split(KEY, 4)
    states = _rand(ks[0], (B, nc, nh, hd, N), jnp.float32)
    totals = -jnp.abs(_rand(ks[1], (B, nc, nh), jnp.float32))
    C = _rand(ks[2], (B, nc, Q, N), jnp.float32)
    cum = -jnp.abs(_rand(ks[3], (B, nc, Q, nh), jnp.float32))
    y, fin = ops.ssd_state_scan(states, totals, C, cum)
    yr, finr = ref.ssd_state_scan_ref(states, totals, C, cum)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr),
                               rtol=1e-5, atol=1e-5)


# ---------------------------- rmsnorm ------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 64), (3, 17, 128), (2, 5, 7, 32)])
def test_rmsnorm_sweep(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = _rand(ks[0], shape, dtype)
    w = 0.1 * _rand(ks[1], shape[-1:], jnp.float32)
    out = ops.rmsnorm(x, w, block_rows=4)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 33), d=st.sampled_from([8, 32, 128]),
       seed=st.integers(0, 2**16))
def test_property_rmsnorm_matches_oracle(rows, d, seed):
    x = jax.random.normal(jax.random.key(seed), (rows, d))
    w = jax.random.normal(jax.random.key(seed + 1), (d,)) * 0.1
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, w, block_rows=8)),
        np.asarray(ref.rmsnorm_ref(x, w)), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), s=st.sampled_from([64, 128]),
       h=st.sampled_from([1, 2, 4]))
def test_property_flash_attention_rowsum(seed, s, h):
    """Attention output is a convex combination of V rows: with V = const c,
    output must be exactly c everywhere (softmax rows sum to 1)."""
    ks = jax.random.split(jax.random.key(seed), 2)
    q = jax.random.normal(ks[0], (1, s, h, 16))
    k = jax.random.normal(ks[1], (1, s, h, 16))
    v = jnp.full((1, s, h, 16), 3.5)
    pos = jnp.arange(s)
    out = ops.flash_attention(q, k, v, pos, pos, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), 3.5, rtol=1e-5)
