"""Dry-run machinery units: HLO collective parsing, model-FLOPs math,
analytic memory floor, shape assignments, sharding-rule fallbacks."""

import pytest

import repro.launch.dryrun as dr
from repro.configs import get_config
from repro.configs.shapes import LONG_CAPABLE, shapes_for
from repro.launch.mesh import make_local_mesh
from repro.launch.shardings import make_rules, zero_rules

HLO = """
  %ag = bf16[16,512,128]{2,1,0} all-gather(bf16[1,512,128]{2,1,0} %p), dimensions={0}
  %ar.1 = f32[1024,1024]{1,0} all-reduce(f32[1024,1024]{1,0} %x), to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(f32[512]{0} %y), dimensions={0}
  %a2a = bf16[4,256]{1,0} all-to-all(bf16[4,256]{1,0} %z), dimensions={0}
  %cp.2 = u32[8]{0} collective-permute(u32[8]{0} %w), source_target_pairs={{0,1}}
  %ag.s = (bf16[2,8]{1,0}, bf16[16,8]{1,0}) all-gather-start(bf16[2,8]{1,0} %q)
  %notacoll = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""


def test_collective_parser_counts_and_bytes():
    out = dr.parse_collectives(HLO)
    assert out["all-gather"]["count"] == 2
    assert out["all-gather"]["bytes"] == 16 * 512 * 128 * 2 + (2 * 8 + 16 * 8) * 2
    assert out["all-reduce"]["bytes"] == 1024 * 1024 * 4
    assert out["reduce-scatter"]["bytes"] == 64 * 4
    assert out["all-to-all"]["bytes"] == 4 * 256 * 2
    assert out["collective-permute"]["bytes"] == 8 * 4
    assert sum(v["count"] for v in out.values()) == 6


def test_collective_seconds_weights_allreduce_2x():
    one_gb = {"all-reduce": {"count": 1, "bytes": int(50e9)},
              "all-gather": {"count": 1, "bytes": int(50e9)}}
    t = dr.collective_seconds({**{c: {"count": 0, "bytes": 0}
                                  for c in dr._COLLECTIVES}, **one_gb})
    assert t == pytest.approx(3.0)        # 2x + 1x at 50 GB/s


def test_model_flops_scaling():
    cfg = get_config("granite-8b")
    f_train = dr.model_flops(cfg, "train", 256, 4096)
    f_prefill = dr.model_flops(cfg, "prefill", 256, 4096)
    assert f_train == pytest.approx(3 * f_prefill)
    # MoE: active params not total
    moe = get_config("qwen3-moe-30b-a3b")
    assert dr.model_flops(moe, "train", 8, 128) == pytest.approx(
        6.0 * moe.active_param_count() * 8 * 128)


def test_analytic_memory_positive_and_ordered():
    mesh = make_local_mesh(1, 1)
    cfg = get_config("granite-8b")
    t = dr.analytic_memory_bytes(cfg, "train", 256, 4096, mesh)
    p = dr.analytic_memory_bytes(cfg, "prefill", 32, 32768, mesh)
    d = dr.analytic_memory_bytes(cfg, "decode", 128, 32768, mesh)
    assert t > p > 0 and d > 0


def test_shapes_for_long_capability():
    assert "long_500k" in [s.name for s in shapes_for("zamba2-7b")]
    assert "long_500k" in [s.name for s in shapes_for("xlstm-1.3b")]
    assert "long_500k" not in [s.name for s in shapes_for("granite-8b")]
    assert LONG_CAPABLE == {"zamba2-7b", "xlstm-1.3b"}
    # total baseline cells: 10 archs x 3 + 2 long = 32
    assert sum(len(shapes_for(a)) for a in
               ("musicgen-large", "granite-8b", "granite-34b", "gemma2-9b",
                "granite-3-8b", "zamba2-7b", "moonshot-v1-16b-a3b",
                "qwen3-moe-30b-a3b", "xlstm-1.3b", "pixtral-12b")) == 32


def test_rules_divisibility_fallbacks():
    mesh = make_local_mesh(2, 4)
    # batch 1 cannot shard over data=2 -> replicated, kv_seq takes all axes
    r = make_rules(get_config("zamba2-7b"), mesh, "decode", 1)
    assert r["batch"] is None
    assert r["kv_seq"] == ("data", "model")
    # xlstm train: batch over (data, model)
    r2 = make_rules(get_config("xlstm-1.3b"), mesh, "train", 8)
    assert r2["batch"] == ("data", "model")
    assert r2["vocab"] is None            # model axis consumed by batch
    # gemma2: 16 heads over model=4 shards fine
    r3 = make_rules(get_config("gemma2-9b"), mesh, "train", 8)
    assert r3["heads"] == "model"


def test_zero_rules_shards_d_model():
    mesh = make_local_mesh(2, 2)
    r = make_rules(get_config("granite-8b"), mesh, "train", 8)
    assert r["d_model"] is None
    assert zero_rules(r)["d_model"] == "data"


def test_scan_unit_info_families():
    g = get_config("gemma2-9b")
    units, ov = dr._scan_unit_info(g)
    assert units == 21 and ov(2)["n_layers"] == 4
    z = get_config("zamba2-7b")
    units, ov = dr._scan_unit_info(z)
    assert units == 13
    assert ov(2)["n_layers"] == 2 * 6 + 3
    d = get_config("granite-34b")
    units, _ = dr._scan_unit_info(d)
    assert units == 88
