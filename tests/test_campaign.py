"""Campaign layer: stacked multi-seed engine runs (run_many /
run_stacked), the declarative grid runner, and its fingerprinted cache
resume.  The stacking contract under test: the pilot lane is
bit-identical to a solo run (including its flow-control counters),
every lane conserves messages, non-pilot lanes' summaries stay within a
small tolerance of their solo equivalents (the schedule is the pilot's;
the arithmetic — including credit-backlog accounting, byte-capped
admission and reject-retry cadences — is per-lane), and overflow-regime
cells stack like everything else."""

import numpy as np
import pytest

from repro.core.campaign import CampaignSpec, CellSpec, cell_key, run_campaign
from repro.core.metrics import summarize
from repro.core.parity import band
from repro.core.patterns import sweep
from repro.core.simulator import (
    ExperimentSpec, SimParams, run_experiment)
from repro.core.vectorized import VectorizedStreamSim, run_many
from repro.core.workloads import get_workload

SEEDS = (0, 1000, 2000)


def _spec(seed, pattern="work_sharing", arch="dts", nc=4, msgs=1024, **ov):
    wl = get_workload("generic" if pattern.startswith("broadcast")
                      else "dstream")
    n_producers = 1 if pattern.startswith("broadcast") else nc
    return ExperimentSpec(pattern=pattern, workload=wl, arch=arch,
                          n_producers=n_producers, n_consumers=nc,
                          total_messages=msgs,
                          params=SimParams(seed=seed, **ov))


@pytest.mark.parametrize("pattern,msgs", [("work_sharing", 1024),
                                          ("feedback", 1024),
                                          ("broadcast_gather", 96)])
def test_stacked_pilot_exact_and_lanes_close(pattern, msgs):
    serial = [run_experiment(_spec(s, pattern, msgs=msgs)) for s in SEEDS]
    stacked = run_many([_spec(s, pattern, msgs=msgs) for s in SEEDS])
    # the pilot lane drives scheduling with its own clock: bit-identical
    assert np.array_equal(serial[0].consume_times,
                          stacked[0].consume_times)
    assert np.array_equal(serial[0].rtts, stacked[0].rtts)
    for a, b in zip(serial, stacked):
        assert b.feasible and b.n_consumed == a.n_consumed
        assert b.spec.params.seed == a.spec.params.seed
        sa, sb = summarize(a), summarize(b)
        lane_tol = band("stacked.lanes.summary")
        assert (abs(sb.throughput_msgs_s - sa.throughput_msgs_s)
                / sa.throughput_msgs_s) < lane_tol
        if a.rtts.size:
            assert (b.rtts > 0).all()
            assert (abs(sb.median_rtt_s - sa.median_rtt_s)
                    / sa.median_rtt_s) < lane_tol


def test_stacked_deterministic():
    r1 = run_many([_spec(s) for s in SEEDS])
    r2 = run_many([_spec(s) for s in SEEDS])
    for a, b in zip(r1, r2):
        assert np.array_equal(a.consume_times, b.consume_times)


def test_run_many_mixed_and_fallbacks():
    specs = [
        _spec(0),                                  # stacks with the next
        _spec(1000),
        _spec(0, engine="heap"),                   # heap: per-cell solo
        _spec(0, arch="prs-stunnel", nc=32, msgs=128),   # infeasible
        _spec(0, nc=8),                            # different shape: solo
    ]
    out = run_many(specs)
    assert [r.feasible for r in out] == [True, True, True, False, True]
    assert "connection limit" in out[3].infeasible_reason
    # heap cell really ran on the heap engine's exact path
    ref = run_experiment(_spec(0, engine="heap"))
    assert np.array_equal(out[2].consume_times, ref.consume_times)


def _overflow_specs(msgs=1024, cap_msgs=96, seeds=SEEDS):
    from repro.core.patterns import OVERFLOW_STRESS_DEFAULTS
    wl = get_workload("dstream")
    return [ExperimentSpec(
        pattern="feedback", workload=wl, arch="dts", n_producers=2,
        n_consumers=2, total_messages=msgs,
        params=SimParams(seed=s, queue_max_bytes=cap_msgs * wl.payload_bytes,
                         **OVERFLOW_STRESS_DEFAULTS)) for s in seeds]


def test_overflow_cells_stack_lane_resolved():
    """Overflow-regime cells stack like everything else: the pilot lane
    reproduces its solo run bit-for-bit (admission decisions included),
    and each other lane carries its *own* reject accounting — its own
    clocks and jitter, not a clone of the pilot's counters."""
    specs = _overflow_specs()
    stacked = run_many(specs)
    solo = run_experiment(specs[0])
    assert stacked[0].rejected_publishes == solo.rejected_publishes > 0
    assert np.array_equal(stacked[0].consume_times, solo.consume_times)
    assert np.array_equal(stacked[0].rtts, solo.rtts)
    for r in stacked:
        assert r.feasible and r.n_consumed == specs[0].total_messages
        assert r.rejected_publishes > 0
    # non-pilot lanes genuinely diverge (their own jitter streams drive
    # their own admission clocks)
    for r in stacked[1:]:
        assert not np.array_equal(r.consume_times, stacked[0].consume_times)


def test_credit_flow_cells_stack_lane_resolved():
    """Credit-flow blocking can fire without a byte cap (work queues
    always track the credit threshold); those cells stack too, with the
    pilot's blocked_confirms equal to its solo run and every lane
    reporting its own count."""
    from repro.core.patterns import OVERFLOW_STRESS_DEFAULTS
    specs = [_spec(s, "feedback", nc=2, msgs=2048,
                   **OVERFLOW_STRESS_DEFAULTS) for s in SEEDS]
    stacked = run_many(specs)
    solo = run_experiment(specs[0])
    assert stacked[0].blocked_confirms == solo.blocked_confirms > 0
    assert np.array_equal(stacked[0].consume_times, solo.consume_times)
    for r in stacked:
        assert r.feasible and r.n_consumed == 2048
        assert r.blocked_confirms > 0


def test_stack_seeds_single_lane_equals_solo_overflow():
    """``stack_seeds=[s]`` must equal ``seed=s`` exactly, including on a
    flow-control-reachable cell (the lane-resolved admission path
    collapses to the solo path at one lane)."""
    spec = _overflow_specs(seeds=(7,))[0]
    solo = run_experiment(spec)
    stacked = VectorizedStreamSim(spec, stack_seeds=[7]).run_stacked()
    assert len(stacked) == 1
    assert np.array_equal(solo.consume_times, stacked[0].consume_times)
    assert np.array_equal(solo.rtts, stacked[0].rtts)
    assert np.array_equal(solo.publish_starts, stacked[0].publish_starts)
    assert solo.rejected_publishes == stacked[0].rejected_publishes > 0
    assert solo.blocked_confirms == stacked[0].blocked_confirms


def test_stacked_overflow_pilot_determinism_regression():
    """Lane 0 of a stacked overflow run stays bit-identical to the solo
    vectorized run — every scheduling *and admission* decision is the
    pilot's own, no matter how many lanes ride along."""
    specs = _overflow_specs(msgs=768, seeds=(0, 1000, 2000, 3000))
    solo = run_experiment(specs[0])
    pilot = VectorizedStreamSim(
        specs[0], stack_seeds=[s.params.seed for s in specs]
    ).run_stacked()[0]
    assert np.array_equal(solo.consume_times, pilot.consume_times)
    assert np.array_equal(solo.rtts, pilot.rtts)
    assert np.array_equal(solo.publish_starts, pilot.publish_starts)
    assert solo.rejected_publishes == pilot.rejected_publishes
    assert solo.blocked_confirms == pilot.blocked_confirms


def test_stacked_constructor_validation():
    with pytest.raises(ValueError, match="pilot"):
        VectorizedStreamSim(_spec(0), stack_seeds=[1, 0])
    sim = VectorizedStreamSim(_spec(0), stack_seeds=[0, 1])
    with pytest.raises(RuntimeError, match="run_stacked"):
        sim.run()


# -- the declarative grid + runner ----------------------------------------


def test_campaign_cells_and_per_cell_overrides():
    spec = CampaignSpec(
        name="t", patterns=("work_sharing", "feedback"),
        architectures=("dts",), consumers=(2, 4), n_runs=2,
        total_messages=256, params={"prefetch": 32},
        cell_params=[({"pattern": "feedback"}, {"ack_batch": 2}),
                     ({"pattern": "feedback", "n_consumers": 4},
                      {"prefetch": 16})])
    cells = spec.cells()
    assert len(cells) == 2 * 2 * 2
    by = {(c.pattern, c.n_consumers, c.seed): dict(c.overrides)
          for c in cells}
    assert by[("work_sharing", 2, 0)] == {"prefetch": 32}
    assert by[("feedback", 2, 0)] == {"prefetch": 32, "ack_batch": 2}
    assert by[("feedback", 4, 1000)] == {"prefetch": 16, "ack_batch": 2}
    # JSON round trip preserves the grid
    again = CampaignSpec.from_json(spec.to_json())
    assert [cell_key(c) for c in again.cells()] == \
        [cell_key(c) for c in cells]


def test_campaign_mixed_arch_tenant_grid():
    """A tenant sweep crossed over several architectures builds one
    cell per (arch x tenants x seed) — the §6 deployment grid — and
    runs them through the batched runner."""
    spec = CampaignSpec(
        name="deploy-mini", patterns=("feedback",),
        architectures=("dts", "mss"), consumers=(4,),
        tenants=(1, 2, 4), tenant_isolation="vhost",
        n_runs=2, total_messages=256)
    cells = spec.cells()
    assert len(cells) == 2 * 3 * 2
    assert {(c.arch, c.tenants) for c in cells} == \
        {(a, t) for a in ("dts", "mss") for t in (1, 2, 4)}
    assert all(c.tenant_isolation == "vhost" for c in cells)
    # seeds of one (arch, tenants) cell group and stack together
    groups = {c.group_key() for c in cells}
    assert len(groups) == 6
    res = run_campaign(spec, workers=0)
    assert len(res.summaries) == len(cells)
    assert all(s.feasible for s in res.summaries)
    assert len(res.averaged) == 6
    assert all(s.n_runs == 2 for s in res.averaged)


def test_campaign_tenant_grid_validation_rejects_ambiguous_combos():
    """Mixing tenants > 1 with broadcast patterns or non-dividing
    consumer counts is rejected upfront with the combo named (not a
    late ExperimentSpec error deep in the grid)."""
    with pytest.raises(ValueError, match="broadcast"):
        CampaignSpec(name="bad", patterns=("feedback", "broadcast_gather"),
                     tenants=(1, 2), consumers=(4,)).cells()
    with pytest.raises(ValueError, match=r"\(6, 4\).*evenly divide"):
        CampaignSpec(name="bad2", patterns=("feedback",),
                     architectures=("dts", "mss"),
                     consumers=(4, 6), tenants=(1, 4)).cells()
    # run_campaign's upfront validation surfaces the same error
    with pytest.raises(ValueError, match="evenly divide"):
        run_campaign(CampaignSpec(name="bad3", patterns=("feedback",),
                                  consumers=(6,), tenants=(4,)),
                     workers=0)
    # tenants=1 everywhere: unaffected
    assert CampaignSpec(name="ok", patterns=("broadcast_gather",),
                        consumers=(6,), tenants=(1,)).cells()


def test_cell_key_versioned_and_distinct():
    c = CellSpec(pattern="work_sharing", arch="dts", workload="dstream",
                 n_consumers=4, total_messages=256, seed=0)
    k = cell_key(c)
    assert k.startswith("v2|engine=vectorized|")
    import dataclasses
    assert cell_key(dataclasses.replace(c, seed=1)) != k
    assert cell_key(dataclasses.replace(
        c, overrides=(("prefetch", 16),))) != k


def test_campaign_matches_serial_sweep():
    spec = CampaignSpec(name="t", patterns=("work_sharing",),
                        architectures=("dts", "mss"), consumers=(4,),
                        n_runs=3, total_messages=768)
    res = run_campaign(spec, workers=0)
    serial = sweep("work_sharing", ("dts", "mss"), "dstream",
                   consumers=(4,), n_runs=3, total_messages=768)
    assert len(res.cells) == 6 and len(res.averaged) == 2
    by = {(s.arch, s.n_consumers): s for s in res.averaged}
    for s in serial:
        c = by[(s.arch, s.n_consumers)]
        assert c.n_runs == s.n_runs == 3
        assert (abs(c.throughput_msgs_s - s.throughput_msgs_s)
                / s.throughput_msgs_s) < band("stacked.lanes.summary")


def test_campaign_cache_resume(tmp_path):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import Cache
    spec = CampaignSpec(name="t", patterns=("work_sharing",),
                        architectures=("dts",), consumers=(2,),
                        n_runs=2, total_messages=256)
    cache = Cache(str(tmp_path / "cache.json"))
    r1 = run_campaign(spec, cache=cache, workers=0)
    assert r1.n_cached == 0
    # second run: everything served from the cache, nothing re-run
    cache2 = Cache(str(tmp_path / "cache.json"))
    r2 = run_campaign(spec, cache=cache2, workers=0)
    assert r2.n_cached == len(r2.cells) == 2
    for a, b in zip(r1.summaries, r2.summaries):
        assert a.throughput_msgs_s == b.throughput_msgs_s
    # changing a knob changes the fingerprint: cache misses again
    spec2 = CampaignSpec(name="t", patterns=("work_sharing",),
                         architectures=("dts",), consumers=(2,),
                         n_runs=2, total_messages=256,
                         params={"prefetch": 16})
    assert run_campaign(spec2, cache=cache2, workers=0).n_cached == 0


def test_average_summaries_keeps_fractional_reject_means():
    """int(np.mean(...)) used to floor a rare-overflow cell's mean
    reject count (e.g. one seed with 1 reject out of 3) to an invisible
    0 — the means must stay float."""
    from repro.core.metrics import Summary
    from repro.core.patterns import average_summaries
    base = dict(arch="dts", pattern="feedback", workload="dstream",
                n_producers=2, n_consumers=2, feasible=True)
    avg = average_summaries([Summary(**base, rejected=1, blocked=0),
                             Summary(**base, rejected=0, blocked=2),
                             Summary(**base, rejected=0, blocked=0)])
    assert avg.rejected == pytest.approx(1 / 3)
    assert avg.blocked == pytest.approx(2 / 3)
    assert avg.n_runs == 3


def test_campaign_group_is_the_cache_unit(tmp_path):
    """A partially-cached group must re-run whole: serving the partial
    hits would re-stack the remaining seeds behind a different pilot
    lane, making cached numbers depend on where a campaign was
    interrupted."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import Cache
    spec = CampaignSpec(name="t", patterns=("work_sharing",),
                        architectures=("dts",), consumers=(2,),
                        n_runs=2, total_messages=256)
    cache = Cache(str(tmp_path / "cache.json"))
    cold = run_campaign(spec, cache=cache, workers=0)
    # drop one seed's entry: the group is now partial
    victim = cell_key(cold.cells[1])
    del cache.data[victim]
    cache.save()
    resumed = run_campaign(spec, cache=Cache(str(tmp_path / "cache.json")),
                           workers=0)
    assert resumed.n_cached == 0            # whole group re-ran
    for a, b in zip(cold.summaries, resumed.summaries):
        assert a.throughput_msgs_s == b.throughput_msgs_s


def test_campaign_validates_grid_upfront():
    bad = CampaignSpec(name="t", patterns=("feedback",),
                       architectures=("dts",), consumers=(8,),
                       n_runs=1, total_messages=64, tenants=(3,))
    with pytest.raises(ValueError, match="evenly divide"):
        run_campaign(bad, workers=0)
    with pytest.raises(KeyError):
        run_campaign(CampaignSpec(name="t", workloads=("dstreamm",),
                                  n_runs=1, total_messages=64), workers=0)


def test_campaign_infeasible_cells_reported():
    spec = CampaignSpec(name="t", patterns=("work_sharing",),
                        architectures=("prs-stunnel",), consumers=(32,),
                        n_runs=2, total_messages=128)
    res = run_campaign(spec, workers=0)
    assert all(not s.feasible for s in res.summaries)
    assert not res.averaged[0].feasible and res.averaged[0].n_runs == 0


def test_campaign_engine_validated_at_construction():
    # a bad engine name fails when the spec is built, naming the source,
    # not as a bare SimParams error from deep inside run_campaign
    with pytest.raises(ValueError,
                       match=r"campaign 'bad'.*params.*unknown engine 'jaxx'"):
        CampaignSpec(name="bad", params={"engine": "jaxx"})
    with pytest.raises(
            ValueError,
            match=r"cell_params\[1\].*'arch': 'mss'.*unknown engine 'nope'"):
        CampaignSpec(name="bad2",
                     cell_params=[({"arch": "dts"}, {"prefetch": 2}),
                                  ({"arch": "mss"}, {"engine": "nope"})])
    # every registered name (importable without constructing) is fine
    ok = CampaignSpec(name="ok", params={"engine": "jax"},
                      cell_params=[({"arch": "dts"}, {"engine": "heap"})])
    assert ok.cells()
    # the from_json path re-validates too
    with pytest.raises(ValueError, match="unknown engine"):
        CampaignSpec.from_json(
            CampaignSpec(name="rt").to_json().replace(
                '"params": {}', '"params": {"engine": "typo"}'))


def test_campaign_fallback_counted_and_warned(monkeypatch):
    """A jax campaign in a jax-less environment runs vectorized — the
    result must say so (n_fallback + RuntimeWarning), never silently."""
    from repro.core import jax_engine
    monkeypatch.setattr(jax_engine, "jax_supported",
                        lambda spec: (False, "forced for test"))
    spec = CampaignSpec(name="t", patterns=("work_sharing",),
                        consumers=(2,), n_runs=2, total_messages=64,
                        params={"engine": "jax"})
    with pytest.warns(RuntimeWarning, match="fell back"):
        res = run_campaign(spec, workers=0)
    assert res.n_fallback == len(res.cells) == 2
    assert all(s.engine == "vectorized" for s in res.summaries)
    blob = __import__("json").loads(res.to_json())
    assert blob["n_fallback"] == 2
    # and the cells are keyed under the engine that actually ran
    assert all("engine=vectorized" in c["key"] for c in blob["cells"])


def test_campaign_no_fallback_no_warning():
    spec = CampaignSpec(name="t", patterns=("work_sharing",),
                        consumers=(2,), n_runs=1, total_messages=64)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        res = run_campaign(spec, workers=0)
    assert res.n_fallback == 0
