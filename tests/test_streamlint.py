"""streamlint's own suite: per-rule good/bad fixture pairs on synthetic
trees, suppression-comment semantics, the CLI/JSON surface, and a
self-check that the live tree is violation-free (modulo justified
suppressions).

Fixture trees mirror the repo layout the default ``Config`` expects
(``src/repro/core/...``), written into ``tmp_path`` — the analyzer
never imports the code under test, so the snippets only have to parse.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT))

from tools.streamlint import run_analysis  # noqa: E402

HEAP = "src/repro/core/simulator.py"
VEC = "src/repro/core/vectorized.py"
JAX = "src/repro/core/jax_engine.py"
CAMPAIGN = "src/repro/core/campaign.py"
PARITY = "src/repro/core/parity.py"
DOC = "docs/engines.md"


def lint(tmp_path, tree, paths=("src",), only=None):
    """Write a fixture tree, run the analyzer, return unsuppressed
    diagnostics."""
    for rel, text in tree.items():
        full = tmp_path / rel
        full.parent.mkdir(parents=True, exist_ok=True)
        full.write_text(textwrap.dedent(text))
    analysis = run_analysis(tmp_path, paths, only=only)
    return analysis.failures


def rules_of(diags):
    return {d.rule for d in diags}


# -- SL1xx: engine-contract symmetry ---------------------------------------

HEAP_OK = """
    import dataclasses

    @dataclasses.dataclass
    class RunResult:
        spec: object
        feasible: bool
        infeasible_reason: str = ""
        rtts: object = None
        sim_time: float = 0.0

    class StreamSim:
        def run(self):
            return RunResult(spec=self.spec, feasible=True,
                             rtts=[], sim_time=1.0)

    ENGINES = {}
    ENGINES["heap"] = StreamSim
"""

VEC_OK = """
    class VectorizedStreamSim:
        def _result(self):
            return RunResult(spec=self.spec, feasible=True,
                             rtts=[], sim_time=2.0)

    def run_many(specs):
        return [RunResult(spec=s, feasible=False,
                          infeasible_reason="nope") for s in specs]

    ENGINES = {}
    ENGINES["vectorized"] = VectorizedStreamSim
"""

JAX_OK = """
    class JaxStreamSim(VectorizedStreamSim):
        pass

    ENGINES = {}
    ENGINES["jax"] = JaxStreamSim
"""


def test_sl101_vectorized_missing_heap_field(tmp_path):
    vec_bad = VEC_OK.replace("rtts=[], ", "")
    diags = lint(tmp_path, {HEAP: HEAP_OK, VEC: vec_bad, JAX: JAX_OK},
                 only={"SL101"})
    assert rules_of(diags) == {"SL101"}
    assert "'rtts'" in diags[0].message
    assert not lint(tmp_path, {VEC: VEC_OK}, only={"SL101"})


def test_sl102_heap_missing_vectorized_field(tmp_path):
    vec_bad = VEC_OK.replace("sim_time=2.0", "sim_time=2.0, extra=1")
    diags = lint(tmp_path, {HEAP: HEAP_OK, VEC: vec_bad, JAX: JAX_OK},
                 only={"SL102"})
    assert rules_of(diags) == {"SL102"}
    assert "'extra'" in diags[0].message


def test_sl103_field_nobody_populates(tmp_path):
    heap_bad = HEAP_OK.replace(
        "sim_time: float = 0.0",
        "sim_time: float = 0.0\n        ghost: int = 0")
    diags = lint(tmp_path, {HEAP: heap_bad, VEC: VEC_OK, JAX: JAX_OK},
                 only={"SL103"})
    assert rules_of(diags) == {"SL103"}
    assert "'ghost'" in diags[0].message
    # infeasible_reason is exempt: feasible constructions never pass it
    assert not lint(tmp_path, {HEAP: HEAP_OK}, only={"SL103"})


def test_sl104_jax_neither_subclasses_nor_constructs(tmp_path):
    jax_bad = """
        class JaxStreamSim:
            pass

        ENGINES = {}
        ENGINES["jax"] = JaxStreamSim
    """
    diags = lint(tmp_path, {HEAP: HEAP_OK, VEC: VEC_OK, JAX: jax_bad},
                 only={"SL104"})
    assert rules_of(diags) == {"SL104"}
    # subclassing the vectorized engine is the sanctioned handling
    assert not lint(tmp_path, {JAX: JAX_OK}, only={"SL104"})


def test_sl104_jax_incomplete_own_construction(tmp_path):
    jax_bad = """
        class JaxStreamSim:
            def run(self):
                return RunResult(spec=self.spec, feasible=True,
                                 sim_time=3.0)

        ENGINES = {}
        ENGINES["jax"] = JaxStreamSim
    """
    diags = lint(tmp_path, {HEAP: HEAP_OK, VEC: VEC_OK, JAX: jax_bad},
                 only={"SL104"})
    assert rules_of(diags) == {"SL104"}
    assert "'rtts'" in diags[0].message


# -- SL2xx: cache-key completeness -----------------------------------------

SIM_SPECS = """
    import dataclasses

    @dataclasses.dataclass
    class SimParams:
        seed: int = 0
        window_bytes: int = 1024

    @dataclasses.dataclass
    class ExperimentSpec:
        pattern: str = "work_sharing"
        arch: str = "dts"
"""

CAMPAIGN_OK = """
    import dataclasses

    def params_fingerprint(params):
        return repr(sorted(params.__dict__.items()))

    @dataclasses.dataclass
    class CellSpec:
        pattern: str = "work_sharing"
        arch: str = "dts"

        def experiment(self):
            return ExperimentSpec(pattern=self.pattern, arch=self.arch)

    def cell_key(cell):
        return f"{cell.pattern}|{cell.arch}"
"""


def test_sl201_fingerprint_missing_field(tmp_path):
    camp_bad = CAMPAIGN_OK.replace(
        "return repr(sorted(params.__dict__.items()))",
        "return repr(params.seed)")
    diags = lint(tmp_path, {HEAP: SIM_SPECS, CAMPAIGN: camp_bad},
                 only={"SL201"})
    assert rules_of(diags) == {"SL201"}
    assert "'window_bytes'" in diags[0].message
    # covering __dict__ is field-complete by construction
    assert not lint(tmp_path, {CAMPAIGN: CAMPAIGN_OK}, only={"SL201"})


def test_sl202_cell_key_missing_field(tmp_path):
    camp_bad = CAMPAIGN_OK.replace('f"{cell.pattern}|{cell.arch}"',
                                   'f"{cell.pattern}"')
    diags = lint(tmp_path, {HEAP: SIM_SPECS, CAMPAIGN: camp_bad},
                 only={"SL202"})
    assert rules_of(diags) == {"SL202"}
    assert "'arch'" in diags[0].message


def test_sl202_experiment_expansion_counts_as_coverage(tmp_path):
    # cell_key that calls cell.experiment() inherits whatever the
    # expansion reads off self
    camp = CAMPAIGN_OK.replace('f"{cell.pattern}|{cell.arch}"',
                               'repr(cell.experiment())')
    assert not lint(tmp_path, {HEAP: SIM_SPECS, CAMPAIGN: camp},
                    only={"SL202"})


def test_sl203_experiment_spec_field_not_threaded(tmp_path):
    camp_bad = CAMPAIGN_OK.replace(
        "ExperimentSpec(pattern=self.pattern, arch=self.arch)",
        "ExperimentSpec(pattern=self.pattern)")
    diags = lint(tmp_path, {HEAP: SIM_SPECS, CAMPAIGN: camp_bad},
                 only={"SL203"})
    assert rules_of(diags) == {"SL203"}
    assert "'arch'" in diags[0].message
    assert not lint(tmp_path, {CAMPAIGN: CAMPAIGN_OK}, only={"SL203"})


# -- SL3xx: jit/x64 purity -------------------------------------------------


def test_sl301_global_x64_flip(tmp_path):
    bad = """
        import jax
        jax.config.update("jax_enable_x64", True)
    """
    diags = lint(tmp_path, {"src/somewhere.py": bad}, only={"SL301"})
    assert rules_of(diags) == {"SL301"}
    good = """
        from jax.experimental import enable_x64

        def build():
            with enable_x64():
                return 1
    """
    assert not lint(tmp_path, {"src/somewhere.py": good}, only={"SL301"})


def test_sl302_host_sync_in_jitted_kernel(tmp_path):
    bad = """
        import jax
        import numpy as np

        @jax.jit
        def kernel(x):
            return float(x.sum()) + np.asarray(x)[0]
    """
    diags = lint(tmp_path, {JAX: bad}, only={"SL302"})
    assert rules_of(diags) == {"SL302"}
    assert len(diags) == 2  # float() and np.asarray()
    # the same code outside a jitted def is host code: fine
    good = bad.replace("@jax.jit\n        ", "")
    assert not lint(tmp_path, {JAX: good}, only={"SL302"})


def test_sl302_wrapped_name_counts_as_jitted(tmp_path):
    # x64(jax.vmap(fifo1)) marks fifo1 jitted through the transform
    bad = """
        import jax

        def x64(fn):
            return fn

        def fifo1(a):
            return a.item()

        scan = x64(jax.vmap(fifo1))
    """
    diags = lint(tmp_path, {JAX: bad}, only={"SL302"})
    assert rules_of(diags) == {"SL302"}


def test_sl303_data_dependent_branch(tmp_path):
    bad = """
        import jax

        @jax.jit
        def kernel(x, flag):
            if flag:
                return x
            while x.sum() > 0:
                x = x - 1
            return x
    """
    diags = lint(tmp_path, {JAX: bad}, only={"SL303"})
    assert rules_of(diags) == {"SL303"}
    assert len(diags) == 2  # the if and the while
    # shape/ndim dispatch resolves at trace time: allowed
    good = """
        import jax

        @jax.jit
        def kernel(x, m):
            if x.ndim == 2:
                m = m[:, None]
            if len(x.shape) > 1 and x.shape[0] > 4:
                return x + m
            return x * m
    """
    assert not lint(tmp_path, {JAX: good}, only={"SL303"})


# -- SL4xx: determinism ----------------------------------------------------


def test_sl401_stdlib_random(tmp_path):
    bad = "import random\n"
    diags = lint(tmp_path, {"src/repro/core/x.py": bad}, only={"SL401"})
    assert rules_of(diags) == {"SL401"}
    # outside the determinism scope (engine paths) it is not flagged
    assert not lint(tmp_path, {"src/repro/core/x.py": "x = 1\n",
                               "src/other/x.py": bad}, only={"SL401"})


def test_sl402_unseeded_rng(tmp_path):
    bad = """
        import numpy as np
        rng = np.random.default_rng()
        legacy = np.random.randint(0, 10)
    """
    diags = lint(tmp_path, {"src/repro/core/x.py": bad}, only={"SL402"})
    assert len(diags) == 2
    good = """
        import numpy as np
        rng = np.random.default_rng(1234)
    """
    assert not lint(tmp_path, {"src/repro/core/x.py": good},
                    only={"SL402"})


def test_sl403_wall_clock(tmp_path):
    bad = """
        import time
        t0 = time.time()
        t1 = time.perf_counter()
    """
    diags = lint(tmp_path, {"src/repro/core/x.py": bad}, only={"SL403"})
    assert len(diags) == 2


def test_sl404_set_iteration(tmp_path):
    bad = """
        def f(xs):
            for x in set(xs):
                yield x
            return [y for y in {1, 2, 3}]
    """
    diags = lint(tmp_path, {"src/repro/core/x.py": bad}, only={"SL404"})
    assert len(diags) == 2
    good = """
        def f(xs):
            for x in sorted(set(xs)):
                yield x
    """
    assert not lint(tmp_path, {"src/repro/core/x.py": good},
                    only={"SL404"})


# -- SL5xx: doc/test tolerance drift ---------------------------------------

PARITY_FIX = """
    PARITY_BANDS: dict = {
        "work_sharing.dts.throughput": 0.03,
    }
    FACTOR_BANDS: dict = {
        "overflow.lanes.rejected": (0.3, 3.0),
    }
"""

DOC_FIX = """
    | Cell | Metric | Bound | Band id |
    |---|---|---|---|
    | work sharing | throughput | <= 3% | `band:work_sharing.dts.throughput` |
    | overflow counters | rejected | 0.3-3x | `band:overflow.lanes.rejected` |
"""


def test_sl501_docs_bound_mismatch(tmp_path):
    doc_bad = DOC_FIX.replace("<= 3%", "<= 5%")
    diags = lint(tmp_path, {PARITY: PARITY_FIX, DOC: doc_bad},
                 only={"SL501"})
    assert rules_of(diags) == {"SL501"}
    assert "3%" in diags[0].message
    assert not lint(tmp_path, {DOC: DOC_FIX}, only={"SL501"})


def test_sl501_unknown_band_id(tmp_path):
    doc_bad = DOC_FIX + \
        "| ghost | x | <= 1% | `band:no.such.band` |\n"
    diags = lint(tmp_path, {PARITY: PARITY_FIX, DOC: doc_bad},
                 only={"SL501"})
    assert any("no.such.band" in d.message for d in diags)


def test_sl501_factor_band_mismatch(tmp_path):
    doc_bad = DOC_FIX.replace("0.3-3x", "0.1-9x")
    diags = lint(tmp_path, {PARITY: PARITY_FIX, DOC: doc_bad},
                 only={"SL501"})
    assert rules_of(diags) == {"SL501"}


def test_sl502_undocumented_band(tmp_path):
    parity_more = PARITY_FIX.replace(
        '"work_sharing.dts.throughput": 0.03,',
        '"work_sharing.dts.throughput": 0.03,\n'
        '        "feedback.dts.median_rtt": 0.035,')
    diags = lint(tmp_path, {PARITY: parity_more, DOC: DOC_FIX},
                 only={"SL502"})
    assert rules_of(diags) == {"SL502"}
    assert "feedback.dts.median_rtt" in diags[0].message


def test_sl503_parity_suite_not_importing_bands(tmp_path):
    tree = {
        PARITY: PARITY_FIX, DOC: DOC_FIX,
        "tests/test_engine_parity.py": "THR_TOL = {'dts': 0.03}\n",
        "tests/test_multi_tenant.py":
            "from repro.core.parity import band\n",
    }
    diags = lint(tmp_path, tree, only={"SL503"})
    assert rules_of(diags) == {"SL503"}
    assert diags[0].file == "tests/test_engine_parity.py"


# -- suppression semantics -------------------------------------------------


def test_suppression_with_justification(tmp_path):
    src = """
        import time
        t0 = time.time()  # streamlint: disable=SL403 -- telemetry only
    """
    assert not lint(tmp_path, {"src/repro/core/x.py": src},
                    only={"SL403", "SL001", "SL002"})


def test_suppression_standalone_comment_guards_next_code_line(tmp_path):
    src = """
        import time
        # streamlint: disable=SL403 -- wall-clock telemetry, reported
        # alongside results, never fed into them
        t0 = time.time()
    """
    assert not lint(tmp_path, {"src/repro/core/x.py": src},
                    only={"SL403", "SL001", "SL002"})


def test_sl001_unjustified_suppression(tmp_path):
    src = """
        import time
        t0 = time.time()  # streamlint: disable=SL403
    """
    diags = lint(tmp_path, {"src/repro/core/x.py": src},
                 only={"SL403", "SL001"})
    assert rules_of(diags) == {"SL001"}


def test_sl002_unused_suppression(tmp_path):
    src = """
        x = 1  # streamlint: disable=SL403 -- nothing to suppress here
    """
    diags = lint(tmp_path, {"src/repro/core/x.py": src},
                 only={"SL403", "SL002"})
    assert rules_of(diags) == {"SL002"}


def test_suppression_is_rule_specific(tmp_path):
    src = """
        import time
        t0 = time.time()  # streamlint: disable=SL401 -- wrong rule id
    """
    diags = lint(tmp_path, {"src/repro/core/x.py": src}, only={"SL403"})
    assert rules_of(diags) == {"SL403"}


# -- CLI / report surface --------------------------------------------------


def test_cli_json_report_and_exit_codes(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "repro").mkdir()
    core = tmp_path / "src" / "repro" / "core"
    core.mkdir()
    (core / "x.py").write_text("import random\n")
    report = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "tools.streamlint", "src",
         "--root", str(tmp_path), "--json", str(report)],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert proc.returncode == 1
    assert "SL401" in proc.stdout
    data = json.loads(report.read_text())
    assert data["counts"]["SL401"] == 1
    assert data["exit_code"] == 1
    assert any(d["rule"] == "SL401" for d in data["diagnostics"])

    (core / "x.py").write_text("x = 1\n")
    proc = subprocess.run(
        [sys.executable, "-m", "tools.streamlint", "src",
         "--root", str(tmp_path)],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert proc.returncode == 0


def test_syntax_error_is_a_diagnostic_not_a_crash(tmp_path):
    diags = lint(tmp_path, {"src/repro/core/x.py": "def broken(:\n"})
    assert rules_of(diags) == {"SL900"}


# -- live-tree self-check --------------------------------------------------


def test_live_tree_is_clean():
    """The acceptance gate, as a test: the real tree has no unsuppressed
    findings, and every suppression it does carry is justified."""
    analysis = run_analysis(REPO_ROOT, ["src", "benchmarks"])
    assert analysis.failures == [], [d.format() for d in analysis.failures]
    suppressed = [d for d in analysis.diagnostics if d.suppressed]
    assert all(d.justified for d in suppressed)
    # the live tree exercises the suppression machinery (campaign.py's
    # wall-clock telemetry) — keep this test honest about that
    assert suppressed, "expected justified suppressions in campaign.py"


def test_live_docs_table_matches_constants():
    """SL5xx sees the real docs/engines.md and repro.core.parity."""
    analysis = run_analysis(REPO_ROOT, ["src"],
                            only={"SL501", "SL502", "SL503"})
    assert analysis.failures == [], [d.format() for d in analysis.failures]
