"""Workloads (Table 1), architectures (hop graphs), SciStream/S3M control
planes, DS2HPC deployment mechanics."""

import pytest

from repro.core import architectures as A
from repro.core import scistream as S
from repro.core.ds2hpc import (
    ClusterInventory, NodePortService, RabbitMQRelease)
from repro.core.s3m import (
    ResourceSettings, S3MAuthError, S3MError, S3MService)
from repro.core.workloads import (
    DSTREAM, GENERIC, LSTREAM, tokens_from_payload)


# --------------------------- Table 1 -----------------------------------------

def test_table1_characteristics():
    assert DSTREAM.payload_bytes == 16 * 1024          # 8 x 2 KiB
    assert DSTREAM.events_per_message == 8
    assert DSTREAM.data_rate_gbps == 32.0
    assert LSTREAM.payload_bytes == 1024 ** 2
    assert LSTREAM.payload_format.value == "hdf5"
    assert LSTREAM.data_rate_gbps == 30.0
    assert GENERIC.payload_bytes == 4 * 1024 ** 2
    assert GENERIC.events_per_message == 1
    assert GENERIC.data_rate_gbps == 25.0


def test_payload_deterministic_and_sized():
    p1 = DSTREAM.payload(seed=42)
    p2 = DSTREAM.payload(seed=42)
    assert p1 == p2 and len(p1) == DSTREAM.payload_bytes
    assert DSTREAM.payload(seed=43) != p1


def test_tokens_from_payload_deterministic():
    p = DSTREAM.payload(seed=7)
    t1 = tokens_from_payload(p, 1000, 128)
    t2 = tokens_from_payload(p, 1000, 128)
    assert (t1 == t2).all() and t1.shape == (128,)
    assert t1.min() >= 0 and t1.max() < 1000


def test_message_rate_math():
    # 32 Gbps over 16 KiB messages ~= 244K msgs/s
    assert abs(DSTREAM.messages_per_second_at_rate() - 32e9 / (16384 * 8)) < 1


# --------------------------- architectures -----------------------------------

def test_dts_paths_are_minimal_hop_and_tls():
    a = A.make_architecture("dts")
    pub = a.publish_path(0, 0, 0)
    assert [e.resource for e in pub] == ["plink:0", "dsn_in:0", "bcpu:0"]
    assert all(e.byte_factor > 1.0 for e in pub[:2])   # AMQPS on the wire


def test_prs_tunnel_placement_and_plain_amqp_inside():
    a = A.make_architecture("prs-haproxy")
    pub = a.publish_path(0, 1, 1)
    res = [e.resource for e in pub]
    assert "tunnel" in res and "pproxy" in res and "cproxy" in res
    # client link is plain AMQP (byte_factor 1.0) — TLS only on tunnel
    assert pub[0].byte_factor == 1.0
    tun = pub[res.index("tunnel")]
    assert tun.byte_factor > 1.0
    # consumers are inside the facility: no tunnel on delivery
    dlv = a.delivery_path(1, 1, 0)
    assert "tunnel" not in [e.resource for e in dlv]
    # replies to external producers re-traverse the tunnel
    rply = a.reply_delivery_path(1, 1, 0)
    assert "tunnel" in [e.resource for e in rply]


def test_stunnel_connection_limit():
    a = A.make_architecture("prs-stunnel")
    assert a.producer_conn_limit() == 16


def test_mss_traverses_lb_and_ingress_both_ways():
    a = A.make_architecture("mss")
    pub = [e.resource for e in a.publish_path(2, 0, 0)]
    dlv = [e.resource for e in a.delivery_path(0, 0, 3)]
    assert "lb" in pub and "ingress_in" in pub
    assert "lb" in dlv and "ingress_out" in dlv
    assert any(r and r.startswith("ingw_in") for r in pub)
    assert any(r and r.startswith("ingw_out") for r in dlv)


def test_haproxy_flow_degradation_configures():
    a = A.make_architecture("prs-haproxy")
    base = a.resources["tunnel"].service_s
    a.configure(64, 64)
    assert a.resources["tunnel"].service_s > base
    a.configure(1, 1)
    assert a.resources["tunnel"].service_s == pytest.approx(base)


# --------------------------- SciStream ---------------------------------------

def test_scistream_handshake_full_sequence():
    sess = S.establish_prs_session(num_conn=4)
    assert sess.num_conn == 4
    assert len(sess.connection_map) == 4
    assert sess.hops[0] == "producer" and sess.hops[-1] == "consumer"
    assert sess.producer_proxy.side == "producer"
    assert sess.consumer_proxy.side == "consumer"
    lo, hi = S.STREAM_PORT_RANGE
    assert lo <= sess.consumer_proxy.listen_port <= hi


def test_scistream_rejects_bad_cert_and_uid():
    s2uc = S.S2UC()
    cons = S.S2CS("198.51.100.0")
    prod = S.S2CS("198.51.100.1")
    with pytest.raises(S.SciStreamError):
        s2uc.inbound_request(server_cert=prod.cert, remote_ip="x",
                             s2cs=cons, receiver_ports=(5672,))
    port, uid = s2uc.inbound_request(server_cert=cons.cert, remote_ip="x",
                                     s2cs=cons, receiver_ports=(5672,))
    with pytest.raises(S.SciStreamError):
        s2uc.outbound_request(server_cert=prod.cert, remote_ip="x",
                              s2cs=prod, receiver_port=port, uid="uid-zzz")


def test_scistream_port_exhaustion():
    s2cs = S.S2CS("10.0.0.1")
    lo, hi = S.STREAM_PORT_RANGE
    for _ in range(hi - lo + 1):
        s2cs.launch_s2ds("consumer", (5672,), 1, "u")
    with pytest.raises(S.SciStreamError):
        s2cs.launch_s2ds("consumer", (5672,), 1, "u")


def test_scistream_teardown_releases_ports():
    s2uc = S.S2UC()
    cons = S.S2CS("198.51.100.0")
    prod = S.S2CS("198.51.100.1")
    port, uid = s2uc.inbound_request(server_cert=cons.cert, remote_ip="x",
                                     s2cs=cons, receiver_ports=(5672,))
    sess = s2uc.outbound_request(server_cert=prod.cert, remote_ip="x",
                                 s2cs=prod, receiver_port=port, uid=uid)
    s2uc.teardown(sess.uid, prod, cons)
    assert not cons.data_servers and not prod.data_servers


# --------------------------- S3M ---------------------------------------------

def test_s3m_provision_requires_valid_token():
    svc = S3MService()
    svc.register_project("abc123")
    tok = svc.issue_token("abc123")
    c = svc.provision_cluster(tok, settings=ResourceSettings(
        cpus=12, ram_gbs=32, nodes=3))
    assert c.amqps_url.startswith("amqps://") and ":443" in c.amqps_url
    assert c.dsn_placement == [0, 1, 2]


def test_s3m_rejects_expired_forged_and_overquota():
    now = [0.0]
    svc = S3MService(clock=lambda: now[0])
    svc.register_project("p", max_clusters=1)
    tok = svc.issue_token("p", ttl_s=10)
    now[0] = 100.0
    import pytest as _pt
    with _pt.raises(S3MAuthError):
        svc.provision_cluster(tok)
    tok2 = svc.issue_token("p")
    svc.provision_cluster(tok2)
    with _pt.raises(S3MError):
        svc.provision_cluster(tok2)              # quota
    forged = S.ProxyCertificate  # noqa: F841  (placeholder)


def test_s3m_policy_validation():
    with pytest.raises(S3MError):
        ResourceSettings(nodes=99).validate()


# --------------------------- DS2HPC -------------------------------------------

def test_rabbitmq_release_anti_affinity():
    rel = RabbitMQRelease()
    inv = ClusterInventory()
    assert rel.pod_placement(inv) == [0, 1, 2]
    with pytest.raises(ValueError):
        RabbitMQRelease(replicas=4).pod_placement(inv)
    assert "helm install rabbitmq" in rel.helm_command()


def test_nodeport_range_enforced():
    with pytest.raises(ValueError):
        NodePortService.allocate("x", 0, port=99999)
    s = NodePortService.allocate("ok", 1)
    assert 30000 <= s.port <= 32767


def test_highspeed_projection_inventory():
    inv = ClusterInventory().highspeed()
    assert inv.dsn_link_gbps == 100.0
