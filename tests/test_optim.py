"""Optimizer substrate: AdamW vs a numpy reference, clipping, schedules,
and error-feedback gradient compression (convergence property)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import AxisType, make_mesh, shard_map
from repro.optim import (
    AdamW, clip_by_global_norm, compressed_pod_mean, cosine_warmup,
    dequantize_int8, quantize_int8)


def _np_adamw_step(p, g, m, v, t, lr=1e-2, b1=0.9, b2=0.95, eps=1e-8,
                   wd=0.1):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    delta = mh / (np.sqrt(vh) + eps)
    if p.ndim >= 2:
        delta = delta + wd * p
    return p - lr * delta, m, v


def test_adamw_matches_numpy_reference():
    opt = AdamW(learning_rate=1e-2, grad_clip_norm=0.0)
    params = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]]),
              "b": jnp.array([0.1, -0.1])}
    state = opt.init(params)
    g = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]]),
         "b": jnp.array([0.05, -0.02])}
    np_p = {k: np.asarray(v) for k, v in params.items()}
    np_m = {k: np.zeros_like(v) for k, v in np_p.items()}
    np_v = {k: np.zeros_like(v) for k, v in np_p.items()}
    for t in range(1, 4):
        params, state, _ = opt.update(g, state, params)
        for k in np_p:
            np_p[k], np_m[k], np_v[k] = _np_adamw_step(
                np_p[k], np.asarray(g[k]), np_m[k], np_v[k], t)
    for k in np_p:
        np.testing.assert_allclose(np.asarray(params[k]), np_p[k],
                                   rtol=1e-5, atol=1e-6)


def test_grad_clip_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(90 + 160), rel=1e-5)
    new_norm = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                                  for x in jax.tree.leaves(clipped))))
    assert new_norm == pytest.approx(1.0, rel=1e-5)


def test_adamw_descends_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    fn = lambda p: jnp.sum((p["x"] - 1.0) ** 2)
    for _ in range(200):
        g = jax.grad(fn)(params)
        params, state, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["x"]), [1.0, 1.0],
                               atol=1e-2)


def test_cosine_warmup_shape():
    lr = cosine_warmup(1e-3, warmup_steps=10, total_steps=100)
    vals = [float(lr(jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert vals[0] == 0.0
    assert vals[1] == pytest.approx(5e-4)
    assert vals[2] == pytest.approx(1e-3)
    assert vals[3] < vals[2]
    assert vals[4] == pytest.approx(1e-4, rel=1e-2)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (1000,))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_compressed_pod_mean_and_error_feedback():
    """shard_map over a 1-sized pod axis: mean == identity, and the carried
    error equals the quantization residual."""
    mesh = make_mesh((1,), ("pod",), axis_types=(AxisType.Auto,))
    x = jax.random.normal(jax.random.key(1), (64,))
    e0 = jnp.zeros_like(x)
    fn = shard_map(
        lambda g, e: compressed_pod_mean(g, e, "pod"),
        mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),) * 2,
        out_specs=(jax.sharding.PartitionSpec(),) * 2, check_vma=False)
    mean, err = fn(x, e0)
    np.testing.assert_allclose(np.asarray(mean + err), np.asarray(x),
                               rtol=1e-5, atol=1e-6)


def test_error_feedback_sgd_converges():
    """Quadratic descent *through the compressor* still converges (the
    error-feedback guarantee)."""
    mesh = make_mesh((1,), ("pod",), axis_types=(AxisType.Auto,))
    P = jax.sharding.PartitionSpec
    comp = jax.jit(shard_map(
        lambda g, e: compressed_pod_mean(g, e, "pod"), mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False))
    x = jnp.array([4.0, -7.0, 2.0])
    err = jnp.zeros_like(x)
    for _ in range(300):
        g = 2 * (x - 1.0)
        g_hat, err = comp(g, err)
        x = x - 0.05 * g_hat
    np.testing.assert_allclose(np.asarray(x), 1.0, atol=5e-2)
