"""End-to-end training behavior: loss decreases, streamed training works,
fault injection mid-run survives, serve generates."""

import argparse

import jax
import jax.numpy as jnp
import pytest

from repro.launch.train import run as train_run


def _args(**kw):
    base = dict(arch="granite-8b-smoke", steps=25, batch=8, seq=32,
                lr=2e-3, seed=0, microbatches=1, data="local",
                ckpt_dir="", ckpt_every=50, resume=True, log_every=100,
                feedback_every=5, crash_consumer_at=-1)
    base.update(kw)
    return argparse.Namespace(**base)


def test_local_training_loss_decreases():
    out = train_run(_args())
    assert out["losses"][0] > out["final_loss"] + 0.3


def test_microbatched_equals_more_steps_loss_trend():
    out = train_run(_args(microbatches=2, steps=15))
    assert out["losses"][0] > out["final_loss"]


@pytest.mark.slow
def test_streamed_training_with_crash_and_feedback():
    """Full edge->HPC loop: streamed ingest, steering feedback every 5
    steps, a consumer crash at step 6, training continues and learns."""
    out = train_run(_args(data="stream", steps=14, batch=4, seq=16,
                          crash_consumer_at=6))
    assert len(out["losses"]) == 14
    assert all(jnp.isfinite(jnp.asarray(out["losses"])))


def test_checkpoint_restart_continues(tmp_path):
    train_run(_args(steps=10, ckpt_dir=str(tmp_path), ckpt_every=5))
    out2 = train_run(_args(steps=14, ckpt_dir=str(tmp_path), ckpt_every=5))
    # resumed run starts from step 10 and produces only 4 more losses
    assert len(out2["losses"]) == 4


def test_serve_generates_tokens():
    from repro.configs import get_smoke_config
    from repro.launch.serve import generate
    from repro.models.zoo import build_model
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (2, 4), 0,
                                 cfg.vocab_size, jnp.int32)
    toks = generate(model, params, prompts, max_new=6)
    assert toks.shape == (2, 10)
    assert int(toks.max()) < cfg.vocab_size
