"""Property tests for lane-resolved flow control in the vectorized
engine (hypothesis when installed, the deterministic fallback
otherwise — see tests/_hypothesis_compat.py).

The invariants under test, per stacked seed-lane:

* **conservation** — every published message is delivered exactly once
  (published = delivered + rejected-in-flight, and in-flight is empty
  once the run drains); reject/blocked counters are non-negative and
  zero when flow-control events are unreachable;
* **backlog cap** — the admission path never lets a lane's un-drained
  queue backlog exceed the byte cap (checked against the per-lane
  high-water mark the queue state records);
* **confirm causality / resolution** — every lane's publisher-confirm
  clock is at or after its own publish start, and the per-producer
  resolved-confirm prefix reaches the end of the run (all confirms
  finite: nothing stays withheld);
* **pilot invariance** — lane 0 of a stacked run is bit-identical to
  the solo vectorized run across sampled overflow configurations;
* the **FIFO-scan lane axis** computes exactly the per-lane solo scans
  (the identity every lane-threaded time array relies on);
* **device-program backend equivalence** — the whole-run ``lax.scan``
  wave program (:mod:`repro.core.jax_device_loop`) and its NumPy-mirror
  step loop produce identical per-generation traces over drawn shapes,
  seeds and jitter.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.jax_engine import jax_available
from repro.core.patterns import OVERFLOW_STRESS_DEFAULTS
from repro.core.simulator import ExperimentSpec, SimParams, run_experiment
from repro.core.vectorized import VectorizedStreamSim, _fifo_scan
from repro.core.workloads import get_workload

#: every lane-resolved invariant below holds for each batched engine;
#: the jax engine swaps the kernel layer only (masked depart stores,
#: device admission scan), so it rides the same properties
VEC_ENGINES = (("vectorized", "jax") if jax_available()
               else ("vectorized",))


def _engine_cls(engine):
    if engine == "jax":
        from repro.core.jax_engine import JaxStreamSim
        return JaxStreamSim
    return VectorizedStreamSim


def _overflow_spec(seed, cap_msgs, msgs, nc=2):
    wl = get_workload("dstream")
    return ExperimentSpec(
        pattern="feedback", workload=wl, arch="dts", n_producers=nc,
        n_consumers=nc, total_messages=msgs,
        params=SimParams(seed=seed,
                         queue_max_bytes=cap_msgs * wl.payload_bytes,
                         **OVERFLOW_STRESS_DEFAULTS))


# -- FIFO-scan lane axis ----------------------------------------------------


@settings(max_examples=40)
@given(holds=st.lists(st.floats(min_value=0.0, max_value=5.0),
                      min_size=1, max_size=30),
       gaps=st.lists(st.floats(min_value=0.0, max_value=3.0),
                     min_size=1, max_size=30),
       scales=st.lists(st.floats(min_value=0.5, max_value=2.0),
                       min_size=2, max_size=4),
       carry=st.floats(min_value=0.0, max_value=10.0))
def test_fifo_scan_lane_axis_matches_per_lane(holds, gaps, scales, carry):
    """A lane-stacked ``_fifo_scan`` must equal running each lane's solo
    scan independently — the identity that lets one batched recurrence
    carry every seed-lane's clock."""
    n = min(len(holds), len(gaps))
    a1 = np.cumsum(np.asarray(gaps[:n]))
    h1 = np.asarray(holds[:n])
    sc = np.asarray(scales)
    a = a1[:, None] * sc[None, :]
    h = h1[:, None] * sc[None, :]
    carries = carry * sc
    got = _fifo_scan(a, h, carries)
    for lane in range(sc.size):
        want = _fifo_scan(a[:, lane], h[:, lane], carries[lane])
        assert np.allclose(got[:, lane], want, rtol=1e-12, atol=1e-12)


# -- admission-path unit properties ----------------------------------------


def _mini_sim(n_lanes, engine="vectorized"):
    spec = ExperimentSpec(
        pattern="work_sharing", workload=get_workload("dstream"),
        arch="dts", n_producers=2, n_consumers=2, total_messages=64,
        params=SimParams(seed=0, engine=engine))
    return _engine_cls(engine)(spec, stack_seeds=list(range(n_lanes)))


@pytest.mark.parametrize("engine", VEC_ENGINES)
@settings(max_examples=25)
@given(cap=st.integers(min_value=2, max_value=12),
       lanes=st.integers(min_value=1, max_value=3),
       batches=st.lists(
           st.lists(st.floats(min_value=0.0, max_value=50.0),
                    min_size=1, max_size=12),
           min_size=1, max_size=6),
       drain_frac=st.floats(min_value=0.0, max_value=1.0))
def test_enqueue_batch_per_lane_cap_and_conservation(engine, cap, lanes,
                                                     batches, drain_frac):
    """Feeding arbitrary enqueue cohorts (with partial drains recorded
    in between) through ``_enqueue_batch`` never lets any lane's
    backlog — or its recorded high-water mark — exceed the byte cap,
    and per lane attempted == admitted + rejected at every step."""
    sim = _mini_sim(lanes, engine)
    q = sim._queue_state(("prop", 0), [0], 100, credit=3 * cap,
                         cap_msgs=cap)
    rng = np.random.default_rng(0)
    admitted = np.zeros(lanes, dtype=int)
    attempted = 0
    rejected = np.zeros(lanes, dtype=int)
    for b, times in enumerate(batches):
        base = np.sort(np.asarray(times))
        t = (base[:, None] * (1.0 + 0.05 * np.arange(lanes))
             if lanes > 1 else base)
        acc, _ = sim._enqueue_batch([q], t)
        acc2 = acc.reshape(len(times), lanes)
        admitted += acc2.sum(axis=0)
        rejected += (~acc2).sum(axis=0)
        attempted += len(times)
        assert (q["n_enq"] == admitted).all()
        assert (q["hwm"] <= cap).all()
        assert ((q["n_enq"] - q["departed"]) <= cap).all()
        # drain a fraction of what each lane has admitted
        backlog = q["n_enq"] - q["departed"]
        n_drain = int(drain_frac * backlog.min())
        if n_drain:
            d = np.cumsum(rng.uniform(0.1, 2.0, (n_drain, lanes)), axis=0) \
                + float(np.max(t))
            sim._record_departs(q, d if lanes > 1 else d[:, 0])
    assert attempted * lanes == int(admitted.sum() + rejected.sum())


# -- whole-run lane invariants under overflow ------------------------------


@pytest.mark.parametrize("engine", VEC_ENGINES)
@settings(max_examples=5, deadline=None)
@given(seeds=st.lists(st.integers(min_value=1, max_value=10_000),
                      min_size=1, max_size=3),
       cap_msgs=st.integers(min_value=48, max_value=128),
       msgs=st.sampled_from((256, 512)))
def test_stacked_overflow_lane_invariants(engine, seeds, cap_msgs, msgs):
    """Whole-run invariants of a stacked overflow cell, per lane:
    conservation, non-negative lane-resolved counters, positive RTTs,
    confirm causality + full confirm resolution, backlog high-water
    marks within the cap, drained queues, and a bit-identical pilot
    (the solo reference stays on the vectorized engine, so the jax
    param also pins jax-pilot == numpy-solo bit-identity)."""
    spec = _overflow_spec(0, cap_msgs, msgs)
    sim = _engine_cls(engine)(spec, stack_seeds=[0] + seeds)
    results = sim.run_stacked()
    solo = run_experiment(spec)
    # pilot invariance: the admission path collapses to the solo one
    assert np.array_equal(results[0].consume_times, solo.consume_times)
    assert results[0].rejected_publishes == solo.rejected_publishes
    assert results[0].blocked_confirms == solo.blocked_confirms
    for r in results:
        assert r.feasible
        # conservation: published = delivered (+ empty in-flight)
        assert r.n_consumed == msgs
        assert r.publish_starts.size == msgs
        assert r.rtts.size == msgs and (r.rtts > 0).all()
        assert r.rejected_publishes >= 0 and r.blocked_confirms >= 0
    # per-lane confirm causality + resolution (prefix reached the end)
    conf, pub = sim._fin_confirms, sim._fin_pub
    assert np.isfinite(conf).all()
    assert (conf >= pub - 1e-12).all()
    # per-lane queue accounting: drained, capped, nothing withheld
    for q in sim._queues.values():
        if not q["track"]:
            continue
        assert not q["deferred"]
        assert (q["n_enq"] == q["released"]).all()
        assert (q["departed"] <= q["released"]).all()
        if q["cap"] is not None:
            # the pilot's admission is exact; non-pilot lanes may
            # overshoot only by their counted optimistic admissions
            # (a lane at cap with no known future drain admits on the
            # next retry instead of deferring its pilot-fixed schedule)
            assert q["hwm"][0] <= q["cap"]
            assert (q["hwm"] <= q["cap"] + q["forced"]).all()


# -- whole-run device program: backend equivalence --------------------------


@pytest.mark.slow
@pytest.mark.skipif(not jax_available(), reason="jax required")
@settings(max_examples=8, deadline=None)
@given(pattern=st.sampled_from(("work_sharing", "feedback")),
       npr=st.sampled_from((2, 4)),
       msgs_per=st.sampled_from((16, 32)),
       jitter=st.floats(min_value=0.0, max_value=0.05),
       seed=st.integers(min_value=0, max_value=999))
def test_device_trace_jax_matches_numpy_step_for_step(pattern, npr,
                                                      msgs_per, jitter,
                                                      seed):
    """The jitted ``lax.scan`` device program and its NumPy-mirror step
    loop (``backend="numpy"``) emit identical per-generation traces for
    arbitrary drawn shapes, seeds and jitter — the numpy mirror is the
    step-for-step oracle of :mod:`repro.core.jax_device_loop`, so any
    divergence is a jit/vmap artifact, never modeling noise."""
    from repro.core import jax_device_loop as dl
    spec = ExperimentSpec(
        pattern=pattern, workload=get_workload("dstream"), arch="dts",
        n_producers=npr, n_consumers=2,
        total_messages=npr * msgs_per,
        params=SimParams(seed=seed, jitter=jitter))
    sim = VectorizedStreamSim(spec)
    ws = dl.build_static(sim)
    jit = dl.draw_jitter(sim, ws)
    yn = dl.run_wave_trace(ws, jit, backend="numpy")
    yj = dl.run_wave_trace(ws, jit, backend="jax")
    assert set(yn) == set(yj)
    for k in sorted(yn):
        np.testing.assert_allclose(yj[k], yn[k], rtol=1e-12,
                                   atol=1e-12, err_msg=k)


@pytest.mark.parametrize("engine", VEC_ENGINES)
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       nc=st.sampled_from((2, 4)))
def test_no_flow_events_means_zero_counters_every_lane(engine, seed, nc):
    """With no byte cap and no reachable credit threshold, every lane's
    flow-control counters must be exactly zero (the lane-resolved
    admission path must not invent events)."""
    wl = get_workload("dstream")
    spec = ExperimentSpec(
        pattern="work_sharing", workload=wl, arch="dts", n_producers=nc,
        n_consumers=nc, total_messages=512,
        params=SimParams(seed=seed, engine=engine))
    sim = _engine_cls(engine)(spec, stack_seeds=[seed, seed + 1,
                                                 seed + 2])
    assert not sim.flow_events_possible()
    for r in sim.run_stacked():
        assert r.rejected_publishes == 0
        assert r.blocked_confirms == 0
        assert r.n_consumed == 512
