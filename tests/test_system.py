"""End-to-end behaviour of the paper's system: an edge-to-HPC streaming
workflow driving model training, evaluated across all three cross-facility
architectures — the full stack in one test module."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ResourceSettings, S3MService, establish_prs_session,
    make_architecture, run_pattern, summarize)
from repro.core.metrics import overhead_table
from repro.core.workloads import DSTREAM


def test_three_architectures_deployable_end_to_end():
    """Each architecture can be stood up via its control plane and carries
    a work-sharing experiment to completion."""
    # DTS: direct — no control plane beyond the helm release
    r_dts = run_pattern("work_sharing", "dts", "dstream", 2,
                        total_messages=600, n_runs=1)[0]
    # PRS: SciStream handshake provisions the session
    sess = establish_prs_session(num_conn=1, tunnel="haproxy")
    assert len(sess.connection_map) == 1
    r_prs = run_pattern("work_sharing", "prs-haproxy", "dstream", 2,
                        total_messages=600, n_runs=1)[0]
    # MSS: S3M token + provision_cluster
    svc = S3MService()
    svc.register_project("abc123")
    tok = svc.issue_token("abc123")
    cluster = svc.provision_cluster(tok, settings=ResourceSettings())
    arch = make_architecture("mss", managed_cluster=cluster)
    r_mss = run_pattern("work_sharing", "mss", "dstream", 2,
                        total_messages=600, n_runs=1)[0]
    for r in (r_dts, r_prs, r_mss):
        assert r.feasible and r.n_consumed == 600
    assert arch.managed_cluster.amqps_url.endswith(":443")


def test_paper_headline_ordering_holds():
    """The paper's §6 conclusions, at reduced message counts: DTS fastest
    in work sharing; PRS between; MSS most overhead."""
    ss = [summarize(run_pattern("work_sharing", a, "dstream", 8,
                                total_messages=1500, n_runs=1)[0])
          for a in ("dts", "prs-haproxy", "mss")]
    t = {s.arch: s.throughput_msgs_s for s in ss}
    assert t["dts"] > t["prs-haproxy"] > t["mss"]
    ot = overhead_table(ss)
    assert ot[("mss", "dstream", 8)] > 1.5


def test_streamed_batches_train_a_model():
    """Detector payloads -> broker -> loader -> train_step: loss is finite
    and the batch content is exactly reproducible from the payload bytes."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import build_train_step
    from repro.models.zoo import build_model
    from repro.optim import AdamW
    from repro.streaming import (EdgeProducer, RealtimeBroker,
                                 StreamingDataLoader)

    cfg = get_smoke_config("granite-8b")
    broker = RealtimeBroker()
    loader = StreamingDataLoader(broker, DSTREAM, vocab_size=cfg.vocab_size,
                                 seq_len=16, batch_size=2, n_consumers=1)
    prod = EdgeProducer(broker, DSTREAM, lambda i: "work:0",
                        rate_msgs_s=2000, n_messages=10,
                        producer_id="edge").start()
    batch = loader.next_batch(timeout=15)
    model = build_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    step = jax.jit(build_train_step(model, opt, None, 1))
    params = model.init_params(jax.random.key(0))
    p2, s2, metrics = step(params, opt.init(params),
                           {k: jnp.asarray(v) for k, v in batch.items()})
    assert bool(jnp.isfinite(metrics["loss"]))
    prod.stop(join=False)
    loader.close()
