"""Beyond-paper: throughput vs payload size per architecture (the paper
fixes three sizes; sweeping exposes each architecture's per-message
overhead vs bandwidth crossover — where PRS's proxy CPU cost stops
mattering and MSS's ingress byte-cap takes over)."""

import dataclasses

from benchmarks.common import cache_key, resolve_engine
from repro.core.metrics import summarize
from repro.core.patterns import run_pattern
from repro.core.workloads import DSTREAM

SIZES_KIB = (4, 16, 64, 256, 1024)


def run(cache):
    rows = []
    for arch in ("dts", "prs-haproxy", "mss"):
        for kib in SIZES_KIB:
            key = f"psweep/{arch}/{kib}KiB"

            def compute(kib=kib, arch=arch):
                wl = dataclasses.replace(
                    DSTREAM, name=f"sweep{kib}", payload_bytes=kib * 1024)
                r = run_pattern("work_sharing", arch, wl, 8,
                                total_messages=2048, n_runs=1,
                                engine=resolve_engine())[0]
                s = summarize(r)
                return {"throughput": s.throughput_msgs_s,
                        "gbps": s.goodput_gbps}

            cell = cache.get_or(cache_key(key), compute)
            rows.append((key, 1e6 / max(cell["throughput"], 1e-9),
                         f"thr={cell['throughput']:.0f}msg/s "
                         f"goodput={cell['gbps']:.2f}Gbps"))
    return rows
