"""Overflow-regime stress sweep: reject-publish + credit-flow blocking.

The paper's configurations never trigger RabbitMQ's overflow machinery
(queue backlogs stay far below both the byte caps and the credit-flow
threshold).  This bench pushes StreamSim into that regime — small confirm
window, slow consumers, tight per-queue byte caps — and sweeps it to
consumer counts only the vectorized engine can run interactively.

Cell families:

* ``overflow/parity/*`` — the heap and vectorized engines on the same
  both-mechanisms cell (cap ~6% above the credit threshold, 4
  producers/consumers, jitter off); 'derived' carries the throughput
  deviation and the rejected/blocked counters side by side.
* ``overflow/scale/*``  — vectorized-only reject-publish sweeps at 64,
  256 and 1024 consumers with a fixed small queue cap and a fixed
  aggregate drain rate (consumer processing time scales with the fleet,
  so producers outpace the drain at every size and the queue pins at its
  cap — the pure overflow/retry path at affordable message volumes).
* ``overflow/stacked/*`` — the parity cell across N seed lanes through
  ONE lane-resolved stacked event loop vs N per-cell runs; 'derived'
  carries the wall-clock speedup and the per-lane reject-count spread
  (lane-resolved counters: each lane's own admission realization, not
  clones of the pilot's).

Set ``OVERFLOW_BENCH_SMOKE=1`` to run only the parity cell, the
64-consumer scale cell and a shrunk stacked cell (the CI smoke
configuration).
"""

from __future__ import annotations

import os
import time

from benchmarks.common import Cache, cache_key
from repro.core.broker import ClassicQueue
from repro.core.metrics import summarize
from repro.core.patterns import OVERFLOW_STRESS_DEFAULTS, overflow_stress
from repro.core.workloads import DSTREAM

PARITY_NC = 4
#: seed lanes of the stacked-overflow cell (lane-resolved flow control)
STACKED_LANES = 6
SCALE_NCS = (64, 256, 1024)
SCALE_CAP_MSGS = 2048
SCALE_MSGS = 32768
SCALE_MSGS_SMOKE = 8192       # CI smoke: one short reject-retry episode
#: per-consumer processing seconds per fleet member: fixes the aggregate
#: drain at 1/SCALE_PROC_PER_NC ~ 4000 msg/s regardless of consumer count
SCALE_PROC_PER_NC = 250e-6


def _summ(r) -> dict:
    s = summarize(r)
    return {"feasible": r.feasible,
            "throughput": s.throughput_msgs_s,
            "median_rtt": s.median_rtt_s,
            "rejected": int(r.rejected_publishes),
            "blocked": int(r.blocked_confirms)}


def run(cache: Cache):
    smoke = bool(os.environ.get("OVERFLOW_BENCH_SMOKE"))
    rows = []

    parity_cap = int(ClassicQueue.FLOW_CREDIT * PARITY_NC * 1.06)
    parity_params = dict(OVERFLOW_STRESS_DEFAULTS, jitter=0.0,
                         queue_max_bytes=parity_cap * DSTREAM.payload_bytes)

    def parity_cell() -> dict:
        out = {}
        for eng in ("heap", "vectorized"):
            t0 = time.time()
            r = overflow_stress("dts", PARITY_NC, engine=eng,
                                **parity_params)[0]
            out[eng] = _summ(r)
            out[eng]["wall"] = time.time() - t0
        return out

    c = cache.get_or(
        cache_key(f"overflow|parity|dts|{PARITY_NC}", engine="vectorized",
                  **parity_params), parity_cell)
    h, v = c["heap"], c["vectorized"]
    dev = 100.0 * (v["throughput"] - h["throughput"]) / h["throughput"]
    rows.append((f"overflow/parity/dts/c{PARITY_NC}",
                 1e6 / v["throughput"],
                 f"dev={dev:+.2f}% rej={h['rejected']}/{v['rejected']} "
                 f"blk={h['blocked']}/{v['blocked']} (heap/vec)"))

    msgs = SCALE_MSGS_SMOKE if smoke else SCALE_MSGS
    for nc in SCALE_NCS:
        if smoke and nc != SCALE_NCS[0]:
            continue
        scale_params = dict(
            OVERFLOW_STRESS_DEFAULTS,
            consumer_proc_s=SCALE_PROC_PER_NC * nc,
            queue_max_bytes=SCALE_CAP_MSGS * DSTREAM.payload_bytes)

        def scale_cell(nc=nc, scale_params=scale_params) -> dict:
            r = overflow_stress(
                "dts", nc, queue_cap_msgs=SCALE_CAP_MSGS,
                total_messages=msgs, engine="vectorized",
                **scale_params)[0]
            return _summ(r)

        c = cache.get_or(
            cache_key(f"overflow|scale|dts|{nc}|{SCALE_CAP_MSGS}"
                      f"|{msgs}", engine="vectorized",
                      **scale_params), scale_cell)
        rows.append((f"overflow/scale/dts/c{nc}",
                     1e6 / c["throughput"],
                     f"thr={c['throughput']:.0f}msg/s "
                     f"rej={c['rejected']} blk={c['blocked']}"))

    n_lanes = 4 if smoke else STACKED_LANES
    stacked_msgs = SCALE_MSGS_SMOKE if smoke else None   # overflow default
    # default jitter (unlike the parity cell): each lane's own jitter
    # stream is what makes its admission realization diverge
    stacked_params = dict(parity_params)
    del stacked_params["jitter"]

    def stacked_cell() -> dict:
        import numpy as np

        from repro.core.vectorized import run_many
        t0 = time.time()
        serial = [overflow_stress(
            "dts", PARITY_NC, n_runs=1, seed=1000 * r,
            engine="vectorized", total_messages=stacked_msgs,
            **stacked_params)[0] for r in range(n_lanes)]
        wall_serial = time.time() - t0
        # the same cells as ONE lane-stacked engine run
        t0 = time.time()
        stacked = run_many([r.spec for r in serial])
        wall_stacked = time.time() - t0
        assert np.array_equal(serial[0].consume_times,
                              stacked[0].consume_times)
        rej = [int(r.rejected_publishes) for r in stacked]
        return {"wall_serial": wall_serial, "wall_stacked": wall_stacked,
                "speedup": wall_serial / wall_stacked, "n_lanes": n_lanes,
                "rej_min": min(rej), "rej_max": max(rej)}

    c = cache.get_or(
        cache_key(f"overflow|stacked|dts|{PARITY_NC}|l{n_lanes}"
                  f"|{stacked_msgs}", engine="vectorized",
                  **stacked_params), stacked_cell)
    rows.append((f"overflow/stacked/dts/c{PARITY_NC}/l{n_lanes}",
                 c["wall_stacked"] * 1e6 / max(1, c["n_lanes"]),
                 f"speedup={c['speedup']:.2f}x (serial "
                 f"{c['wall_serial']:.1f}s stacked "
                 f"{c['wall_stacked']:.1f}s) "
                 f"rej/lane=[{c['rej_min']},{c['rej_max']}]"))
    return rows
