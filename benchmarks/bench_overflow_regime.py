"""Overflow-regime stress sweep: reject-publish + credit-flow blocking.

The paper's configurations never trigger RabbitMQ's overflow machinery
(queue backlogs stay far below both the byte caps and the credit-flow
threshold).  This bench pushes StreamSim into that regime — small confirm
window, slow consumers, tight per-queue byte caps — and sweeps it to
consumer counts only the vectorized engine can run interactively.

Cell families:

* ``overflow/parity/*`` — the heap and vectorized engines on the same
  both-mechanisms cell (cap ~6% above the credit threshold, 4
  producers/consumers, jitter off); 'derived' carries the throughput
  deviation and the rejected/blocked counters side by side.
* ``overflow/scale/*``  — vectorized-only reject-publish sweeps at 64,
  256 and 1024 consumers with a fixed small queue cap and a fixed
  aggregate drain rate (consumer processing time scales with the fleet,
  so producers outpace the drain at every size and the queue pins at its
  cap — the pure overflow/retry path at affordable message volumes).

Set ``OVERFLOW_BENCH_SMOKE=1`` to run only the parity cell and the
64-consumer scale cell (the CI smoke configuration).
"""

from __future__ import annotations

import os
import time

from benchmarks.common import Cache, cache_key
from repro.core.broker import ClassicQueue
from repro.core.metrics import summarize
from repro.core.patterns import OVERFLOW_STRESS_DEFAULTS, overflow_stress
from repro.core.workloads import DSTREAM

PARITY_NC = 4
SCALE_NCS = (64, 256, 1024)
SCALE_CAP_MSGS = 2048
SCALE_MSGS = 32768
SCALE_MSGS_SMOKE = 8192       # CI smoke: one short reject-retry episode
#: per-consumer processing seconds per fleet member: fixes the aggregate
#: drain at 1/SCALE_PROC_PER_NC ~ 4000 msg/s regardless of consumer count
SCALE_PROC_PER_NC = 250e-6


def _summ(r) -> dict:
    s = summarize(r)
    return {"feasible": r.feasible,
            "throughput": s.throughput_msgs_s,
            "median_rtt": s.median_rtt_s,
            "rejected": int(r.rejected_publishes),
            "blocked": int(r.blocked_confirms)}


def run(cache: Cache):
    smoke = bool(os.environ.get("OVERFLOW_BENCH_SMOKE"))
    rows = []

    parity_cap = int(ClassicQueue.FLOW_CREDIT * PARITY_NC * 1.06)
    parity_params = dict(OVERFLOW_STRESS_DEFAULTS, jitter=0.0,
                         queue_max_bytes=parity_cap * DSTREAM.payload_bytes)

    def parity_cell() -> dict:
        out = {}
        for eng in ("heap", "vectorized"):
            t0 = time.time()
            r = overflow_stress("dts", PARITY_NC, engine=eng,
                                **parity_params)[0]
            out[eng] = _summ(r)
            out[eng]["wall"] = time.time() - t0
        return out

    c = cache.get_or(
        cache_key(f"overflow|parity|dts|{PARITY_NC}", engine="vectorized",
                  **parity_params), parity_cell)
    h, v = c["heap"], c["vectorized"]
    dev = 100.0 * (v["throughput"] - h["throughput"]) / h["throughput"]
    rows.append((f"overflow/parity/dts/c{PARITY_NC}",
                 1e6 / v["throughput"],
                 f"dev={dev:+.2f}% rej={h['rejected']}/{v['rejected']} "
                 f"blk={h['blocked']}/{v['blocked']} (heap/vec)"))

    msgs = SCALE_MSGS_SMOKE if smoke else SCALE_MSGS
    for nc in SCALE_NCS:
        if smoke and nc != SCALE_NCS[0]:
            continue
        scale_params = dict(
            OVERFLOW_STRESS_DEFAULTS,
            consumer_proc_s=SCALE_PROC_PER_NC * nc,
            queue_max_bytes=SCALE_CAP_MSGS * DSTREAM.payload_bytes)

        def scale_cell(nc=nc, scale_params=scale_params) -> dict:
            r = overflow_stress(
                "dts", nc, queue_cap_msgs=SCALE_CAP_MSGS,
                total_messages=msgs, engine="vectorized",
                **scale_params)[0]
            return _summ(r)

        c = cache.get_or(
            cache_key(f"overflow|scale|dts|{nc}|{SCALE_CAP_MSGS}"
                      f"|{msgs}", engine="vectorized",
                      **scale_params), scale_cell)
        rows.append((f"overflow/scale/dts/c{nc}",
                     1e6 / c["throughput"],
                     f"thr={c['throughput']:.0f}msg/s "
                     f"rej={c['rejected']} blk={c['blocked']}"))
    return rows
