"""Cross-architecture deployment-feasibility study (paper §6).

The paper argues *qualitatively* that MSS trades per-message overhead
for multi-user deployment feasibility, DTS needs a per-user minimal-hop
path, and PRS sits between.  This bench runs the quantitative version
(`patterns.deployment_feasibility`): the same 1 -> 64 tenant sweep over
all three deployment models —

* ``dts`` — per-tenant dedicated S2DS tunnel pairs terminating on the
  facility gateway (contention at the shared gateway NIC + per-tunnel
  process overhead on the gateway host);
* ``prs-haproxy`` — every tenant multiplexes the one shared proxy pair
  ahead of per-tenant vhost queues;
* ``mss`` — the managed LB + ingress + broker fabric.

Rows: per (arch, tenant-count) cell, per-tenant throughput / RTT / Jain
fairness / degradation vs the single-tenant deployment / shared-ingress
utilization — plus

* a per-arch heap-vs-vectorized parity cell on the smallest multi-
  tenant point (the <= 5% engine contract on the new topology), and
* the headline ``deploy/crossover`` row: the interpolated tenant count
  where MSS's shared broker overtakes per-tenant DTS tunnels, and DTS's
  ingress utilization there.

``DEPLOY_BENCH_SMOKE=1`` shrinks the sweep for CI.  The same grid is
also runnable through the campaign CLI: ``python -m benchmarks.run
--campaign deployment`` (see :data:`DEPLOYMENT_CAMPAIGN`).
"""

from __future__ import annotations

import dataclasses
import os

from benchmarks.common import Cache, cache_key, resolve_engine
from repro.core.metrics import summarize
from repro.core.patterns import DEPLOYMENT_ARCHS, deployment_feasibility
from repro.core.simulator import ExperimentSpec, SimParams, run_experiment
from repro.core.workloads import DSTREAM

SMOKE = os.environ.get("DEPLOY_BENCH_SMOKE") == "1"

if SMOKE:
    TENANTS = (1, 4, 16, 64)
    MSGS = 64
    N_RUNS = 1
else:
    TENANTS = (1, 2, 4, 8, 16, 32, 64)
    MSGS = 256
    N_RUNS = 3
# the parity cell stays the same in smoke mode: below ~100 messages per
# tenant the throughput estimator's own noise exceeds the 5% band
PARITY_TENANTS = 4
PARITY_MSGS = 128

#: the same three-arch tenant grid as a campaign spec
#: (``python -m benchmarks.run --campaign deployment``): a fixed
#: 16-client fleet partitioned into 1..16 tenants, so every
#: (arch x tenants) cell's 3 seeds stack through one batched run
DEPLOYMENT_CAMPAIGN = {
    "name": "deployment",
    "patterns": ["feedback"],
    "architectures": list(DEPLOYMENT_ARCHS),
    "workloads": ["dstream"],
    "consumers": [16],
    "tenants": [1, 2, 4, 8, 16],
    "tenant_isolation": "vhost",
    "n_runs": 3,
    "total_messages": 2048,
}


def _study_cells() -> dict:
    study = deployment_feasibility(
        tenant_counts=TENANTS, messages_per_tenant=MSGS, n_runs=N_RUNS,
        engine=resolve_engine(None))
    return {
        "curves": {arch: [dataclasses.asdict(p) for p in pts]
                   for arch, pts in study.curves.items()},
        "crossover_tenants": study.crossover_tenants,
        "crossover_utilization": study.crossover_utilization,
        "headline": study.headline(),
    }


def _parity_spec(arch: str, engine: str) -> ExperimentSpec:
    T = PARITY_TENANTS
    return ExperimentSpec(
        pattern="feedback", workload=DSTREAM, arch=arch,
        n_producers=T, n_consumers=T, total_messages=T * PARITY_MSGS,
        params=SimParams(seed=0, engine=engine),
        tenants=T, tenant_isolation="vhost")


def _parity_cell() -> dict:
    """Heap-vs-vectorized deviation on one multi-tenant cell per arch
    (the <= 5% contract on the new tenant-aware topologies)."""
    out = {}
    for arch in DEPLOYMENT_ARCHS:
        hs = summarize(run_experiment(_parity_spec(arch, "heap")))
        vs = summarize(run_experiment(_parity_spec(arch, "vectorized")))
        dev = max(
            abs(vs.throughput_msgs_s - hs.throughput_msgs_s)
            / hs.throughput_msgs_s,
            abs(vs.median_rtt_s - hs.median_rtt_s) / hs.median_rtt_s)
        out[arch] = dev
        assert dev <= 0.05, (
            f"multi-tenant {arch} heap/vec deviation {dev:.3f} > 5%")
    return {"dev": out, "tenants": PARITY_TENANTS}


def run(cache: Cache):
    rows = []
    tag = f"{'-'.join(map(str, TENANTS))}|m{MSGS}|r{N_RUNS}"
    c = cache.get_or(cache_key(f"deploy|study|{tag}"), _study_cells)
    for arch in DEPLOYMENT_ARCHS:
        for p in c["curves"][arch]:
            name = f"deploy/{arch}/t{p['tenants']}"
            if not p["feasible"]:
                rows.append((name, float("nan"), "INFEASIBLE"))
                continue
            thr = p["tenant_throughput_msgs_s"]
            rows.append((name, 1e6 / thr if thr else float("nan"),
                         f"thr/tenant={thr:.0f}msg/s "
                         f"rtt={p['tenant_median_rtt_s'] * 1e3:.0f}ms "
                         f"fairness={p['fairness']:.3f} "
                         f"degradation={p['degradation']:.2f} "
                         f"ingress_util={p['ingress_utilization']:.2f}"))

    pk = cache_key(f"deploy|parity|t{PARITY_TENANTS}|m{PARITY_MSGS}")
    pc = cache.get_or(pk, _parity_cell)
    for arch, dev in pc["dev"].items():
        rows.append((f"deploy/parity/{arch}/t{pc['tenants']}",
                     float("nan"), f"heap_vs_vec_dev={100 * dev:.2f}%"))

    ct = c["crossover_tenants"]
    rows.append(("deploy/crossover", float("nan"),
                 (f"crossover_tenants={ct:.1f} "
                  f"dts_ingress_util={c['crossover_utilization']:.2f}"
                  if ct == ct else "no-crossover-in-sweep")
                 + f" :: {c['headline']}"))
    return rows
