"""Paper Table 1: workload streaming characteristics (verification that the
generators produce exactly the published parameters)."""

from repro.core.workloads import DSTREAM, GENERIC, LSTREAM


def run(cache):
    rows = []
    for wl, rate in ((DSTREAM, 32.0), (LSTREAM, 30.0), (GENERIC, 25.0)):
        mps = wl.messages_per_second_at_rate()
        rows.append((f"table1/{wl.name}/payload", 0.0,
                     f"bytes={wl.payload_bytes} fmt={wl.payload_format.value} "
                     f"events/msg={wl.events_per_message}"))
        rows.append((f"table1/{wl.name}/rate", 1e6 / mps,
                     f"{rate}Gbps => {mps:.0f} msgs/s nominal"))
    return rows
