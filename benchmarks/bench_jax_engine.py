"""JAX engine benchmarks: compile-amortized kernel speedup + end-to-end
engine comparison on the deployment grid.

Two cell families:

* ``jaxeng/kernel/*`` — the headline gate.  The deployment campaign's
  measured ``_fifo_scan`` call profile is ~26k calls per 3-seed group,
  overwhelmingly tiny cohorts (1-16 steps x seed lanes): at those
  shapes the NumPy engine's cost is per-call overhead, not arithmetic.
  The JAX engine's pad-and-mask contract buckets every cohort to a
  power-of-two shape, so a whole campaign round's worth of scans
  batches through **one** ``fifo_scan_cells`` device program
  (``vmap`` over the cell axis of an already lane-vmapped kernel).
  These rows time that call — jit-compiled once, then amortized —
  against the equivalent NumPy call loop, and **assert the >= 2x
  speedup gate** the PR promises (measured ~4-10x on the profile
  shapes; compile time is reported separately, never counted).

* ``jaxeng/e2e/*`` — whole deployment-grid cells run through
  ``run_many`` on ``engine="jax"`` (with the whole-run device program
  requested via ``jax_device_loop=True``; see
  :mod:`repro.core.jax_device_loop`) vs ``engine="vectorized"``,
  wall-clock + throughput parity in 'derived'.  These rows **assert the
  >= 1x end-to-end gate**: with the cohort event loop lifted into one
  ``lax.scan`` device program the jax engine must at least match the
  NumPy engine's wall clock on cells the wave model supports (measured
  ~20x on this grid; jit compile time is reported separately and never
  counted — the compiled program is shape-bucketed and amortizes across
  a campaign).  Throughput parity is asserted at the
  ``device_loop.all.throughput`` band from :mod:`repro.core.parity`.

``JAX_BENCH_SMOKE=1`` shrinks call counts and the e2e grid for CI.
Without jax importable, every row degrades to ``SKIPPED:no-jax``
instead of failing (mirroring ``run_many``'s per-cell fallback).
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import Cache, cache_key, plain_key
from repro.core.jax_engine import jax_available
from repro.core.metrics import summarize
from repro.core.simulator import ExperimentSpec, SimParams
from repro.core.workloads import DSTREAM

SMOKE = os.environ.get("JAX_BENCH_SMOKE") == "1"

#: the >= 2x compile-amortized kernel gate (PR acceptance)
KERNEL_SPEEDUP_GATE = 2.0

#: the >= 1x end-to-end gate: the device-programmed jax engine must not
#: lose to the NumPy cohort loop on wave-supported deployment cells
#: (compile excluded; measured ~20x once compiled)
E2E_SPEEDUP_GATE = 1.0

#: (calls, cohort, lanes) kernel shapes from the measured deployment-
#: grid profile: 3-seed groups pad their cohorts into pow2 buckets
#: dominated by N<=16 at L=3 lanes
KERNEL_SHAPES = ([(256, 16, 3), (256, 4, 3)] if SMOKE
                 else [(4096, 16, 3), (4096, 4, 3)])
REPS = 3 if SMOKE else 7

E2E_SEEDS = (0, 1000, 2000)
E2E_MSGS = 256 if SMOKE else 2048
E2E_ARCHS = ("mss",) if SMOKE else ("dts", "prs-haproxy", "mss")
E2E_TENANTS = 4


def _profile_arrays(C: int, N: int, L: int):
    rng = np.random.default_rng(0)
    a = np.sort(rng.uniform(0.0, 10.0, (C, N, L)), axis=1)
    h = rng.uniform(0.0, 1e-3, (C, N, L))
    return a, h, np.zeros((C, L))


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _kernel_cell(C: int, N: int, L: int) -> dict:
    from repro.core.jax_engine import _kernels
    from repro.core.vectorized import _fifo_scan
    K = _kernels()
    a, h, carry = _profile_arrays(C, N, L)

    t0 = time.perf_counter()
    out_j = np.asarray(K.fifo_scan_cells(a, h, carry))   # includes compile
    compile_s = time.perf_counter() - t0
    out_n = np.stack([_fifo_scan(a[i], h[i], carry[i]) for i in range(C)])
    np.testing.assert_allclose(out_j, out_n, rtol=1e-12)

    wall_np = _best_of(
        lambda: [_fifo_scan(a[i], h[i], carry[i]) for i in range(C)], REPS)
    wall_jx = _best_of(
        lambda: np.asarray(K.fifo_scan_cells(a, h, carry)), REPS)
    speedup = wall_np / wall_jx
    assert speedup >= KERNEL_SPEEDUP_GATE, (
        f"jax fifo_scan_cells ({C}x{N}x{L}) compile-amortized speedup "
        f"{speedup:.2f}x < {KERNEL_SPEEDUP_GATE}x gate "
        f"(numpy {wall_np * 1e3:.2f}ms, jax {wall_jx * 1e3:.2f}ms)")
    return {"wall_np_s": wall_np, "wall_jax_s": wall_jx,
            "speedup": speedup, "compile_s": compile_s}


def _e2e_specs(arch: str, engine: str) -> list:
    # work_sharing is the wave model's broadly-validated regime (the
    # feedback corridor is narrow; see _device_loop_ok) — these cells
    # sit squarely inside it at the full deployment-grid scale
    device = True if engine == "jax" else None
    return [ExperimentSpec(
        pattern="work_sharing", workload=DSTREAM, arch=arch,
        n_producers=16, n_consumers=16, total_messages=E2E_MSGS,
        params=SimParams(seed=s, engine=engine, jax_device_loop=device),
        tenants=E2E_TENANTS, tenant_isolation="vhost")
        for s in E2E_SEEDS]


def _e2e_cell(arch: str) -> dict:
    from repro.core.parity import band
    from repro.core.vectorized import run_many
    out = {}
    # first jax call jit-compiles the device program for this shape
    # bucket; time it separately so the gate measures the amortized
    # cost a campaign actually pays
    t0 = time.perf_counter()
    run_many(_e2e_specs(arch, "jax"))
    compile_s = time.perf_counter() - t0
    for engine in ("vectorized", "jax"):
        t0 = time.perf_counter()
        rs = run_many(_e2e_specs(arch, engine))
        wall = time.perf_counter() - t0
        s = summarize(rs[0])
        out[engine] = {"wall_s": wall, "thr": s.throughput_msgs_s,
                       "rtt": s.median_rtt_s, "ran_on": s.engine}
    v, j = out["vectorized"], out["jax"]
    out["thr_dev"] = abs(j["thr"] - v["thr"]) / v["thr"]
    out["compile_s"] = compile_s
    out["speedup"] = v["wall_s"] / j["wall_s"]
    assert out["thr_dev"] <= band("device_loop.all.throughput"), (
        f"e2e {arch}: device-program throughput deviates "
        f"{100 * out['thr_dev']:.2f}% from the vectorized engine, "
        f"outside the device_loop.all.throughput band")
    assert out["speedup"] >= E2E_SPEEDUP_GATE, (
        f"e2e {arch}: jax engine (device program) {j['wall_s']:.2f}s "
        f"vs vectorized {v['wall_s']:.2f}s — speedup "
        f"{out['speedup']:.2f}x < {E2E_SPEEDUP_GATE}x gate")
    return out


def run(cache: Cache):
    rows = []
    if not jax_available():
        for C, N, L in KERNEL_SHAPES:
            rows.append((f"jaxeng/kernel/fifo/c{C}xn{N}xl{L}",
                         float("nan"), "SKIPPED:no-jax"))
        for arch in E2E_ARCHS:
            rows.append((f"jaxeng/e2e/{arch}/t{E2E_TENANTS}",
                         float("nan"), "SKIPPED:no-jax"))
        return rows

    for C, N, L in KERNEL_SHAPES:
        c = cache.get_or(
            plain_key(f"jaxeng|kernel|c{C}|n{N}|l{L}|r{REPS}"),
            lambda C=C, N=N, L=L: _kernel_cell(C, N, L))
        rows.append((
            f"jaxeng/kernel/fifo/c{C}xn{N}xl{L}",
            1e6 * c["wall_jax_s"] / C,
            f"speedup={c['speedup']:.1f}x (gate>={KERNEL_SPEEDUP_GATE}x) "
            f"numpy={c['wall_np_s'] * 1e3:.2f}ms "
            f"jax={c['wall_jax_s'] * 1e3:.2f}ms "
            f"compile={c['compile_s'] * 1e3:.0f}ms"))

    for arch in E2E_ARCHS:
        c = cache.get_or(
            cache_key(f"jaxeng|e2e|ws-dev|{arch}|t{E2E_TENANTS}"
                      f"|m{E2E_MSGS}", engine="jax"),
            lambda arch=arch: _e2e_cell(arch))
        v, j = c["vectorized"], c["jax"]
        rows.append((
            f"jaxeng/e2e/{arch}/t{E2E_TENANTS}",
            1e6 / j["thr"] if j["thr"] else float("nan"),
            f"speedup={c.get('speedup', float('nan')):.1f}x "
            f"(gate>={E2E_SPEEDUP_GATE}x) "
            f"thr_dev={100 * c['thr_dev']:.2f}% "
            f"wall_vec={v['wall_s']:.1f}s wall_jax={j['wall_s']:.1f}s "
            f"compile={c.get('compile_s', float('nan')):.1f}s "
            f"ran_on={j['ran_on']}"))
    return rows
