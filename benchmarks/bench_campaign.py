"""Campaign layer benchmarks: batched grids + multi-tenant MSS curve.

Three cell families:

* ``campaign/batched_vs_serial`` — the same (pattern x arch x consumers
  x 3 seeds) grid through ``patterns.sweep`` (the serial cell-at-a-time
  loop) and through ``campaign.run_campaign`` (seed-stacked batched
  runs + process fan-out).  'derived' carries the wall-clock speedup —
  the PR-3 >=2x acceptance gate — and the worst averaged-summary
  deviation between the two paths.
* ``campaign/stacked_overflow`` — the same comparison on an
  *overflow-regime* cell (tight queue cap, reject-publish + retry
  active): seed lanes stacked through one lane-resolved flow-control
  event loop vs per-cell serial runs.  'derived' carries the speedup —
  the PR-4 >=2x acceptance gate — plus the worst per-lane summary
  deviation from each seed's solo *heap* run (the <=5% contract) and
  the per-lane reject counts.
* ``campaign/multi_tenant/*`` — the paper's §6 MSS multi-user
  scalability claim made quantitative: N independent feedback workflows
  (1 producer + 1 consumer each) share one managed broker, N sweeping
  1 -> 64.  'derived' reports per-tenant throughput, RTT, the Jain
  fairness index and degradation vs the single-tenant baseline.

``CAMPAIGN_BENCH_SMOKE=1`` shrinks all families for CI.
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import Cache, cache_key, resolve_engine
from repro.core.broker import ClassicQueue
from repro.core.campaign import CampaignSpec, run_campaign
from repro.core.metrics import summarize
from repro.core.patterns import (
    OVERFLOW_STRESS_DEFAULTS, multi_tenant, sweep)
from repro.core.simulator import ExperimentSpec, SimParams, run_experiment
from repro.core.workloads import DSTREAM

SMOKE = os.environ.get("CAMPAIGN_BENCH_SMOKE") == "1"

if SMOKE:
    GRID = dict(patterns=("feedback",), architectures=("mss",),
                consumers=(4,), n_runs=3, total_messages=512)
    TENANTS = (1, 4, 16)
    TENANT_MSGS = 64
    TENANT_RUNS = 1
    OVF = dict(nc=2, msgs=2048, n_seeds=4, heap=False)
else:
    GRID = dict(patterns=("feedback",), architectures=("dts", "mss"),
                consumers=(4, 8), n_runs=3, total_messages=2048)
    TENANTS = (1, 2, 4, 8, 16, 32, 64)
    TENANT_MSGS = 256
    TENANT_RUNS = 3
    OVF = dict(nc=4, msgs=8192, n_seeds=4, heap=True)


def _speedup_cell() -> dict:
    # pin the --engine-resolved engine into the cells so the runs match
    # the engine name the cache key carries (seed stacking only applies
    # on the vectorized engine; heap cells fall back per-cell)
    eng = resolve_engine(None)
    spec = CampaignSpec(name="bench-grid", workloads=("dstream",),
                        params={"engine": eng}, **GRID)
    t0 = time.time()
    res = run_campaign(spec, cache=None)     # cold: measure execution
    wall_campaign = time.time() - t0
    t0 = time.time()
    serial = sweep(GRID["patterns"][0], GRID["architectures"], "dstream",
                   consumers=GRID["consumers"], n_runs=GRID["n_runs"],
                   total_messages=GRID["total_messages"], engine=eng)
    wall_serial = time.time() - t0
    by_cell = {(s.arch, s.n_consumers): s for s in res.averaged}
    dev = 0.0
    for s in serial:
        c = by_cell[(s.arch, s.n_consumers)]
        dev = max(dev, abs(c.throughput_msgs_s - s.throughput_msgs_s)
                  / s.throughput_msgs_s)
        if s.median_rtt_s == s.median_rtt_s:   # not NaN
            dev = max(dev, abs(c.median_rtt_s - s.median_rtt_s)
                      / s.median_rtt_s)
    return {"wall_campaign": wall_campaign, "wall_serial": wall_serial,
            "speedup": wall_serial / wall_campaign,
            "n_cells": len(res.cells), "max_summary_dev": dev}


def _overflow_spec(seed: int, engine: str) -> ExperimentSpec:
    nc = OVF["nc"]
    cap = int(ClassicQueue.FLOW_CREDIT * nc * 1.06) * DSTREAM.payload_bytes
    return ExperimentSpec(
        pattern="feedback", workload=DSTREAM, arch="dts",
        n_producers=nc, n_consumers=nc, total_messages=OVF["msgs"],
        params=SimParams(seed=seed, engine=engine, queue_max_bytes=cap,
                         **OVERFLOW_STRESS_DEFAULTS))


def _stacked_overflow_cell() -> dict:
    """Stacked overflow grid: N seed-lanes of one reject-publish cell
    through the lane-resolved batched event loop vs N per-cell runs.
    Flow control is lane-resolved, so this regime — which PR 3 had to
    run per-cell — now batches; the per-lane contract is checked
    against each seed's solo heap run."""
    from repro.core.vectorized import run_many
    seeds = [1000 * r for r in range(OVF["n_seeds"])]
    specs = [_overflow_spec(s, "vectorized") for s in seeds]
    t0 = time.time()
    serial = [run_experiment(s) for s in specs]
    wall_serial = time.time() - t0
    t0 = time.time()
    stacked = run_many(specs)
    wall_stacked = time.time() - t0
    dev = 0.0
    if OVF["heap"]:
        for s, v in zip(seeds, stacked):
            hs = summarize(run_experiment(_overflow_spec(s, "heap")))
            vs = summarize(v)
            dev = max(dev,
                      abs(vs.throughput_msgs_s - hs.throughput_msgs_s)
                      / hs.throughput_msgs_s,
                      abs(vs.median_rtt_s - hs.median_rtt_s)
                      / hs.median_rtt_s)
    else:   # smoke: deviation vs the per-cell vectorized runs instead
        for a, b in zip(serial, stacked):
            sa, sb = summarize(a), summarize(b)
            dev = max(dev, abs(sb.throughput_msgs_s - sa.throughput_msgs_s)
                      / sa.throughput_msgs_s)
    assert all(r.rejected_publishes > 0 for r in stacked)
    assert np.array_equal(serial[0].consume_times, stacked[0].consume_times)
    return {"wall_serial": wall_serial, "wall_stacked": wall_stacked,
            "speedup": wall_serial / wall_stacked,
            "n_lanes": len(seeds), "max_lane_dev": dev,
            "vs": "heap" if OVF["heap"] else "vectorized",
            "rejected": [int(r.rejected_publishes) for r in stacked]}


def run(cache: Cache):
    rows = []

    grid_tag = (f"{'x'.join(GRID['architectures'])}|"
                f"c{'-'.join(map(str, GRID['consumers']))}|"
                f"m{GRID['total_messages']}|r{GRID['n_runs']}")
    c = cache.get_or(cache_key(f"campaign|batched_vs_serial|{grid_tag}"),
                     _speedup_cell)
    rows.append((f"campaign/batched_vs_serial/{grid_tag}",
                 c["wall_campaign"] * 1e6 / max(1, c["n_cells"]),
                 f"speedup={c['speedup']:.2f}x (serial "
                 f"{c['wall_serial']:.1f}s campaign "
                 f"{c['wall_campaign']:.1f}s, {c['n_cells']} cells) "
                 f"max_dev={100 * c['max_summary_dev']:.2f}%"))

    ovf_tag = f"dts|c{OVF['nc']}|m{OVF['msgs']}|l{OVF['n_seeds']}"
    ovf_params = dict(OVERFLOW_STRESS_DEFAULTS,
                      queue_max_bytes=int(ClassicQueue.FLOW_CREDIT
                                          * OVF["nc"] * 1.06)
                      * DSTREAM.payload_bytes)
    c = cache.get_or(
        cache_key(f"campaign|stacked_overflow|{ovf_tag}",
                  engine="vectorized", **ovf_params),
        _stacked_overflow_cell)
    rows.append((f"campaign/stacked_overflow/{ovf_tag}",
                 c["wall_stacked"] * 1e6 / max(1, c["n_lanes"]),
                 f"speedup={c['speedup']:.2f}x (serial "
                 f"{c['wall_serial']:.1f}s stacked "
                 f"{c['wall_stacked']:.1f}s, {c['n_lanes']} lanes) "
                 f"max_lane_dev={100 * c['max_lane_dev']:.2f}% "
                 f"vs {c['vs']} rej={c['rejected']}"))

    def tenant_cells() -> dict:
        pts = multi_tenant("mss", TENANTS,
                           messages_per_tenant=TENANT_MSGS,
                           n_runs=TENANT_RUNS,
                           engine=resolve_engine(None))
        return {str(p.tenants): {
            "thr": p.tenant_throughput_msgs_s,
            "rtt": p.tenant_median_rtt_s,
            "fairness": p.fairness,
            "degradation": p.degradation,
            "feasible": p.feasible} for p in pts}

    key = cache_key(
        f"campaign|multi_tenant|mss|{'-'.join(map(str, TENANTS))}"
        f"|m{TENANT_MSGS}|r{TENANT_RUNS}")
    cells = cache.get_or(key, tenant_cells)
    for t in TENANTS:
        p = cells[str(t)]
        if not p["feasible"]:
            rows.append((f"campaign/multi_tenant/mss/t{t}", float("nan"),
                         "INFEASIBLE"))
            continue
        rows.append((f"campaign/multi_tenant/mss/t{t}",
                     1e6 / p["thr"] if p["thr"] else float("nan"),
                     f"thr/tenant={p['thr']:.0f}msg/s "
                     f"rtt={p['rtt'] * 1e3:.0f}ms "
                     f"fairness={p['fairness']:.3f} "
                     f"degradation={p['degradation']:.2f}"))
    return rows
