"""Paper Fig 6: median RTT under work sharing with feedback (Dstream +
Lstream). PRS-Stunnel excluded as in the paper (poor earlier results)."""

from benchmarks.common import rtt_row, sim_cell

PAPER_S = {
    ("mss", "lstream", 64): 40.0,       # severe bottleneck @64
    ("mss", "dstream", 64): 1.8,
}
ARCHS = ("dts", "prs-haproxy", "mss")
SWEEP = (1, 2, 4, 8, 16, 32, 64)


def run(cache):
    rows = []
    for wl, msgs in (("dstream", 3072), ("lstream", 1536)):
        for arch in ARCHS:
            for nc in SWEEP:
                cell = sim_cell(cache, "feedback", arch, wl, nc, msgs)
                rows.append(rtt_row(f"fig6/{wl}/{arch}/c{nc}", cell,
                                    PAPER_S.get((arch, wl, nc))))
    return rows
