"""§Roofline: per (arch x shape) terms from the dry-run artifact
(results/dryrun.json, single-pod mesh). One row per baseline cell; the
'derived' column packs the three terms + dominant bottleneck + the
useful-compute ratio."""

import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results",
                      "dryrun.json")


def run(cache):
    rows = []
    if not os.path.exists(DRYRUN):
        return [("roofline/missing", float("nan"),
                 "run repro.launch.dryrun first")]
    with open(DRYRUN) as f:
        data = json.load(f)
    for key, rec in sorted(data.items()):
        if rec.get("mesh") != "single" or not rec.get("ok"):
            continue
        rl = rec["roofline"]
        rows.append((
            f"roofline/{rec['arch']}/{rec['shape']}",
            rl["bound_step_s"] * 1e6,
            f"comp={rl['compute_s']:.3f}s mem={rl['memory_s']:.3f}s "
            f"coll={rl['collective_s']:.3f}s dom={rl['dominant'][:-2]} "
            f"useful={rl['useful_compute_ratio']:.2f} "
            f"frac={rl['roofline_fraction']:.3f}"))
    n_multi = sum(1 for r in data.values()
                  if r.get("mesh") == "multi" and r.get("ok"))
    rows.append(("roofline/multi_pod_cells_ok", 0.0,
                 f"{n_multi} multi-pod cells compiled"))
    return rows
