"""Kernel micro-benchmarks: wall time of the interpret-mode Pallas kernels
vs their jnp oracles (CPU; correctness-oriented — real perf is the TPU
target) + analytic MXU utilization of the chosen BlockSpecs."""

import time

import jax
import jax.numpy as jnp

from benchmarks.common import plain_key

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(cache):
    def compute():
        k = jax.random.key(0)
        B, S, H, hd = 1, 512, 4, 64
        q = jax.random.normal(k, (B, S, H, hd), jnp.float32)
        kv = jax.random.normal(k, (B, S, H, hd), jnp.float32)
        pos = jnp.arange(S)
        rows = []
        us_k = _time(lambda a, b, c: ops.flash_attention(
            a, b, c, pos, pos, block_q=128, block_k=128), q, kv, kv)
        us_r = _time(lambda a, b, c: ref.flash_attention_ref(
            a, b, c, pos, pos), q, kv, kv)
        rows.append(["kernels/flash_attention/interp", us_k,
                     f"oracle={us_r:.0f}us blocks=128x128 "
                     f"vmem~{(128 * hd * 3 + 128 * 128) * 4 / 1024:.0f}KiB"])
        qd = jax.random.normal(k, (2, H, hd), jnp.float32)
        cache_ = jax.random.normal(k, (2, 1024, H, hd), jnp.float32)
        posd = jnp.array([800, 900], jnp.int32)
        us_k = _time(lambda a: ops.flash_decode(a, cache_, cache_, posd,
                                                block_k=128), qd)
        rows.append(["kernels/flash_decode/interp", us_k, "block_k=128"])
        x = jax.random.normal(k, (4096, 512), jnp.bfloat16)
        w = jax.random.normal(k, (512,), jnp.float32) * 0.1
        us_k = _time(lambda a: ops.rmsnorm(a, w, block_rows=256), x)
        rows.append(["kernels/rmsnorm/interp", us_k, "block_rows=256"])
        st = jax.random.normal(k, (2, 8, 4, 16, 32), jnp.float32)
        tot = -jnp.abs(jax.random.normal(k, (2, 8, 4)))
        C = jax.random.normal(k, (2, 8, 64, 32), jnp.float32)
        cum = -jnp.abs(jax.random.normal(k, (2, 8, 64, 4)))
        us_k = _time(lambda a: ops.ssd_state_scan(a, tot, C, cum), st)
        rows.append(["kernels/ssd_state_scan/interp", us_k,
                     "fused inter-chunk recurrence"])
        return rows
    return [tuple(r) for r in cache.get_or(plain_key("kernels/micro"), compute)]
