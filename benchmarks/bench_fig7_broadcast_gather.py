"""Paper Fig 7: (a) broadcast throughput and (b) broadcast+gather median
RTT for the generic 4 MiB workload."""

from benchmarks.common import rtt_row, sim_cell, thr_row

PAPER_THR = {("mss", 8): 110.0, ("mss", 64): 110.0}
SWEEP = (1, 2, 4, 8, 16, 32, 64)


def run(cache):
    rows = []
    for arch in ("dts", "prs-haproxy", "mss"):
        for nc in SWEEP:
            cell = sim_cell(cache, "broadcast", arch, "generic", nc, 384)
            rows.append(thr_row(f"fig7a/{arch}/c{nc}", cell,
                                PAPER_THR.get((arch, nc))))
    for arch in ("dts", "prs-haproxy", "mss"):
        for nc in (1, 2, 4, 8, 16, 32):
            cell = sim_cell(cache, "broadcast_gather", arch, "generic", nc,
                            384)
            rows.append(rtt_row(f"fig7b/{arch}/c{nc}", cell))
    return rows
