"""Paper Fig 5: RTT CDFs under feedback. Validates the quoted CDF claims:
'for 64 consumers PRS keeps 80% of message RTTs under 0.7 s (Dstream) and
12.5 s (Lstream)'."""

from benchmarks.common import sim_cell


def run(cache):
    rows = []
    d = sim_cell(cache, "feedback", "prs-haproxy", "dstream", 64, 3072)
    f = (d.get("frac_under") or {}).get("0.7")
    rows.append(("fig5/dstream/prs/frac<0.7s@64", 0.0,
                 f"{(f or 0) * 100:.0f}% (paper: 80%)"))
    l = sim_cell(cache, "feedback", "prs-haproxy", "lstream", 64, 1536)
    f2 = (l.get("frac_under") or {}).get("12.5")
    rows.append(("fig5/lstream/prs/frac<12.5s@64", 0.0,
                 f"{(f2 or 0) * 100:.0f}% (paper: 80%)"))
    # rightward shift beyond 8 consumers (all archs)
    for arch in ("dts", "prs-haproxy", "mss"):
        a = sim_cell(cache, "feedback", arch, "dstream", 8, 3072)
        b = sim_cell(cache, "feedback", arch, "dstream", 64, 3072)
        shift = (b["p95_rtt"] or 0) / max(a["p95_rtt"] or 1e-9, 1e-9)
        rows.append((f"fig5/dstream/{arch}/p95shift_8to64", 0.0,
                     f"p95 x{shift:.1f} (paper: rightward shift)"))
    return rows
