"""Paper §6 projection: the same experiments once the DSNs' 100 Gbps NICs
become usable (and clients get 10 Gbps) — quantifies how far the 1 Gbps
links constrain every architecture today."""

from benchmarks.common import cache_key, resolve_engine
from repro.core.ds2hpc import ClusterInventory
from repro.core.metrics import summarize
from repro.core.patterns import run_pattern


def run(cache):
    def cell(key, arch, inv):
        def compute():
            r = run_pattern("work_sharing", arch, "dstream", 16,
                            total_messages=4096, n_runs=1,
                            engine=resolve_engine(), inventory=inv)[0]
            s = summarize(r)
            return {"feasible": r.feasible, "throughput": s.throughput_msgs_s}
        return cache.get_or(cache_key(key), compute)

    rows = []
    base = ClusterInventory()
    fast = base.highspeed()
    for arch in ("dts", "prs-haproxy", "mss"):
        b = cell(f"hs/base/{arch}", arch, base)
        f = cell(f"hs/fast/{arch}", arch, fast)
        gain = f["throughput"] / max(b["throughput"], 1e-9)
        rows.append((f"highspeed/{arch}/c16", 1e6 / f["throughput"],
                     f"{b['throughput']:.0f} -> {f['throughput']:.0f} msg/s "
                     f"(x{gain:.1f} with 100G DSNs)"))
    return rows
