"""Shared benchmark machinery: disk-cached simulator runs + CSV rows.

Row contract (benchmarks/run.py prints ``name,us_per_call,derived``):
  name        - benchmark cell id
  us_per_call - microseconds per *message* (1e6 / throughput) for
                throughput cells, or median RTT in us for latency cells
  derived     - paper reference value + deviation, or the measured
                secondary quantity

Cache keys are versioned and carry the *engine name* plus a fingerprint
of the fully-resolved :class:`SimParams` (defaults + overrides), so an
engine switch or a simulator-default change can never silently serve
stale numbers.  Legacy-format keys (pre-``v2|``) make the cache fail
loudly — see :class:`Cache`.
"""

from __future__ import annotations

import json
import os
from typing import Callable, Optional

from repro.core.campaign import CACHE_KEY_VERSION
from repro.core.campaign import params_fingerprint as _params_fingerprint
from repro.core.metrics import rtt_fraction_under, summarize
from repro.core.patterns import run_pattern
from repro.core.simulator import SimParams

CACHE_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                          "bench_cache.json")

# CACHE_KEY_VERSION (re-exported above from repro.core.campaign, the
# single definition): every cache key must start with it; anything else
# is a legacy key from before engine/params-aware keying and must not
# be served.

#: process-wide engine override (benchmarks/run.py --engine); None means
#: "whatever SimParams defaults to"
DEFAULT_ENGINE: Optional[str] = None


def resolve_engine(engine: Optional[str] = None, spec=None) -> str:
    """Effective engine for a benchmark cell: explicit argument, then the
    --engine override, then the SimParams default.

    When the cell's :class:`ExperimentSpec` is known, pass it: a
    requested ``jax`` cell that ``jax_supported`` rejects actually runs
    vectorized (the ``run_many`` fallback), and its cache key must say
    so — keying on the requested engine would serve those vectorized
    numbers to a later genuinely-jax run (cache poisoning)."""
    if engine is not None:
        eng = engine
    elif DEFAULT_ENGINE is not None:
        eng = DEFAULT_ENGINE
    else:
        eng = SimParams().engine
    if eng == "jax" and spec is not None:
        import dataclasses

        from repro.core.campaign import resolved_engine
        if spec.params.engine != eng:
            spec = dataclasses.replace(
                spec, params=dataclasses.replace(spec.params, engine=eng))
        eng = resolved_engine(spec)
    return eng


def params_fingerprint(engine: str, **params) -> str:
    """Short stable hash of the fully-resolved SimParams for a cell.

    Built from the constructed dataclass (defaults + overrides) with
    the one shared fingerprint construction
    (``repro.core.campaign.params_fingerprint``), so any change to
    simulator defaults — not just the overrides a bench passes —
    invalidates the cache entry, for bench and campaign cells alike."""
    return _params_fingerprint(SimParams(engine=engine, **params))


def cache_key(name: str, engine: Optional[str] = None, spec=None,
              **params) -> str:
    """Versioned cache key: ``v2|engine=<engine>|p=<fingerprint>|<name>``.

    Use for every cell whose value depends on a simulator run; cells with
    no simulator dependence may use :func:`plain_key`.  Pass ``spec``
    (the cell's :class:`ExperimentSpec`) whenever it is known so the key
    carries the *resolved* engine — see :func:`resolve_engine`."""
    eng = resolve_engine(engine, spec=spec)
    return (f"{CACHE_KEY_VERSION}|engine={eng}|"
            f"p={params_fingerprint(eng, **params)}|{name}")


def plain_key(name: str) -> str:
    """Versioned key for cells with no simulator dependence (kernels)."""
    return f"{CACHE_KEY_VERSION}|{name}"


class LegacyCacheError(RuntimeError):
    pass


class Cache:
    """Disk-backed benchmark cache.

    Refuses to operate on a cache file containing legacy-format keys
    (anything not ``v2|``-prefixed): those entries predate engine- and
    params-aware keying, so serving them after an engine change would
    silently report stale heap-engine numbers.  Delete the file (or the
    offending entries) to proceed — the bench runner re-measures."""

    def __init__(self, path: str = CACHE_PATH):
        self.path = os.path.abspath(path)
        self.data: dict = {}
        if os.path.exists(self.path):
            with open(self.path) as f:
                self.data = json.load(f)
            legacy = [k for k in self.data
                      if not k.startswith(f"{CACHE_KEY_VERSION}|")]
            if legacy:
                raise LegacyCacheError(
                    f"{self.path} contains {len(legacy)} legacy-format "
                    f"cache key(s) (e.g. {legacy[0]!r}) from before "
                    f"engine/params-aware keying; serving them could "
                    f"return stale numbers for the wrong engine. Delete "
                    f"the file and re-run to re-measure.")

    def get_or(self, key: str, fn: Callable[[], dict]) -> dict:
        if not key.startswith(f"{CACHE_KEY_VERSION}|"):
            raise LegacyCacheError(
                f"cache key {key!r} lacks the {CACHE_KEY_VERSION}| "
                f"version prefix; build it with cache_key()/plain_key()")
        if key not in self.data:
            self.data[key] = fn()
            self.save()
        return self.data[key]

    def save(self) -> None:
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        with open(self.path, "w") as f:
            json.dump(self.data, f, indent=1)


def sim_cell(cache: Cache, pattern: str, arch: str, workload: str,
             nc: int, msgs: int, n_runs: int = 1,
             engine: Optional[str] = None, **params) -> dict:
    from repro.core.patterns import pattern_spec
    rep = pattern_spec(pattern, arch, workload, nc, total_messages=msgs,
                       engine=resolve_engine(engine), **params)
    eng = resolve_engine(engine, spec=rep)
    key = cache_key(f"{pattern}|{arch}|{workload}|{nc}|{msgs}|{n_runs}",
                    engine=eng, **params)

    def compute() -> dict:
        rs = run_pattern(pattern, arch, workload, nc, total_messages=msgs,
                         n_runs=n_runs, engine=eng, **params)
        r = rs[0]
        if not r.feasible:
            return {"feasible": False, "reason": r.infeasible_reason}
        s = summarize(r)
        import numpy as np
        meds = [summarize(x).median_rtt_s for x in rs]
        thrs = [summarize(x).throughput_msgs_s for x in rs]
        return {
            "feasible": True,
            "throughput": float(np.nanmean(thrs)),
            "median_rtt": float(np.nanmean(meds)) if r.rtts.size else None,
            "min_rtt": s.min_rtt_s if r.rtts.size else None,
            "p95_rtt": s.p95_rtt_s if r.rtts.size else None,
            "frac_under": {
                str(t): rtt_fraction_under(r, t)
                for t in (0.7, 5.0, 12.5)} if r.rtts.size else None,
            "goodput_gbps": s.goodput_gbps,
            "rejected": s.rejected,
            "blocked": s.blocked,
        }

    return cache.get_or(key, compute)


def thr_row(name: str, cell: dict, paper: float | None = None):
    if not cell.get("feasible"):
        return (name, float("nan"), "INFEASIBLE:" + cell.get("reason", "")[:40])
    t = cell["throughput"]
    us = 1e6 / t if t else float("nan")
    if paper:
        dev = 100.0 * (t - paper) / paper
        return (name, us, f"thr={t:.0f}msg/s paper={paper:.0f} dev={dev:+.0f}%")
    return (name, us, f"thr={t:.0f}msg/s")


def rtt_row(name: str, cell: dict, paper_s: float | None = None):
    if not cell.get("feasible"):
        return (name, float("nan"), "INFEASIBLE")
    m = cell["median_rtt"]
    if paper_s:
        dev = 100.0 * (m - paper_s) / paper_s
        return (name, m * 1e6, f"rtt={m * 1e3:.0f}ms paper={paper_s * 1e3:.0f}ms dev={dev:+.0f}%")
    return (name, m * 1e6, f"rtt={m * 1e3:.0f}ms")
