"""Shared benchmark machinery: disk-cached simulator runs + CSV rows.

Row contract (benchmarks/run.py prints ``name,us_per_call,derived``):
  name        - benchmark cell id
  us_per_call - microseconds per *message* (1e6 / throughput) for
                throughput cells, or median RTT in us for latency cells
  derived     - paper reference value + deviation, or the measured
                secondary quantity
"""

from __future__ import annotations

import json
import os
from typing import Callable

from repro.core.metrics import rtt_fraction_under, summarize
from repro.core.patterns import run_pattern

CACHE_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                          "bench_cache.json")


class Cache:
    def __init__(self, path: str = CACHE_PATH):
        self.path = os.path.abspath(path)
        self.data: dict = {}
        if os.path.exists(self.path):
            with open(self.path) as f:
                self.data = json.load(f)

    def get_or(self, key: str, fn: Callable[[], dict]) -> dict:
        if key not in self.data:
            self.data[key] = fn()
            self.save()
        return self.data[key]

    def save(self) -> None:
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        with open(self.path, "w") as f:
            json.dump(self.data, f, indent=1)


def sim_cell(cache: Cache, pattern: str, arch: str, workload: str,
             nc: int, msgs: int, n_runs: int = 1, engine: str = "heap",
             **params) -> dict:
    key = f"{pattern}|{arch}|{workload}|{nc}|{msgs}|{n_runs}|" + \
        (f"engine={engine}|" if engine != "heap" else "") + \
        ",".join(f"{k}={v}" for k, v in sorted(params.items()))

    def compute() -> dict:
        rs = run_pattern(pattern, arch, workload, nc, total_messages=msgs,
                         n_runs=n_runs, engine=engine, **params)
        r = rs[0]
        if not r.feasible:
            return {"feasible": False, "reason": r.infeasible_reason}
        s = summarize(r)
        import numpy as np
        meds = [summarize(x).median_rtt_s for x in rs]
        thrs = [summarize(x).throughput_msgs_s for x in rs]
        return {
            "feasible": True,
            "throughput": float(np.nanmean(thrs)),
            "median_rtt": float(np.nanmean(meds)) if r.rtts.size else None,
            "min_rtt": s.min_rtt_s if r.rtts.size else None,
            "p95_rtt": s.p95_rtt_s if r.rtts.size else None,
            "frac_under": {
                str(t): rtt_fraction_under(r, t)
                for t in (0.7, 5.0, 12.5)} if r.rtts.size else None,
            "goodput_gbps": s.goodput_gbps,
            "rejected": s.rejected,
        }

    return cache.get_or(key, compute)


def thr_row(name: str, cell: dict, paper: float | None = None):
    if not cell.get("feasible"):
        return (name, float("nan"), "INFEASIBLE:" + cell.get("reason", "")[:40])
    t = cell["throughput"]
    us = 1e6 / t if t else float("nan")
    if paper:
        dev = 100.0 * (t - paper) / paper
        return (name, us, f"thr={t:.0f}msg/s paper={paper:.0f} dev={dev:+.0f}%")
    return (name, us, f"thr={t:.0f}msg/s")


def rtt_row(name: str, cell: dict, paper_s: float | None = None):
    if not cell.get("feasible"):
        return (name, float("nan"), "INFEASIBLE")
    m = cell["median_rtt"]
    if paper_s:
        dev = 100.0 * (m - paper_s) / paper_s
        return (name, m * 1e6, f"rtt={m * 1e3:.0f}ms paper={paper_s * 1e3:.0f}ms dev={dev:+.0f}%")
    return (name, m * 1e6, f"rtt={m * 1e3:.0f}ms")
