"""Paper Fig 4: work-sharing throughput, Dstream + Lstream, all five
architecture variants across the consumer sweep. 'derived' carries the
paper's quoted values where the text gives one."""

from benchmarks.common import sim_cell, thr_row

# paper-quoted targets (msgs/s): {(arch, workload, consumers): value}
PAPER = {
    ("prs-haproxy", "dstream", 1): 6300,
    ("dts", "dstream", 64): 39000,
    ("prs-haproxy", "dstream", 4): 19000,
    ("mss", "dstream", 64): 14000,
    ("dts", "lstream", 64): 685,
    ("mss", "lstream", 64): 256,
}

ARCHS = ("dts", "prs-haproxy", "prs-haproxy-c4", "prs-stunnel", "mss")
SWEEP = (1, 2, 4, 8, 16, 32, 64)


def run(cache):
    rows = []
    for wl, msgs in (("dstream", 4096), ("lstream", 2048)):
        for arch in ARCHS:
            for nc in SWEEP:
                cell = sim_cell(cache, "work_sharing", arch, wl, nc, msgs)
                rows.append(thr_row(f"fig4/{wl}/{arch}/c{nc}", cell,
                                    PAPER.get((arch, wl, nc))))
    return rows
