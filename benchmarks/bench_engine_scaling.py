"""Engine scaling: heap vs vectorized StreamSim, pushed far beyond the
paper's 64-consumer sweep (256 and 1024 consumers, up to 10^6 messages).

Three cell families:

* ``parity/*``     — both engines on the same 256-consumer work-sharing
  run; 'derived' carries the throughput deviation and the wall-clock
  speedup (the PR's >=10x acceptance gate).
* ``vec1024/*``    — vectorized-only 1024-consumer sweeps at message
  counts the heap engine cannot run interactively.
* ``vec1M/*``      — a 10^6-message work-sharing run on the vectorized
  engine (wall-clock seconds in 'derived').

Inventory note: beyond 64 consumers the paper's 16+16 Andes client nodes
host multiple producer/consumer processes per node — the shared client
NICs then bottleneck exactly as the inventory model dictates.
"""

from __future__ import annotations

import time

from benchmarks.common import Cache, cache_key, sim_cell, thr_row
from repro.core.ds2hpc import ClusterInventory
from repro.core.metrics import throughput_msgs_per_s
from repro.core.patterns import run_pattern

PARITY_NC = 256
PARITY_MSGS = 65_536
BIG_NC = 1024
BIG_MSGS = 262_144
HUGE_MSGS = 1_048_576


def _timed(engine: str, nc: int, msgs: int, arch: str = "dts",
           pattern: str = "work_sharing", workload: str = "dstream"):
    t0 = time.time()
    r = run_pattern(pattern, arch, workload, nc, total_messages=msgs,
                    n_runs=1, seed=0, engine=engine)[0]
    return throughput_msgs_per_s(r), time.time() - t0


def run(cache: Cache):
    rows = []

    def parity_cell() -> dict:
        thr_h, wall_h = _timed("heap", PARITY_NC, PARITY_MSGS)
        thr_v, wall_v = _timed("vectorized", PARITY_NC, PARITY_MSGS)
        return {"thr_heap": thr_h, "thr_vec": thr_v,
                "wall_heap": wall_h, "wall_vec": wall_v}

    c = cache.get_or(
        cache_key(f"engine_scaling|parity|{PARITY_NC}|{PARITY_MSGS}",
                  engine="vectorized"), parity_cell)
    dev = 100.0 * (c["thr_vec"] - c["thr_heap"]) / c["thr_heap"]
    speedup = c["wall_heap"] / c["wall_vec"]
    rows.append((f"engine/parity/ws/dts/c{PARITY_NC}",
                 1e6 / c["thr_vec"],
                 f"dev={dev:+.2f}% speedup={speedup:.1f}x "
                 f"(heap {c['wall_heap']:.1f}s vec {c['wall_vec']:.1f}s)"))

    for arch in ("dts", "prs-haproxy", "mss"):
        cell = sim_cell(cache, "work_sharing", arch, "dstream", BIG_NC,
                        BIG_MSGS, engine="vectorized")
        rows.append(thr_row(f"engine/vec1024/ws/{arch}/c{BIG_NC}", cell))
    cell = sim_cell(cache, "broadcast", "dts", "generic", BIG_NC, 512,
                    engine="vectorized")
    rows.append(thr_row(f"engine/vec1024/bcast/dts/c{BIG_NC}", cell))

    def huge_cell() -> dict:
        thr, wall = _timed("vectorized", PARITY_NC, HUGE_MSGS)
        return {"thr": thr, "wall": wall}

    c = cache.get_or(
        cache_key(f"engine_scaling|vec1M|{PARITY_NC}|{HUGE_MSGS}",
                  engine="vectorized"), huge_cell)
    rows.append((f"engine/vec1M/ws/dts/c{PARITY_NC}", 1e6 / c["thr"],
                 f"thr={c['thr']:.0f}msg/s wall={c['wall']:.1f}s "
                 f"({HUGE_MSGS} msgs)"))

    # the projected 100 Gbps fabric (paper §6), only reachable interactively
    # with the vectorized engine
    inv = ClusterInventory().highspeed()

    def highspeed_cell() -> dict:
        r = run_pattern("work_sharing", "dts", "dstream", BIG_NC,
                        total_messages=BIG_MSGS, n_runs=1, seed=0,
                        engine="vectorized", inventory=inv)[0]
        return {"thr": throughput_msgs_per_s(r)}

    c = cache.get_or(
        cache_key(f"engine_scaling|highspeed1024|{BIG_NC}|{BIG_MSGS}",
                  engine="vectorized"), highspeed_cell)
    rows.append((f"engine/vec1024hs/ws/dts/c{BIG_NC}", 1e6 / c["thr"],
                 f"thr={c['thr']:.0f}msg/s (100Gbps DSN projection)"))
    return rows
