"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun.json. Usage:

  PYTHONPATH=src python -m benchmarks.report [results/dryrun.json]
"""

import json
import sys


def gib(b):
    return f"{b / 2**30:.2f}"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}m"
    return f"{x * 1e6:.0f}u"


def roofline_table(data) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | coll_s | dominant | "
        "useful | frac | mem_floor_s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    recs = [r for r in data.values()
            if r.get("mesh") == "single" and r.get("ok")
            and not r.get("tag")]
    recs.sort(key=lambda r: (r["arch"], order.index(r["shape"])))
    for r in recs:
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
            f"{rl['dominant'][:-2]} | {rl['useful_compute_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} | "
            f"{fmt_s(rl.get('memory_floor_s', 0))} |")
    return "\n".join(lines)


def dryrun_table(data) -> str:
    lines = [
        "| arch | shape | mesh | devs | arg GiB/dev | temp GiB/dev | "
        "fits 16GiB | AG/AR/RS/A2A/CP (count) | coll GiB/dev | compile_s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    recs = [r for r in data.values() if r.get("ok") and not r.get("tag")]
    recs.sort(key=lambda r: (r["arch"], order.index(r["shape"]),
                             r["mesh"]))
    for r in recs:
        m = r.get("memory", {})
        arg = m.get("argument_bytes", 0)
        tmp = m.get("temp_bytes", 0)
        fits = "Y" if (arg + tmp) < 16 * 2**30 else "OVER"
        c = r.get("collectives", {})
        counts = "/".join(str(c.get(k, {}).get("count", 0)) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        cbytes = sum(v.get("bytes", 0) for v in c.values())
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['devices']} | "
            f"{gib(arg)} | {gib(tmp)} | {fits} | {counts} | {gib(cbytes)} | "
            f"{r['timings']['compile_s']} |")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    with open(path) as f:
        data = json.load(f)
    n_ok = sum(1 for r in data.values() if r.get("ok"))
    n_single = sum(1 for r in data.values()
                   if r.get("ok") and r.get("mesh") == "single")
    n_multi = sum(1 for r in data.values()
                  if r.get("ok") and r.get("mesh") == "multi")
    print(f"## Dry-run summary: {n_ok} cells OK "
          f"({n_single} single-pod, {n_multi} multi-pod)\n")
    print("### §Dry-run (memory + collective schedule per cell)\n")
    print(dryrun_table(data))
    print("\n### §Roofline (single-pod, trip-count-corrected)\n")
    print(roofline_table(data))


if __name__ == "__main__":
    main()
