"""Paper Fig 8: broadcast&gather RTT CDFs — convergence of the three
architectures at >=32 consumers (measured as the max/min spread of median
RTTs, which shrinks as consumers scale)."""

from benchmarks.common import sim_cell


def run(cache):
    rows = []
    for nc in (4, 32):
        meds = {}
        for arch in ("dts", "prs-haproxy", "mss"):
            cell = sim_cell(cache, "broadcast_gather", arch, "generic", nc,
                            384)
            meds[arch] = cell.get("median_rtt") or float("nan")
        spread = max(meds.values()) / max(min(meds.values()), 1e-9)
        rows.append((f"fig8/median_spread/c{nc}", 0.0,
                     f"max/min={spread:.1f} ({'converging' if nc >= 32 else 'wide'};"
                     f" paper: CDFs converge at >=32)"))
    return rows
