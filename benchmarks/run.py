"""Benchmark harness: one module per paper table/figure + the roofline and
kernel micro-benches. Prints ``name,us_per_call,derived`` CSV.

Simulator cells are disk-cached (results/bench_cache.json); delete the
cache to force re-measurement."""

import sys
import time

from benchmarks import (
    bench_engine_scaling, bench_fig4_work_sharing, bench_fig5_rtt_cdf,
    bench_fig6_feedback_rtt, bench_fig7_broadcast_gather, bench_fig8_bg_cdf,
    bench_highspeed_projection, bench_kernels, bench_payload_sweep,
    bench_roofline, bench_table1_workloads)
from benchmarks.common import Cache

MODULES = [
    ("table1", bench_table1_workloads),
    ("fig4", bench_fig4_work_sharing),
    ("fig5", bench_fig5_rtt_cdf),
    ("fig6", bench_fig6_feedback_rtt),
    ("fig7", bench_fig7_broadcast_gather),
    ("fig8", bench_fig8_bg_cdf),
    ("highspeed", bench_highspeed_projection),
    ("payload_sweep", bench_payload_sweep),
    ("kernels", bench_kernels),
    ("roofline", bench_roofline),
    ("engine_scaling", bench_engine_scaling),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    cache = Cache()
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if only and only != name:
            continue
        t0 = time.time()
        for row in mod.run(cache):
            n, us, derived = row
            print(f"{n},{us:.1f},{derived}")
        print(f"# {name} finished in {time.time() - t0:.1f}s",
              file=sys.stderr)
    cache.save()


if __name__ == "__main__":
    main()
