"""Benchmark harness: one module per paper table/figure + the roofline and
kernel micro-benches. Prints ``name,us_per_call,derived`` CSV.

Simulator cells run on the vectorized engine by default; ``--engine
heap`` is the escape hatch back to the exact reference engine (cache
keys carry the engine name, so the two never collide).

Simulator cells are disk-cached (results/bench_cache.json); delete the
cache to force re-measurement.  A cache file with legacy-format keys
(pre engine/params-aware keying) aborts the run loudly instead of
serving stale numbers.

Campaign mode executes a whole sweep grid as batched work (seed-stacked
engine runs + process fan-out; see ``src/repro/core/campaign.py``) and
writes the per-cell + averaged summaries to ``results/``::

    python -m benchmarks.run --campaign demo
    python -m benchmarks.run --campaign my_grid.json --workers 2 \\
        --campaign-out results/campaign_mygrid.json

The JSON spec mirrors ``CampaignSpec`` (axes, n_runs, params,
cell_params); ``demo`` runs a small built-in paper-style grid.
Campaign cells share the bench cache, so re-running a finished (or
interrupted) campaign is incremental."""

import argparse
import glob
import json
import math
import os
import sys
import time

from benchmarks import (
    bench_campaign, bench_deployment_feasibility, bench_engine_scaling,
    bench_fig4_work_sharing, bench_fig5_rtt_cdf, bench_fig6_feedback_rtt,
    bench_fig7_broadcast_gather, bench_fig8_bg_cdf,
    bench_highspeed_projection, bench_jax_engine, bench_kernels,
    bench_overflow_regime, bench_payload_sweep, bench_roofline,
    bench_table1_workloads)
from benchmarks import common
from benchmarks.common import Cache, LegacyCacheError

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

MODULES = [
    ("table1", bench_table1_workloads),
    ("fig4", bench_fig4_work_sharing),
    ("fig5", bench_fig5_rtt_cdf),
    ("fig6", bench_fig6_feedback_rtt),
    ("fig7", bench_fig7_broadcast_gather),
    ("fig8", bench_fig8_bg_cdf),
    ("highspeed", bench_highspeed_projection),
    ("payload_sweep", bench_payload_sweep),
    ("kernels", bench_kernels),
    ("roofline", bench_roofline),
    ("engine_scaling", bench_engine_scaling),
    ("overflow_regime", bench_overflow_regime),
    ("campaign", bench_campaign),
    ("deployment_feasibility", bench_deployment_feasibility),
    ("jax_engine", bench_jax_engine),
]


def write_bench_json(name: str, rows: list, wall_s: float) -> str:
    """Machine-readable companion to the CSV: one
    ``results/BENCH_<name>.json`` per bench module (CI uploads them as
    artifacts), mapping each cell name to its measured row.

    NaN never reaches the artifact as a bare value: a non-finite
    ``us_per_call`` (infeasible cells) is written as ``null`` plus an
    explicit ``"status": "nan"`` marker, and the dump runs with
    ``allow_nan=False`` so any *other* NaN that sneaks into a row is a
    loud ``ValueError`` at write time — a stale artifact full of
    silent ``NaN`` literals (not even valid JSON) is how the chaos
    bench rot went unnoticed."""
    out = os.path.join(RESULTS_DIR, f"BENCH_{name}.json")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cells = {}
    for n, us, derived in rows:
        cell: dict = {"us_per_call": us, "derived": derived}
        if not math.isfinite(us):
            cell["us_per_call"] = None
            cell["status"] = "nan"
        cells[n] = cell
    payload = {
        "module": name,
        "wall_s": round(wall_s, 3),
        "engine_override": common.DEFAULT_ENGINE,
        "cells": cells,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, allow_nan=False)
    return out


def _registered_artifact_names() -> set:
    """Every BENCH_<name>.json stem the current bench registry can
    produce: one per module plus one per *named* campaign."""
    names = {name for name, _ in MODULES}
    names |= {f"campaign_{n}" for n in NAMED_CAMPAIGNS}
    return names


def check_artifacts() -> list[str]:
    """Validate ``results/BENCH_*.json`` against the bench registry.

    Returns human-readable problem strings (empty = clean).  Two
    failure classes, both of which have bitten before:

    * an artifact whose stem maps to no registered bench module or
      named campaign — a leftover from a deleted bench (the stale
      ``BENCH_chaos.json``) that CI can never refresh;
    * a bare ``NaN``/``Infinity`` literal, or a non-finite/null
      ``us_per_call`` without the explicit ``"status": "nan"``
      marker — a number downstream tooling would silently propagate.
    """
    known = _registered_artifact_names()
    problems: list[str] = []

    def _reject(const: str):
        raise ValueError(f"bare {const} literal")

    for path in sorted(glob.glob(os.path.join(RESULTS_DIR,
                                              "BENCH_*.json"))):
        base = os.path.basename(path)
        stem = base[len("BENCH_"):-len(".json")]
        if stem not in known:
            problems.append(
                f"{base}: no registered bench module or named campaign "
                f"produces it (stale artifact — delete it)")
            continue
        try:
            with open(path) as f:
                payload = json.load(f, parse_constant=_reject)
        except ValueError as e:
            problems.append(f"{base}: invalid JSON ({e})")
            continue
        for n, cell in payload.get("cells", {}).items():
            us = cell.get("us_per_call")
            bad = us is None or (isinstance(us, float)
                                 and not math.isfinite(us))
            if bad and cell.get("status") != "nan":
                problems.append(
                    f"{base}: cell {n!r} has non-finite us_per_call "
                    f"without the explicit 'status': 'nan' marker")
    return problems

#: --campaign demo: a small paper-style grid (Fig 6 slice + tenants),
#: including one overflow-regime cell (the dts/4-consumer cell gets a
#: tight 256-message queue cap + the overflow stress knobs, so the
#: demo exercises the lane-resolved stacked flow-control path — the
#: grid's per-queue volume is 2048/2 = 1024 messages, well past the
#: cap, and the 3 seed lanes stack through one batched run)
DEMO_CAMPAIGN = {
    "name": "demo",
    "patterns": ["feedback"],
    "architectures": ["dts", "mss"],
    "workloads": ["dstream"],
    "consumers": [4, 8],
    "n_runs": 3,
    "total_messages": 2048,
    "cell_params": [
        [{"arch": "dts", "n_consumers": 4},
         {"confirm_window": 64, "prefetch": 16, "ack_batch": 4,
          "consumer_proc_s": 2e-3, "queue_max_bytes": 256 * 16384}],
    ],
}


#: named campaign specs runnable as --campaign <name>
NAMED_CAMPAIGNS = {
    "demo": lambda: DEMO_CAMPAIGN,
    # the §6 deployment-feasibility grid (three archs x tenant sweep)
    "deployment": lambda: bench_deployment_feasibility.DEPLOYMENT_CAMPAIGN,
}


def run_campaign_cli(args, cache: Cache) -> None:
    from repro.core.campaign import CampaignSpec, run_campaign
    if args.campaign in NAMED_CAMPAIGNS:
        spec = CampaignSpec.from_json(
            json.dumps(NAMED_CAMPAIGNS[args.campaign]()))
    else:
        with open(args.campaign) as f:
            spec = CampaignSpec.from_json(f.read())
    if args.engine is not None:
        # the --engine escape hatch applies to campaign cells too
        # (explicit per-spec params win)
        spec.params.setdefault("engine", args.engine)
        if args.engine == "jax":
            # opt the grid into the whole-run device program; cells
            # outside its validated regime fall back per cell (the
            # fallback is counted in the campaign result JSON)
            spec.params.setdefault("jax_device_loop", True)
    res = run_campaign(spec, cache=cache, workers=args.workers,
                       progress=lambda m: print(f"# {m}", file=sys.stderr))
    out = args.campaign_out or os.path.join(
        os.path.dirname(__file__), "..", "results",
        f"campaign_{spec.name}.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        f.write(res.to_json())
    cache.save()
    print(f"# campaign {spec.name!r}: {len(res.cells)} cells "
          f"({res.n_cached} cached) in {res.wall_s:.1f}s -> {out}",
          file=sys.stderr)
    print("name,us_per_call,derived")
    rows = []
    for s in res.averaged:
        us = (1e6 / s.throughput_msgs_s if s.feasible
              and s.throughput_msgs_s else float("nan"))
        tenant_tag = f"/t{s.tenants}" if s.tenants > 1 else ""
        name = (f"campaign/{spec.name}/{s.pattern}/{s.arch}/{s.workload}/"
                f"c{s.n_consumers}{tenant_tag}")
        derived = (f"thr={s.throughput_msgs_s:.0f}msg/s "
                   f"n_runs={s.n_runs} engine={s.engine}")
        print(f"{name},{us:.1f},{derived}")
        rows.append((name, us, derived))
    jpath = write_bench_json(f"campaign_{spec.name}", rows, res.wall_s)
    print(f"# wrote {jpath}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="?", default=None,
                    help="run a single module (e.g. fig4, campaign)")
    ap.add_argument("--engine", choices=("heap", "vectorized", "jax"),
                    default=None,
                    help="StreamSim backend for simulator cells "
                         "(default: the SimParams default, vectorized); "
                         "'jax' falls back per cell when jax is missing")
    ap.add_argument("--campaign", default=None, metavar="SPEC",
                    help="execute a campaign grid: path to a "
                         "CampaignSpec JSON file, or a named grid "
                         "('demo', 'deployment')")
    ap.add_argument("--campaign-out", default=None, metavar="PATH",
                    help="where to write the campaign results JSON "
                         "(default results/campaign_<name>.json)")
    ap.add_argument("--workers", type=int, default=None,
                    help="campaign process fan-out (default: one per "
                         "CPU, capped by the group count)")
    ap.add_argument("--check-artifacts", action="store_true",
                    help="validate results/BENCH_*.json against the "
                         "bench registry (stale artifacts, bare NaN) "
                         "and exit")
    args = ap.parse_args()
    if args.check_artifacts:
        problems = check_artifacts()
        for p in problems:
            print(f"ARTIFACT: {p}", file=sys.stderr)
        if problems:
            raise SystemExit(1)
        print("# artifacts OK", file=sys.stderr)
        return
    if args.campaign and args.only:
        ap.error("--campaign replaces the bench modules; drop the "
                 f"positional module argument {args.only!r}")
    common.DEFAULT_ENGINE = args.engine
    try:
        cache = Cache()
    except LegacyCacheError as e:
        print(f"FATAL: {e}", file=sys.stderr)
        raise SystemExit(2)
    if args.campaign:
        run_campaign_cli(args, cache)
        return
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        rows = [tuple(row) for row in mod.run(cache)]
        for n, us, derived in rows:
            print(f"{n},{us:.1f},{derived}")
        wall = time.time() - t0
        jpath = write_bench_json(name, rows, wall)
        print(f"# {name} finished in {wall:.1f}s -> {jpath}",
              file=sys.stderr)
    cache.save()


if __name__ == "__main__":
    main()
