"""Benchmark harness: one module per paper table/figure + the roofline and
kernel micro-benches. Prints ``name,us_per_call,derived`` CSV.

Simulator cells run on the vectorized engine by default; ``--engine
heap`` is the escape hatch back to the exact reference engine (cache
keys carry the engine name, so the two never collide).

Simulator cells are disk-cached (results/bench_cache.json); delete the
cache to force re-measurement.  A cache file with legacy-format keys
(pre engine/params-aware keying) aborts the run loudly instead of
serving stale numbers."""

import argparse
import sys
import time

from benchmarks import (
    bench_engine_scaling, bench_fig4_work_sharing, bench_fig5_rtt_cdf,
    bench_fig6_feedback_rtt, bench_fig7_broadcast_gather, bench_fig8_bg_cdf,
    bench_highspeed_projection, bench_kernels, bench_overflow_regime,
    bench_payload_sweep, bench_roofline, bench_table1_workloads)
from benchmarks import common
from benchmarks.common import Cache, LegacyCacheError

MODULES = [
    ("table1", bench_table1_workloads),
    ("fig4", bench_fig4_work_sharing),
    ("fig5", bench_fig5_rtt_cdf),
    ("fig6", bench_fig6_feedback_rtt),
    ("fig7", bench_fig7_broadcast_gather),
    ("fig8", bench_fig8_bg_cdf),
    ("highspeed", bench_highspeed_projection),
    ("payload_sweep", bench_payload_sweep),
    ("kernels", bench_kernels),
    ("roofline", bench_roofline),
    ("engine_scaling", bench_engine_scaling),
    ("overflow_regime", bench_overflow_regime),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="?", default=None,
                    help="run a single module (e.g. fig4, overflow_regime)")
    ap.add_argument("--engine", choices=("heap", "vectorized"), default=None,
                    help="StreamSim backend for simulator cells "
                         "(default: the SimParams default, vectorized)")
    args = ap.parse_args()
    common.DEFAULT_ENGINE = args.engine
    try:
        cache = Cache()
    except LegacyCacheError as e:
        print(f"FATAL: {e}", file=sys.stderr)
        raise SystemExit(2)
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        for row in mod.run(cache):
            n, us, derived = row
            print(f"{n},{us:.1f},{derived}")
        print(f"# {name} finished in {time.time() - t0:.1f}s",
              file=sys.stderr)
    cache.save()


if __name__ == "__main__":
    main()
